"""Disaggregated prefill/decode serving quickstart — one gang lease,
two tiers, KV pages streamed over the routed XLink-CXL fabric.

The pool places a ``prefill`` and a ``decode`` sub-lease as one gang
(the decode tier's placement scores the KV handoff route against live
traffic); ``DisaggCluster`` then runs one arrival trace across both
tiers on a single modeled clock: prefill pods run bucketed prefill and
stream each KV page the moment it is sliced, the fabric prices every
page under the ``kv:<tenant>`` label, and decode pods admit a request
as pages land — never decoding a row before its last page arrives.

The punchline is the determinism invariant: the disaggregated token
stream is bit-identical to the colocated engine's, for direct pod->pod
transfers AND when staged through a tier-2 memory node.

    PYTHONPATH=src python examples/disagg_demo.py
"""

import jax

from repro.configs import get_config
from repro.core import fabric as fb
from repro.disagg import DisaggCluster, DisaggConfig, PrefillWorker
from repro.fabric import Topology, Transport
from repro.models.api import build_model
from repro.obs import Tracer
from repro.pool import ResourcePool, build_inventory
from repro.serve import (Engine, EngineConfig, burst_trace,
                         latency_summary, run_trace)

cfg = get_config("qwen1.5-0.5b", smoke=True)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

# ---------------------------------------------------------------------------
# 1. gang placement: one grant, two role-tagged sub-leases.  The
#    allocator wires the decode member as a handoff peer of the prefill
#    member, so its placement avoids the page stream's busy links; the
#    estate route the stream rides comes back from handoff_route().
# ---------------------------------------------------------------------------
pool = ResourcePool(build_inventory(
    n_pods=4, pod_size=8, hbm_per_accel_gb=192.0, n_memory_nodes=2,
    memory_node_gb=1024.0, interconnect="scalepool"), policy="contention")
gang = pool.lease_gang("serve", {
    "prefill": dict(n_accels=8),
    "decode": dict(n_accels=8, tier2_gb=8, kv_gb=1.0, tenants=("kv",)),
})
estate_route = pool.handoff_route(gang["prefill"], gang["decode"])
print(f"gang: prefill={gang['prefill'].job} decode={gang['decode'].job}")
print(f"estate handoff route: "
      f"{[l.name for l in estate_route.links] if estate_route else None}")

# ---------------------------------------------------------------------------
# 2. the serving fabric: two pods behind one leaf switch plus a tier-2
#    memory node for staged handoffs.  The transport is SHARED — every
#    KV page contends with whatever else rides these links.
# ---------------------------------------------------------------------------
topo = Topology("disagg-demo")
topo.add_node("leaf", "switch")
for p in (0, 1):
    topo.add_node(f"pod:{p}", "pod")
    topo.connect(f"pod:{p}", "leaf", fb.UALINK200, capacity=2e8,
                 latency=1e-6)
topo.add_node("mem:0", "memory")
topo.connect("mem:0", "leaf", fb.CXL_CAPACITY, capacity=4e8, latency=1e-6)

tracer = Tracer()
tx = Transport(topo, tracer=tracer)
ecfg = EngineConfig(max_slots=4, max_seq=96, page_size=16)
trace = burst_trace(8, prompt_len=48, max_new_tokens=16, vocab=cfg.vocab,
                    seed=0)

# the colocated reference: one engine does both phases
ref = run_trace(Engine.local(model, ecfg, params=params), trace)
print(f"\ncolocated : {latency_summary(ref)}")

# ---------------------------------------------------------------------------
# 3. the disaggregated cluster: prefill on pod:0, decode on pod:1,
#    pages streamed direct over the XLink trunk as prefill produces
#    them (min_ready_pages=1 reserves the decode slot on first landing)
# ---------------------------------------------------------------------------
for staging in ("direct", "tier2"):
    kw = {}
    if staging == "tier2":
        kw = dict(stage_in=topo.route("pod:0", "mem:0"),
                  stage_out=topo.route("mem:0", "pod:1"))
    cluster = DisaggCluster(
        [PrefillWorker(Engine.local(model, ecfg, params=params,
                                    tracer=tracer), name="p0")],
        [Engine.local(model, ecfg, params=params, tenant="kv",
                      tracer=tracer)],
        transport=tx, route=topo.route("pod:0", "pod:1"), tenant="kv",
        config=DisaggConfig(staging=staging, min_ready_pages=1), **kw)
    handles = cluster.run(trace)
    assert [h.tokens for h in handles] == [h.tokens for h in ref], \
        "disaggregation must never change tokens"
    transit = [h.kv_transit_s for h in handles]
    print(f"{staging:10s}: {latency_summary(handles)}")
    print(f"            handoffs={cluster.handoffs} "
          f"kv transit mean={sum(transit) / len(transit) * 1e6:.1f}us "
          f"max={max(transit) * 1e6:.1f}us")
tx.quiesce()

# ---------------------------------------------------------------------------
# 4. degenerate mode: no route between the tiers means prefill and
#    decode share a pod — the cluster IS the plain engine, replaying
#    run_trace bit-for-bit (tokens, clocks and trace events); it is the
#    correctness anchor every routed mode is measured against
# ---------------------------------------------------------------------------
degenerate = DisaggCluster(
    [PrefillWorker(Engine.local(model, ecfg, params=params))],
    [Engine.local(model, ecfg, params=params)])
handles = degenerate.run(trace)
assert [h.tokens for h in handles] == [h.tokens for h in ref]
assert [(h.submit_clock, h.first_token_clock, h.done_clock)
        for h in handles] == \
    [(h.submit_clock, h.first_token_clock, h.done_clock) for h in ref]
print(f"\ndegenerate: bit-identical to the colocated engine "
      f"({degenerate.colocated} requests, {degenerate.handoffs} handoffs)")

# the kv: label class attributes every page's bytes to its tenant
print("\nper-link kv bytes:")
for link, labels in sorted(tx.link_label_bytes.items()):
    kv_bytes = sum(b for lab, b in labels.items() if lab.startswith("kv:"))
    if kv_bytes:
        print(f"  {link:18s} {kv_bytes / 1e6:8.2f} MB")

pool.release_gang("serve")

"""Walk the repro.obs observability stack end to end on the Fig. 10
cross-tenant contention scenario: run the shared-trunk experiment with
a flight recorder attached, export the Chrome/Perfetto timeline, and
read the per-link utilization report that *attributes* the shared
tenants' ~1.55x p95 degradation to tier-2 trunk occupancy — the same
three artifacts ``--trace-out`` and ``scripts/trace_report.py`` give
you on any serving run.  Then the determinism toolchain on top of the
same run: the lossless JSONL stream (``--trace-stream``), the A/B
trace differ (``scripts/trace_diff.py``), and the schedule-perturbation
race detector (``--racecheck K`` / ``repro.analysis.racecheck``).

    PYTHONPATH=src python examples/trace_explorer.py      # from repo root
"""

import json
import sys
import tempfile
from pathlib import Path

_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))            # benchmarks/ package
sys.path.insert(0, str(_ROOT / "src"))    # repro, if PYTHONPATH unset

from benchmarks.fig10_contention import run
from repro.obs import (format_link_report, link_report_from_trace,
                       tier_report, validate_trace_events)

# ---------------------------------------------------------------------------
# 1. run Fig. 10 (smoke scale) with the flight recorder attached.
#    Tracing is passive — the modeled clocks and tokens are bit-identical
#    to an untraced run (summary["tokens_invariant"] pins that claim).
# ---------------------------------------------------------------------------
trace_path = str(Path(tempfile.gettempdir()) / "fig10_trace.json")
print(f"== running fig10 --smoke with trace -> {trace_path} ==")
lines, summary = run(smoke=True, trace_out=trace_path)

shared = summary["per_tenant_p95"]["shared"]
isolated = summary["per_tenant_p95"]["isolated"]
print(f"\nper-tenant p95 (modeled seconds):")
for t in sorted(shared):
    print(f"  tenant {t}: isolated {isolated[t]:.3f}s -> "
          f"shared trunk {shared[t]:.3f}s "
          f"({shared[t] / isolated[t]:.2f}x)")
print(f"aggregate degradation on the shared trunk: "
      f"{summary['shared_degradation']:.2f}x "
      f"(tokens_invariant={summary['tokens_invariant']})")

# ---------------------------------------------------------------------------
# 2. the exported timeline is plain Chrome trace_event JSON: load it in
#    ui.perfetto.dev (or chrome://tracing) and you get one row per
#    tenant engine, per request, per fabric link, and per pool actor.
# ---------------------------------------------------------------------------
with open(trace_path) as f:
    doc = json.load(f)
problems = validate_trace_events(doc)
tr = summary["trace"]
print(f"\n== exported timeline ==")
print(f"{tr['path']}: {tr['events']} events recorded, "
      f"{tr['dropped']} dropped by the ring, "
      f"schema problems: {problems or 'none'}")
print("open in https://ui.perfetto.dev to see the lanes: engine:a / "
      "engine:b decode+spill spans over link:a->sw / link:b->sw / "
      "link:sw->mem occupancy")

# ---------------------------------------------------------------------------
# 3. attribution: rebuild the per-link report from the trace file alone
#    (scripts/trace_report.py does exactly this offline).  The shared
#    trunk (sw->mem) is the only link both tenants' spill/fetch routes
#    cross — its busy seconds and queueing stretch ARE the degradation.
# ---------------------------------------------------------------------------
links = link_report_from_trace(doc)
print(f"\n== per-link utilization / queueing report (from trace) ==")
print(format_link_report(links))

trunk = links["sw->mem"]
total_busy = sum(r["busy_s"] for r in links.values())
print(f"\nshared trunk sw->mem: {trunk['busy_s']:.3f}s busy "
      f"({trunk['busy_s'] / total_busy:.0%} of all link-busy seconds), "
      f"peak {trunk['peak_flows']} concurrent flows, "
      f"{trunk['stretch_s']:.3f}s of contention-induced stretch")
print(f"tier fold: { {t: round(r['busy_s'], 3) for t, r in sorted(tier_report(links).items())} }")
print(f"\nreading: every modeled second of the {summary['shared_degradation']:.2f}x "
      f"p95 blow-up is on the trunk's queue — the isolated and "
      f"hierarchical estates keep per-tenant leaf links below "
      f"saturation, which is the paper's case for tiered fabrics.")

# ---------------------------------------------------------------------------
# 4. self-check: replay the exported stream through the modeled-time
#    sanitizer (repro.analysis).  Invariants the sanitizer enforces:
#
#      finite-clock            every ts/dur finite, dur >= 0
#      track-monotone          per-track event ends never regress
#      span-serial             one engine never overlaps two compute spans
#      transfer-causality      every fabric span pairs with a prior
#                              begin_transfer carrying the same fid+bytes
#      link-conservation       dur >= solo_s, bytes <= capacity x dur,
#                              and per link the span-interval UNION times
#                              capacity covers the total bytes moved
#      kv-conservation         free + hot pages == pool at every step-end
#                              sample, across arbiter revocations
#      revocation-attribution  swap seconds charged to a tenant never
#                              exceed revocation costs priced against it
#
#    The same check runs live in CI via `--sanitize` on the fig7/9/10/11
#    smoke benchmarks, and offline via scripts/sanitize_trace.py.
# ---------------------------------------------------------------------------
from repro.analysis import sanitize_trace_doc

report = sanitize_trace_doc(doc)
print(f"\n== modeled-time sanitizer ==")
print(report.format())
assert report.ok, "the exported trace violates a causality invariant"

# ---------------------------------------------------------------------------
# 5. A/B diffing.  The Perfetto export quantizes clocks to whole µs; the
#    JSONL stream (``--trace-stream``, repro.obs.JsonlSink) is the
#    lossless sibling: every event is written through a tracer hook
#    BEFORE the ring can drop it, with full float precision.  Two
#    recordings — two seeds, two branches, before/after a refactor —
#    are compared structurally with repro.analysis.diff_trace_files
#    (CLI: scripts/trace_diff.py A B): per track, the FIRST divergent
#    event is named field by field, plus end-clock drift and per-label
#    link-byte drift.  Identical run -> empty diff:
# ---------------------------------------------------------------------------
from repro.analysis import diff_trace_files

diff = diff_trace_files(trace_path, trace_path)
print(f"\n== A/B trace diff (against itself) ==")
print(diff.format())
assert diff.identical

# ---------------------------------------------------------------------------
# 6. the race detector.  Everything above trusts that the modeled
#    estate is DETERMINISTIC — same inputs, bit-identical trace.  The
#    racecheck harness (repro.analysis.racecheck) attacks that claim:
#    it re-runs a scenario K times with the ``tiebreak`` seam active,
#    which perturbs every incidental enumeration order inside the
#    scheduler's same-timestamp drain, the arbiter's victim scan, and
#    the transport's flow re-rating.  Spec'd tie-breaks (FIFO by seq,
#    victim = max-over then min-name) are sort keys and never move; if
#    any outcome or trace event shifts, an incidental order leaked into
#    a decision, and the report names the first divergent event.  CI
#    runs this as `--racecheck 4` on the fig9/10/11 smoke benchmarks:
# ---------------------------------------------------------------------------
from benchmarks.fig10_contention import racecheck_scenario
from repro.analysis import racecheck

rc = racecheck(racecheck_scenario, seeds=(1, 2), label="fig10")
print(f"\n== schedule-perturbation racecheck ==")
print(rc.format())
assert rc.ok, "fig10 is order-dependent — see the first divergent event"

"""repro.serve quickstart — request-level serving with lease-budgeted
tier-2 KV paging (paper §5/§6, Fig. 7 at request granularity).

    PYTHONPATH=src python examples/serve_tiered.py

For N tenants fair-sharing ONE physical page pool (PoolArbiter), see
``examples/serve_multitenant.py``.
"""

from repro.configs import get_config
from repro.core.simulator import avg_access_latency, make_mem_system
from repro.core.tiering import KVBudget, TieringPolicy, tier_traffic_report
from repro.models.api import build_model
from repro.pool import smoke_pool
from repro.serve import (Engine, EngineConfig, Request, burst_trace,
                         latency_summary, run_trace)

cfg = get_config("qwen1.5-0.5b", smoke=True)
model = build_model(cfg)

# ---------------------------------------------------------------------------
# 1. local engine: submit requests, step continuous batching, read stats
# ---------------------------------------------------------------------------
engine = Engine.local(model, EngineConfig(max_slots=4, max_seq=96,
                                          page_size=16))
handles = [engine.submit(Request(prompt_tokens=tuple(range(1, 1 + n)),
                                 max_new_tokens=8))
           for n in (12, 20, 28)]
engine.run_until_idle()
print("generated:", [h.result() for h in handles])
print("stats:", {k: v for k, v in engine.stats().items()
                 if k in ("completed", "tokens_decoded", "kv")})

# ---------------------------------------------------------------------------
# 2. lease-backed engine: the pool grants the tier-2 KV byte budget and a
#    tight tier-1 page quota forces spills over the capacity fabric
# ---------------------------------------------------------------------------
pool = smoke_pool("scalepool")
lease = pool.lease("svc", 4, tier2_gb=64, kv_gb=2.0)
print(f"\nlease: {lease.n_accels} accels, "
      f"{lease.kv_bytes / 1e9:.0f}GB KV grant -> {lease.tiering_policy()}")

budget = KVBudget(tier1_pages=10, tier2_bytes=lease.kv_bytes, page_size=16)
tiered = Engine.from_lease(model, lease, EngineConfig(max_slots=4,
                                                      max_seq=96,
                                                      page_size=16),
                           budget=budget)
trace = burst_trace(8, prompt_len=32, max_new_tokens=32, vocab=cfg.vocab,
                    seed=0)
hs = run_trace(tiered, trace)
stats = tiered.stats()
print(f"tiered run: {stats['completed']} done, "
      f"{stats['preempt_swaps']} tier-2 swaps, "
      f"residency={stats['kv']}")
print("modeled latency:", latency_summary(hs))

# ---------------------------------------------------------------------------
# 3. paged KV under the hood: the engine owns a shared physical page
#    pool (tier1_pages pages + a trash page) and a per-row page table
#    the Pallas paged-attention kernel gathers through — a sequence
#    needs neither contiguous physical pages nor full tier-1 residency.
#    Under pressure the coldest *pages* are evicted to tier-2 and later
#    fetched back into different physical pages; prefill pads prompts
#    to power-of-two page buckets so the jit program count is bounded
#    by the bucket list, not by distinct prompt lengths.
# ---------------------------------------------------------------------------
res = stats["kv"]
print(f"\npage pool: {res['tier1_pages_used']}/{res['tier1_pages_quota']} "
      f"pages hot, {res['spills']} page evictions / {res['fetches']} "
      f"fetches over the capacity fabric, "
      f"{res['partial_seqs']} partially-resident seqs right now")
print(f"prefill buckets {stats['prefill_buckets']} -> "
      f"{stats['prefill_compiles']} compiled prefill programs")

# ---------------------------------------------------------------------------
# 4. the paper's Fig-7 story for this working set (analytic §5 model)
# ---------------------------------------------------------------------------
ms_base = make_mem_system("baseline")
ms_sp = make_mem_system("tiered")
ws = 768e9
print(f"\nworking set 768GB: baseline {avg_access_latency(ms_base, ws)*1e6:.2f}us"
      f" vs ScalePool {avg_access_latency(ms_sp, ws)*1e6:.2f}us per 4KiB block")
print(tier_traffic_report(TieringPolicy(), n_params=0.5e9))

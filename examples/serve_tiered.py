"""Serving example with tier-2 KV paging (deliverable b / paper §5):
generate with a paged KV cache whose cold pages live in the capacity
tier, and report the tier traffic a ScalePool fabric would carry.

    PYTHONPATH=src python examples/serve_tiered.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import fabric as fb
from repro.core.simulator import make_mem_system, avg_access_latency
from repro.core.tiering import PagedKV, TieringPolicy, tier_traffic_report
from repro.models.api import build_model

cfg = get_config("qwen1.5-0.5b", smoke=True)
model = build_model(cfg)
rng = jax.random.PRNGKey(0)
params = model.init(rng)

B, prompt, gen = 2, 32, 16
max_seq = prompt + gen
tokens = jax.random.randint(rng, (B, prompt), 1, cfg.vocab)

cache = model.init_cache(B, max_seq, dtype=jnp.float32)
logits, cache = model.prefill(params, {"tokens": tokens}, cache)
tok = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
outs = [int(tok[0, 0])]
for i in range(gen - 1):
    logits, cache = model.decode(params, tok, cache, jnp.int32(prompt + i))
    tok = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
    outs.append(int(tok[0, 0]))
print("generated:", outs)

# page the (synthetic) long-context KV pool across tiers
kv = PagedKV.create(n_layers=cfg.n_layers, batch=B, max_seq=4096,
                    kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                    page_size=256, hot_fraction=0.25)
kv.spill(hot_slot=0, cold_slot=0)
kv = kv.fetch(cold_slot=0, hot_slot=1, logical_page=9)
print(f"paged KV: {kv.hot_pages} hot pages (tier-1), "
      f"{kv.cold_pages} cold pages (tier-2)")

# the paper's Fig-7 story for this working set
ms_base = make_mem_system("baseline")
ms_sp = make_mem_system("tiered")
ws = 768e9
print(f"working set 768GB: baseline {avg_access_latency(ms_base, ws)*1e6:.2f}us"
      f" vs ScalePool {avg_access_latency(ms_sp, ws)*1e6:.2f}us per 4KiB block")
print(tier_traffic_report(TieringPolicy(), n_params=0.5e9))

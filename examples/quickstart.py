"""Quickstart: build an assigned architecture, run a training step and a
decode step on CPU, and print the ScalePool fabric analysis for it.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import fabric as fb
from repro.core import costmodel as cm
from repro.models.api import build_model

# 1. a reduced config of an assigned architecture (exact full configs are
#    exercised by the dry-run: python -m repro.launch.dryrun)
cfg = get_config("qwen1.5-0.5b", smoke=True)
model = build_model(cfg)
rng = jax.random.PRNGKey(0)
params = model.init(rng)
print(f"{cfg.name}: {sum(x.size for x in jax.tree.leaves(params)):,} params")

# 2. one training step (loss + grads)
batch = {
    "tokens": jax.random.randint(rng, (2, 32), 0, cfg.vocab),
    "labels": jax.random.randint(rng, (2, 32), 0, cfg.vocab),
}
loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
print(f"train: loss={float(loss):.3f}")

# 3. prefill + a few decode steps
cache = model.init_cache(2, 48, dtype=jnp.float32)
logits, cache = model.prefill(params, {"tokens": batch["tokens"]}, cache)
tok = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
for i in range(4):
    logits, cache = model.decode(params, tok, cache, jnp.int32(32 + i))
    tok = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
print(f"decode: generated {tok[:, 0].tolist()}")

# 4. what would ScalePool's fabric do with this model's gradient sync?
xlink = fb.xlink_cluster_fabric(72)
cxl = fb.cxl_fabric(1024)
ib = fb.infiniband_fabric(1024)
dom_cxl = cm.HierarchicalDomains(intra=xlink, inter=cxl, intra_size=8, n_groups=16)
dom_ib = cm.HierarchicalDomains(intra=xlink, inter=ib, intra_size=8, n_groups=16)
grad_bytes = int(2 * sum(x.size for x in jax.tree.leaves(params)))
t_sp = cm.hierarchical_allreduce_time(dom_cxl, grad_bytes)
t_ib = cm.flat_allreduce_time(dom_ib, grad_bytes)
print(f"gradient all-reduce over 128 replicas: RDMA-flat {t_ib*1e3:.2f} ms "
      f"vs ScalePool-hierarchical {t_sp*1e3:.2f} ms "
      f"({t_ib/t_sp:.1f}x)")

"""Explore ScalePool fabric topologies (paper §4, Figure 4a): compare
Clos / 3D-torus / DragonFly CXL fabrics and cluster counts on collective
cost, and reproduce the hybrid-fabric speedup sweep.

    PYTHONPATH=src python examples/fabric_explorer.py
"""

from repro.core import costmodel as cm
from repro.core import fabric as fb
from repro.core.fabric import TopologyKind
from repro.core.simulator import (Calibration, FIG6_WORKLOADS, make_system,
                                  simulate_step)

GB = 1 << 30

print("== CXL fabric topology sweep (1024 endpoints, 1GiB all-reduce over 16 clusters) ==")
for kind in TopologyKind:
    if kind == TopologyKind.SINGLE_HOP:
        continue
    f = fb.cxl_fabric(1024, kind=kind)
    t = cm.allreduce_time(f, GB, 16)
    print(f"{kind.value:18s} hops={f.topology.hops()} "
          f"latency={f.latency()*1e6:.2f}us  allreduce_1GiB={t*1e3:.1f}ms")

print("\n== hybrid-fabric speedup per workload (paper Fig. 6) ==")
import dataclasses
for w in FIG6_WORKLOADS:
    calib = dataclasses.replace(Calibration(), ib_load=w.ib_load,
                                cxl_load=w.cxl_load)
    base = simulate_step(w.model, w.par,
                         make_system("baseline", w.par.n_gpus, calib))
    sp = simulate_step(w.model, w.par,
                       make_system("scalepool", w.par.n_gpus, calib))
    print(f"{w.model.name:10s} {base.total/sp.total:.3f}x "
          f"(comm {base.comm_inter_raw:.3f}s -> {sp.comm_inter_raw:.3f}s)")

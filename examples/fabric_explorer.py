"""Explore ScalePool fabric topologies (paper §4, Figure 4a): compare
Clos / 3D-torus / DragonFly CXL fabrics and cluster counts on collective
cost, reproduce the hybrid-fabric speedup sweep, walk routed paths over
the estate graph, and trace two tenants contending on one tier-2 trunk.

    PYTHONPATH=src python examples/fabric_explorer.py
"""

from repro.core import costmodel as cm
from repro.core import fabric as fb
from repro.core.fabric import TopologyKind
from repro.core.simulator import (Calibration, FIG6_WORKLOADS, make_system,
                                  simulate_step)

GB = 1 << 30

print("== CXL fabric topology sweep (1024 endpoints, 1GiB all-reduce over 16 clusters) ==")
for kind in TopologyKind:
    if kind == TopologyKind.SINGLE_HOP:
        continue
    f = fb.cxl_fabric(1024, kind=kind)
    t = cm.allreduce_time(f, GB, 16)
    print(f"{kind.value:18s} hops={f.topology.hops()} "
          f"latency={f.latency()*1e6:.2f}us  allreduce_1GiB={t*1e3:.1f}ms")

print("\n== hybrid-fabric speedup per workload (paper Fig. 6) ==")
import dataclasses
for w in FIG6_WORKLOADS:
    calib = dataclasses.replace(Calibration(), ib_load=w.ib_load,
                                cxl_load=w.cxl_load)
    base = simulate_step(w.model, w.par,
                         make_system("baseline", w.par.n_gpus, calib))
    sp = simulate_step(w.model, w.par,
                       make_system("scalepool", w.par.n_gpus, calib))
    print(f"{w.model.name:10s} {base.total/sp.total:.3f}x "
          f"(comm {base.comm_inter_raw:.3f}s -> {sp.comm_inter_raw:.3f}s)")

# ---------------------------------------------------------------------------
# routed estate graph: where a transfer actually goes (repro.fabric)
# ---------------------------------------------------------------------------
from repro.fabric import Topology, Transport
from repro.pool import build_inventory

inv = build_inventory(n_pods=4, pod_size=8, n_memory_nodes=2,
                      memory_node_gb=1024.0)
topo = Topology.from_inventory(inv, accels=True)
print(f"\n== routed estate graph: {topo.describe()} ==")
for src, dst in [("accel:0.3", "mem:0"), ("pod:1", "mem:1"),
                 ("pod:0", "pod:3")]:
    r = topo.route(src, dst)
    hops = " -> ".join([r.src] + [l.dst for l in r.links])
    print(f"{src:>10s} -> {dst:<6s} {hops}")
    print(f"{'':>21s}lat={r.latency()*1e6:.2f}us "
          f"bottleneck={r.bandwidth():.0f}GB/s "
          f"64MiB={r.transfer_time(64 * (1 << 20))*1e3:.2f}ms")

# collectives can be priced on a route instead of a whole FabricSpec
r03 = topo.route("pod:0", "pod:3")
print(f"allreduce 1GiB over 4 pods on that route: "
      f"{cm.allreduce_time(r03, GB, 4)*1e3:.1f}ms")

# ---------------------------------------------------------------------------
# two tenants contending on one capacity trunk (the fig10 mechanism)
# ---------------------------------------------------------------------------
print("\n== two-tenant contention timeline (shared tier-2 trunk) ==")
tx = Transport(topo)
ra = topo.route("pod:0", "mem:0")
rb = topo.route("pod:1", "mem:0")       # same memory node: shared trunk+port
nbytes = 256 * (1 << 20)
solo = ra.transfer_time(nbytes)
done_a = tx.begin_transfer(ra, nbytes, 0.0)
print(f"t=0.000s tenant A begins 256MiB  -> solo ETA {done_a*1e3:.2f}ms "
      f"(estimate at begin time; B's arrival will stretch the reality)")
t_b = solo / 2
done_b = tx.begin_transfer(rb, nbytes, t_b)
print(f"t={t_b*1e3:.2f}ms tenant B begins 256MiB -> completes at "
      f"{done_b*1e3:.2f}ms ({(done_b - t_b)/solo:.2f}x its solo time; "
      f"fair-shared with A's residual)")
late = tx.begin_transfer(rb, nbytes, 2 * done_b)
print(f"t={2*done_b*1e3:.2f}ms idle trunk: B again -> "
      f"{(late - 2*done_b)*1e3:.2f}ms = solo ETA again")
print(f"transport: {tx.stats()}")

"""Walk through the repro.pool orchestrator: build a composable estate,
take leases, schedule a contended job mix under both resource-composition
policies, and materialize a lease into a runnable JAX mesh + tiering
policy (the paper's composable-disaggregation pillar, end to end).

    PYTHONPATH=src python examples/pool_demo.py
"""

import dataclasses

from repro.core import simulator as sim
from repro.pool import (PoolJob, ResourcePool, Scheduler, build_inventory,
                        offload_bytes, smoke_pool)

GB = 1e9

# ---------------------------------------------------------------------------
# 1. the estate: XLink pods + CXL fabric + tier-2 memory nodes
# ---------------------------------------------------------------------------
inv = build_inventory(n_pods=4, pod_size=72, n_memory_nodes=8,
                      memory_node_gb=4096, interconnect="scalepool")
print("estate:", inv.describe())
print(f"pods per CXL leaf switch: {inv.pods_per_leaf}; "
      f"hops pod0->pod1: {inv.pod_hops(0, 1)}")

# ---------------------------------------------------------------------------
# 2. composable allocation: accels + tier-2 capacity, independently
# ---------------------------------------------------------------------------
pool = ResourcePool(inv)
train = pool.lease("train-gpt", 128, tier2_gb=2800, tier2_gbps=200,
                   model_parallel=8)
serve = pool.lease("serve-qwen", 16, tier2_gb=512, kv_gb=128, tier2_gbps=50)
print(f"\ntrain lease: {train.n_accels} accels over pods "
      f"{list(train.allocation.pod_ids)} + "
      f"{train.tier2_bytes / GB:.0f}GB tier-2 @ {train.tier2_bw / GB:.0f}GB/s "
      f"-> {train.tiering_policy()}")
print(f"serve lease: {serve.n_accels} accels + {serve.kv_bytes / GB:.0f}GB KV "
      f"grant -> {serve.tiering_policy()}")
m = pool.metrics()
print(f"pool: utilization={m.utilization:.0%} stranded={m.stranded_frac:.0%} "
      f"tier2 reserved={m.tier2_reserved / GB:.0f}GB "
      f"({m.tier2_kv_reserved / GB:.0f}GB KV), "
      f"tier2 bw {m.tier2_bw_reserved / GB:.0f}/{m.tier2_bw_total / GB:.0f}GB/s")

# elastic grow with a checkpoint re-sharding plan (ckpt.elastic)
train, plan = pool.resize("train-gpt", 256)
print(f"grown to {train.n_accels} accels; restore plan: {plan}")
for name in ("train-gpt", "serve-qwen"):
    pool.release(name)

# ---------------------------------------------------------------------------
# 3. multi-job scheduling: static partitioning vs composable pooling
# ---------------------------------------------------------------------------
print("\n== contended job mix (runtimes from the paper's §6 cost models) ==")
calib = sim.Calibration()
jobs = lambda: [
    PoolJob("gopher-0", sim.GOPHER,
            sim.ParallelismConfig(tp=8, pp=4, dp=2, global_batch_seqs=256),
            n_steps=25, tier2_bytes=offload_bytes(sim.GOPHER, calib)),
    PoolJob("gopher-1", sim.GOPHER,
            sim.ParallelismConfig(tp=8, pp=4, dp=2, global_batch_seqs=256),
            n_steps=25, tier2_bytes=offload_bytes(sim.GOPHER, calib)),
    PoolJob("meg-0", sim.MEGATRON,
            sim.ParallelismConfig(tp=8, pp=1, dp=8, global_batch_seqs=512),
            n_steps=60, submit_t=1.0, elastic=True, min_dp=2),
]
for policy in ("baseline", "scalepool"):
    sched = Scheduler(build_inventory(
        n_pods=4, pod_size=72, n_memory_nodes=(8 if policy == "scalepool" else 0),
        memory_node_gb=4096, interconnect=policy), policy)
    for j in jobs():
        sched.submit(j)
    res = sched.run()
    s = res.summary()
    print(f"{policy:10s} util={s['utilization']:.2f} "
          f"stranded={s['stranded_frac']:.2f} jct={s['mean_jct']:.0f}s "
          f"qdelay={s['mean_queue_delay']:.0f}s")

# ---------------------------------------------------------------------------
# 4. a lease drives the actual runtime (CPU-sized pool)
# ---------------------------------------------------------------------------
print("\n== lease -> jax mesh + TieringPolicy ==")
cpu_pool = smoke_pool()
lease = cpu_pool.lease("demo", 8, tier2_gb=64, model_parallel=2)
mesh, policy = lease.materialize()
print(f"mesh axes={dict(zip(mesh.axis_names, mesh.devices.shape))} "
      f"policy={policy}")
print("run a full train step against it with: "
      "PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b "
      "--smoke --pool scalepool --pool-accels 8 --pool-tier2-gb 64")

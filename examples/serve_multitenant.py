"""Multi-tenant serving quickstart — two engines, ONE lease, ONE
physical KV page pool (paper's composability at serving granularity).

The pool grants a single lease whose KV bytes are shared by both
tenants; a ``PoolArbiter`` owns the hot tier-1 pages and hands each
tenant a *revocable max-min fair share* (work-conserving: an idle
tenant's pages are borrowable; a bursting tenant claws its share back,
with the swap clocks charged to the hog, not the burster).

    PYTHONPATH=src python examples/serve_multitenant.py
"""

import dataclasses

from repro.configs import get_config
from repro.models.api import build_model
from repro.pool import smoke_pool
from repro.serve import (Engine, EngineConfig, PoolArbiter, burst_trace,
                         latency_summary, run_multi_trace)

cfg = get_config("qwen1.5-0.5b", smoke=True)
model = build_model(cfg)

# ---------------------------------------------------------------------------
# 1. one lease, two named tenants: the allocator grants ONE shared
#    kv_gb pool; kv_share() is each tenant's static slice of the cold
#    tier-2 bytes (hot pages stay dynamic, see below)
# ---------------------------------------------------------------------------
pool = smoke_pool("scalepool")
lease = pool.lease("svc", 4, tier2_gb=64, kv_gb=2.0,
                   tenants=("chat", "batch"))
print(f"lease: {lease.n_accels} accels, {lease.kv_bytes / 1e9:.0f}GB shared "
      f"KV grant, tenants={lease.tenants}")
print(f"per-tenant cold budget: "
      f"{lease.kv_share('chat').tier2_bytes / 1e9:.0f}GB")

# ---------------------------------------------------------------------------
# 2. the arbiter owns the physical page pool; each tenant engine joins
#    it (first registration fixes the pool's cache geometry)
# ---------------------------------------------------------------------------
ecfg = EngineConfig(max_slots=4, max_seq=96, page_size=16)
arb = PoolArbiter(tier1_pages=12, page_size=16)
chat = Engine.from_lease(model, lease, ecfg, arbiter=arb, tenant="chat")
batch = Engine.from_lease(model, lease, ecfg, arbiter=arb, tenant="batch")

# skewed traffic: "batch" floods from t=0, "chat" bursts in later —
# exactly the shape a static 1/N partition handles worst
flood = burst_trace(8, prompt_len=32, max_new_tokens=32, vocab=cfg.vocab,
                    seed=0)
burst = [dataclasses.replace(r, arrival_time=2e-4)
         for r in burst_trace(3, prompt_len=32, max_new_tokens=16,
                              vocab=cfg.vocab, seed=1)]

h_batch, h_chat = run_multi_trace([(batch, flood), (chat, burst)])
print(f"\nbatch tenant: {latency_summary(h_batch)}")
print(f"chat  tenant: {latency_summary(h_chat)}")

# ---------------------------------------------------------------------------
# 3. what the arbiter did: while "chat" was idle, "batch" borrowed its
#    pages (work conservation); when the chat burst arrived, the
#    arbiter revoked the coldest of batch's paused pages — the swap
#    seconds were charged to BATCH's clock (it was over share), and
#    chat's latency stayed at its guaranteed-slice level
# ---------------------------------------------------------------------------
s = arb.stats()
print(f"\nrevocations: {s['revocations']} episodes, "
      f"{s['revoked_pages']} pages")
for name, t in s["tenants"].items():
    print(f"  {name}: hot={t['hot_used']} share={t['share']} "
          f"allowance={t['allowance']} spills={t['spills']} "
          f"charged={t['revocation_charged_s'] * 1e6:.1f}us")

"""End-to-end training example (deliverable b): trains a ~100M-param
dense LM for a few hundred steps through the full production stack
(data pipeline, sharded train step, AdamW, async checkpoints, fault-
tolerant loop).  The default invocation is CPU-sized; pass --full for
the 100M/300-step configuration on real hardware.

    PYTHONPATH=src python examples/train_e2e.py            # ~25M, 60 steps
    PYTHONPATH=src python examples/train_e2e.py --full     # ~100M, 300 steps
"""

import argparse
import sys

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    if args.full:
        # ~100M params: qwen1.5-0.5b body at reduced depth via smoke=False
        # would be 600M; olmo-1b smoke-up: use the real qwen1.5-0.5b with
        # short sequences for a laptop-scale run.
        argv = ["--arch", "qwen1.5-0.5b", "--steps",
                str(args.steps or 300), "--batch", "8", "--seq", "256",
                "--lr", "3e-4", "--ckpt-every", "100"]
    else:
        argv = ["--arch", "olmo-1b", "--smoke", "--steps",
                str(args.steps or 60), "--batch", "8", "--seq", "128",
                "--lr", "1e-3", "--ckpt-every", "25"]
    return train_cli.main(argv)


if __name__ == "__main__":
    sys.exit(main())

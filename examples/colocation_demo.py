"""Train+serve co-residency on one contended estate (repro.colo).

Walks the fig11 scenario end to end: place a serving job and a training
gang on a 6-pod XLink-CXL estate under hop-only vs contention-aware
placement, co-run them on ONE shared ``fabric.Transport`` with the
clock-interleaved driver, and read the joint frontier (training step
time vs serving p95) plus the per-label link attribution that explains
it.

    PYTHONPATH=src python examples/colocation_demo.py
"""

import dataclasses

import jax

from repro.colo import TrainActor, job_routes, run_colo
from repro.configs import SMOKE_ARCHS
from repro.core import fabric as fb
from repro.core import simulator as sim
from repro.core.tiering import KVBudget
from repro.fabric import Topology, Transport
from repro.models.api import build_model
from repro.obs import link_report
from repro.pool import build_inventory
from repro.pool.allocator import Allocator, JobRequest
from repro.serve import (Engine, EngineConfig, ServeCostModel, burst_trace,
                         latency_summary)

# ---------------------------------------------------------------------------
# the estate: 6 XLink pods over 3 CXL leaves, 2 tier-2 nodes, one trunk
# ---------------------------------------------------------------------------
inv = build_inventory(n_pods=6, pod_size=5, hbm_per_accel_gb=64.0,
                      n_memory_nodes=2, memory_node_gb=64.0,
                      interconnect="scalepool")
inter = inv.inter_fabric
inter = dataclasses.replace(
    inter, topology=dataclasses.replace(
        inter.topology, switch=dataclasses.replace(
            inter.topology.switch, radix=4)))   # 2 pods per leaf
inv = dataclasses.replace(inv, inter_fabric=inter)
print(f"== estate: {inv.describe()} "
      f"({inv.pods_per_leaf} pods/leaf) ==")

# ---------------------------------------------------------------------------
# placement: serving first, then the 8-accel training gang, both policies
# ---------------------------------------------------------------------------
placements = {}
for policy in ("scalepool", "contention"):
    alloc = Allocator(inv, policy)
    svc = alloc.allocate(JobRequest("svc", 1, tier2_bytes=8e9, kv_bytes=1e9))
    trn = alloc.allocate(JobRequest("train", 8, tier2_bytes=16e9))
    placements[policy] = (svc.pod_ids, sorted(svc.tier2),
                          trn.pod_ids, sorted(trn.tier2))
    print(f"{policy:10s} svc pods={svc.pod_ids} mem={sorted(svc.tier2)}  "
          f"train pods={trn.pod_ids} mem={sorted(trn.tier2)}")

# ---------------------------------------------------------------------------
# co-run both placements on the priced estate graph
# ---------------------------------------------------------------------------
mcfg = SMOKE_ARCHS["qwen1.5-0.5b"]
model = build_model(mcfg)
params = model.init(jax.random.PRNGKey(0))
cm = ServeCostModel.from_fabric(2.0 * 1e9)
calib = dataclasses.replace(sim.Calibration(), cluster_size=5)
bd = sim.simulate_step(
    sim.LLMConfig("demo-13b", 40, 5120, 40, 4 * 5120, 50257, 2048, 13e9),
    sim.ParallelismConfig(tp=1, pp=1, dp=8, global_batch_seqs=8),
    sim.make_system("scalepool", 10, calib))


def pricing_topology(bw=1e5):
    lat = fb.tier2_memory_fabric(8).latency()
    topo = Topology("demo")
    topo.add_node("spine", "switch")
    topo.add_node("t2sw", "switch")
    topo.connect("spine", "t2sw", fb.CXL_CAPACITY, capacity=1.6 * bw,
                 latency=lat / 4)
    for leaf in range(3):
        topo.add_node(f"leaf:{leaf}", "switch")
        topo.connect(f"leaf:{leaf}", "spine", fb.CXL3, capacity=1.2 * bw,
                     latency=lat / 4)
    for pid in range(6):
        topo.add_node(f"pod:{pid}", "pod")
        topo.connect(f"pod:{pid}", f"leaf:{inv.leaf_of(pid)}", fb.CXL3,
                     capacity=8 * bw, latency=lat / 4)
    for node in range(2):
        topo.add_node(f"mem:{node}", "memory")
        topo.connect("t2sw", f"mem:{node}", fb.CXL_CAPACITY, capacity=bw,
                     latency=lat / 4)
    return topo


print(f"\ntraining step (closed form): {bd.total * 1e3:.1f}ms "
      f"(dp exposed {bd.comm_dp_exposed * 1e3:.1f}ms, "
      f"offload {bd.offload * 1e3:.1f}ms)")
print("\n== co-residency: joint frontier under each placement ==")
for policy, (svc_pods, svc_mems, trn_pods, trn_mems) in placements.items():
    topo = pricing_topology()
    tx = Transport(topo)
    route = topo.route(f"pod:{svc_pods[0]}", f"mem:{svc_mems[0]}")
    engines = {t: Engine.local(model, EngineConfig(max_slots=4, max_seq=96,
                                                   page_size=16),
                               params=params, budget=KVBudget(12, 1e9, 16),
                               cost_model=cm, transport=tx, route=route,
                               tenant=t)
               for t in ("a", "b")}
    traces = {t: burst_trace(4, prompt_len=24, max_new_tokens=64,
                             vocab=mcfg.vocab, seed=i)
              for i, t in enumerate(("a", "b"))}
    actor = TrainActor("job0", bd, tx,
                       job_routes(topo, trn_pods, trn_mems), n_steps=6)
    res = run_colo([(engines[t], traces[t]) for t in ("a", "b")], [actor])
    tx.quiesce()
    p95 = latency_summary([h for hs in res.serve_handles for h in hs])["p95_s"]
    st = res.train_stats()["job0"]
    print(f"\n{policy:10s} train step avg={st['step_s_avg']*1e3:7.1f}ms "
          f"(stretch {st['stretch_s']*1e3:6.1f}ms)   "
          f"serving p95={p95*1e3:7.1f}ms")
    trunk = link_report(tx)["spine->t2sw"]
    shares = ", ".join(f"{lbl}={b/1e6:.2f}MB"
                       for lbl, b in sorted(trunk["by_label"].items(),
                                            key=lambda kv: -kv[1]))
    print(f"{'':10s} trunk spine->t2sw carried: {shares}")

print("\ncontention-aware placement keeps the gang off the serving leaf: "
      "both jobs get faster, and the only shared link left is the trunk.")

"""Paged decode-attention Pallas TPU kernel.

Decode-time attention where each sequence's K/V lives in fixed-size
pages scattered across a shared device-side page pool (the vLLM /
PagedAttention layout, realized on the paper's tier-1 HBM pool): a
per-sequence page table maps logical page ``i`` to a physical page id,
and the kernel gathers K/V pages *through the table* — no contiguity
and no per-sequence slab reservation.  This is the kernel that lets
``repro.serve`` drop the whole-sequence-resident requirement.

Layouts (kernel-native):
  q            (B, H, D)        one query token per sequence
  k/v pages    (P, ps, KV, D)   the shared pool; P physical pages of
                                ``ps`` tokens each (pool row P-1 may be
                                a scratch/trash page — the kernel never
                                reads positions >= lengths[b])
  page_table   (B, PMAX) int32  logical -> physical page ids; entries
                                past a sequence's live pages must still
                                be *valid* pool indices (point them at
                                the trash page)
  lengths      (B,) int32       valid KV tokens per sequence (0 for an
                                idle row: output is all-zeros)
  out          (B, H, D)

Grid: (B, KV-heads, PMAX) with the page dimension sequential
("arbitrary") — online-softmax state persists across pages in fp32
VMEM scratch exactly like the flash kernel.  The page table and the
lengths ride in as scalar-prefetch operands so the K/V BlockSpec index
maps can resolve the physical page id before the body runs (one DMA
per logical page, skipped pages cost a no-op body via ``pl.when``).

GQA is native: the H query heads are blocked per KV head (group G =
H // KV), so K/V is never replicated in HBM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, sm_scale: float, page_size: int,
            n_pages_max: int, sliding_window: Optional[int]):
    b = pl.program_id(0)
    j = pl.program_id(2)                       # logical page (sequential)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    @pl.when(j * page_size < length)           # page holds live tokens
    def _update():
        q = q_ref[0].astype(jnp.float32)       # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (ps, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                       # (G, ps)

        pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < length
        if sliding_window is not None:
            # the (single) query sits at absolute position length - 1
            mask &= pos > (length - 1 - sliding_window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                    # (G,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)            # fully-masked cols stay dead

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(j == n_pages_max - 1)
    def _finish():
        # length == 0 rows never update: l == 0 -> output exactly 0
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           lengths: jax.Array, *,
                           sm_scale: Optional[float] = None,
                           sliding_window: Optional[int] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """q (B,H,D); k/v pages (P,ps,KV,D); page_table (B,PMAX) int32;
    lengths (B,) int32 -> (B,H,D)."""
    B, H, D = q.shape
    P, ps, KV, _ = k_pages.shape
    PMAX = page_table.shape[1]
    assert H % KV == 0, (H, KV)
    assert v_pages.shape == k_pages.shape
    assert page_table.shape[0] == B and lengths.shape == (B,)
    G = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _kernel, sm_scale=sm_scale, page_size=ps, n_pages_max=PMAX,
        sliding_window=sliding_window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # page_table, lengths
        grid=(B, KV, PMAX),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, h, j, pt, ln: (b, h, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, h, j, pt, ln: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, lengths, q, k_pages, v_pages)

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  sliding_window: Optional[int] = None,
                  sm_scale: Optional[float] = None) -> jax.Array:
    """q: (B,H,Sq,D); k,v: (B,HKV,Skv,D) -> (B,H,Sq,D), fp32 math."""
    B, H, Sq, D = q.shape
    HKV, Skv = k.shape[1], k.shape[2]
    group = H // HKV
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * sm_scale
    q_idx = jnp.arange(Sq)[:, None]
    k_idx = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_idx <= q_idx
    if sliding_window is not None:
        mask &= k_idx > (q_idx - sliding_window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths, *,
                        sm_scale: Optional[float] = None,
                        sliding_window: Optional[int] = None) -> jax.Array:
    """Dense-gather oracle for the paged decode-attention kernel.

    q: (B,H,D); k/v pages: (P,ps,KV,D); page_table: (B,PMAX) int32;
    lengths: (B,) int32 -> (B,H,D), fp32 math.  Rows with length 0
    return exact zeros (the kernel's idle-slot contract).
    """
    B, H, D = q.shape
    P, ps, KV, _ = k_pages.shape
    PMAX = page_table.shape[1]
    G = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    k = k_pages[page_table].reshape(B, PMAX * ps, KV, D)   # logical order
    v = v_pages[page_table].reshape(B, PMAX * ps, KV, D)
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(jnp.float32)) * sm_scale
    pos = jnp.arange(PMAX * ps)[None, :]
    mask = pos < lengths[:, None]
    if sliding_window is not None:
        mask &= pos > (lengths[:, None] - 1 - sliding_window)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    out = jnp.where((lengths > 0)[:, None, None, None], out, 0.0)
    return out.reshape(B, H, D).astype(q.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def ssd_ref(x, dt, A, B_mat, C_mat, D, *, init_state=None):
    """Sequential (token-by-token) SSD recurrence — the ground truth.

    x: (B,S,H,P); dt: (B,S,H); A: (H,); B_mat/C_mat: (B,S,G,N); D: (H,).
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    HG = H // G
    f32 = jnp.float32
    state = (jnp.zeros((Bsz, H, P, N), f32) if init_state is None
             else init_state.astype(f32))

    def step(state, t):
        xt = x[:, t].astype(f32)                    # (B,H,P)
        dtt = dt[:, t].astype(f32)                  # (B,H)
        Bt = jnp.repeat(B_mat[:, t].astype(f32), HG, axis=1)  # (B,H,N)
        Ct = jnp.repeat(C_mat[:, t].astype(f32), HG, axis=1)
        decay = jnp.exp(dtt * A.astype(f32))
        incr = (dtt[..., None] * xt)[..., None] * Bt[:, :, None, :]
        state = decay[..., None, None] * state + incr
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        y = y + D.astype(f32)[None, :, None] * xt
        return state, y

    state, ys = jax.lax.scan(step, state, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1)                      # (B,S,H,P)
    return y.astype(x.dtype), state

"""Fused RMSNorm Pallas kernel (memory-bound fusion: one HBM round trip
instead of three).  Rows are tiled into VMEM blocks; the feature dim is
kept whole (d_model ≤ ~16k fits VMEM comfortably at fp32)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)              # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256,
            interpret: Optional[bool] = None) -> jax.Array:
    """x: (..., d); scale: (d,)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)

    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n_blocks = x2.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)

"""jit'd public wrappers around the Pallas kernels, with model-layout
adapters ((B,S,H,D) <-> kernel layouts), padding to block multiples, and
automatic interpret-mode on non-TPU backends."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd


@functools.partial(jax.jit, static_argnames=("causal", "sliding_window",
                                             "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    sliding_window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Model layout: q (B,Sq,H,D); k,v (B,Skv,HKV,D) -> (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    qk = jnp.moveaxis(q, 1, 2)
    kk = jnp.moveaxis(k, 1, 2)
    vk = jnp.moveaxis(v, 1, 2)
    bq = min(block_q, max(16, Sq))
    bk = min(block_k, max(16, Skv))
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    if pad_q:
        qk = jnp.pad(qk, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kk = jnp.pad(kk, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vk = jnp.pad(vk, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        # padded keys must never win the softmax: causal masking already
        # excludes them for q_idx < Skv; for padded q rows it's irrelevant.
        if not causal:
            raise NotImplementedError("non-causal padding needs kv_len mask")
    out = _fa.flash_attention(qk, kk, vk, causal=causal,
                              sliding_window=sliding_window,
                              sm_scale=1.0 / (D ** 0.5),
                              block_q=bq, block_k=bk)
    if pad_q:
        out = out[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("sliding_window",))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, lengths: jax.Array, *,
                    sliding_window: Optional[int] = None) -> jax.Array:
    """Model layout: q (B,1,H,D) single decode token per sequence;
    k/v pages (P,ps,KV,D); page_table (B,PMAX); lengths (B,) valid KV
    tokens (including the just-written one) -> (B,1,H,D)."""
    B, S, H, D = q.shape
    assert S == 1, "paged attention is a decode (one-query) kernel"
    out = _pa.paged_decode_attention(
        q[:, 0], k_pages, v_pages, page_table, lengths,
        sm_scale=1.0 / (D ** 0.5), sliding_window=sliding_window)
    return out[:, None]


rmsnorm = jax.jit(_rn.rmsnorm, static_argnames=("eps", "block_rows"))


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B_mat, C_mat, D, *, chunk: int = 128,
             init_state=None) -> Tuple[jax.Array, jax.Array]:
    return _ssd.ssd_scan(x, dt, A, B_mat, C_mat, D, chunk=chunk,
                         init_state=init_state)

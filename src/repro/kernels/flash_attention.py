"""Blocked (flash) attention Pallas TPU kernel.

TPU-native adaptation: q/k tiles sized for VMEM, MXU-aligned (multiples
of 128 on the contracted dims), online-softmax accumulation in fp32
scratch that persists across the sequential KV grid dimension.  Supports
causal masking, sliding windows (mixtral) and GQA head mapping directly
in the index maps (no KV replication in HBM).

Layout: q (B, H, Sq, D); k, v (B, HKV, Skv, D); out (B, H, Sq, D).
Grid: (B, H, Sq/bq, Skv/bk) with the KV dim sequential ("arbitrary").
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            sm_scale: float, causal: bool, block_q: int, block_k: int,
            sliding_window: Optional[int], n_kv_blocks: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                                # (bq, bk)

    q_idx = pl.program_id(2) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_idx = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= k_idx <= q_idx
    if sliding_window is not None:
        mask &= k_idx > (q_idx - sliding_window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                             # (bq,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    # fully-masked rows: p underflows to exp(NEG_INF - NEG_INF) = 1; kill
    p = jnp.where(mask, p, 0.0)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(j == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    sliding_window: Optional[int] = None,
                    sm_scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, HKV, Skv, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    _, HKV, Skv, _ = k.shape
    assert H % HKV == 0
    group = H // HKV
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (
        "pad sequences to block multiples in ops.flash_attention")
    n_kv_blocks = Skv // block_k

    kernel = functools.partial(
        _kernel, sm_scale=sm_scale, causal=causal, block_q=block_q,
        block_k=block_k, sliding_window=sliding_window,
        n_kv_blocks=n_kv_blocks)

    return pl.pallas_call(
        kernel,
        grid=(B, H, Sq // block_q, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)

"""Mamba2 SSD chunked-scan Pallas TPU kernel.

TPU adaptation of the paper's (GPU) SSD algorithm: instead of a warp-level
scan, the recurrence is blocked into chunks of Q tokens; each grid step
processes one (batch, head, chunk) cell entirely in VMEM:

  * intra-chunk quadratic term: (Q,Q) masked decay x (C·B^T) — MXU matmuls;
  * the (P,N) recurrent state lives in an fp32 VMEM scratch that persists
    across the sequential chunk dimension (dimension_semantics arbitrary);
  * per-chunk state update is a rank-Q matmul.

Grid: (B, H, nc); chunk dim sequential.  One head per program keeps the
working set at Q*P + Q*N + Q*Q + P*N fp32 ≈ 200 KB for Q=128, P=64,
N=128 — comfortably inside a v5e core's 128 MB VMEM budget with double
buffering.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
            y_ref, hout_ref, state_ref, *, Q: int, n_chunks: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)          # (Q,)
    A = a_ref[0]                                    # ()
    Bm = b_ref[0, 0].astype(jnp.float32)           # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)           # (Q, N)
    D = d_ref[0]                                    # ()

    a = dt * A                                      # (Q,)
    cum = jnp.cumsum(a)                             # (Q,)
    dtx = x * dt[:, None]                           # (Q, P)

    # intra-chunk: scores (Q,Q) on the MXU, masked exponential decay
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    diff = cum[:, None] - cum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.exp(jnp.where(ki <= qi, diff, -jnp.inf))
    y_diag = jax.lax.dot_general(scores * decay, dtx,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # inter-chunk: read previous state, emit, then update
    h_prev = state_ref[...]                         # (P, N)
    y_off = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (Q, P)

    w = jnp.exp(cum[-1] - cum)                      # (Q,)
    # state increment: (P, N) = dtx^T @ (w * B)
    incr = jax.lax.dot_general(dtx, w[:, None] * Bm,
                               (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(jnp.sum(a)) * h_prev + incr

    y = y_diag + y_off + D * x
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(c_idx == n_chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = state_ref[...].astype(hout_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B_mat: jax.Array,
             C_mat: jax.Array, D: jax.Array, *, chunk: int = 128,
             init_state: Optional[jax.Array] = None,
             interpret: Optional[bool] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,H,P); dt: (B,S,H); A,D: (H,); B_mat/C_mat: (B,S,G,N).
    Returns (y: (B,S,H,P), final_state: (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    HG = H // G
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    # kernel-friendly layouts
    xk = jnp.moveaxis(x, 2, 1)                      # (B,H,Sp,P)
    dtk = jnp.moveaxis(dt, 2, 1)                    # (B,H,Sp)
    bk = jnp.moveaxis(B_mat, 2, 1)                  # (B,G,Sp,N)
    ck = jnp.moveaxis(C_mat, 2, 1)

    kernel = functools.partial(_kernel, Q=Q, n_chunks=nc)
    y, hout = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h // HG, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h // HG, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, Sp, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xk, dtk, A.astype(jnp.float32), bk, ck, D.astype(jnp.float32),
      init_state)

    y = jnp.moveaxis(y, 1, 2)[:, :S]                # (B,S,H,P)
    return y, hout

"""Uniform model API over all families.

``build_model(cfg)`` returns a ``Model`` whose members are plain
functions, suitable for jax.jit / AOT lowering:

    model.init(rng)                      -> params
    model.param_axes()                   -> logical-axis pytree (matches params)
    model.loss(params, batch)            -> scalar
    model.init_cache(batch, max_seq)     -> cache pytree
    model.cache_axes()                   -> logical-axis pytree for the cache
    model.prefill(params, batch, cache)  -> (logits, cache[, enc_states])
    model.decode(params, tokens, cache, index[, enc_states]) -> (logits, cache)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, mamba2, moe, transformer
from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    param_axes: Callable[[], Any]
    loss: Callable[..., jax.Array]
    init_cache: Callable[..., Any]
    cache_axes: Callable[[], Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    # paged-KV serving surface (attention-KV families only; None for
    # recurrent-state families whose O(1) cache has nothing to page):
    #   prefill_at(params, batch, cache, last_pos) -> (logits, cache)
    #     bucketed prefill — logits at the last *real* position of a
    #     right-padded prompt
    #   decode_paged(params, tokens, pools, page_table, lengths)
    #     -> (logits, pools) — decode over a shared physical page pool
    prefill_at: Optional[Callable[..., Any]] = None
    decode_paged: Optional[Callable[..., Any]] = None

    @property
    def supports_paged_kv(self) -> bool:
        return self.decode_paged is not None


def build_model(cfg: ModelConfig, *, moe_groups: int = 1) -> Model:
    if cfg.family == "dense":
        m = transformer
        return Model(
            cfg=cfg,
            init=lambda rng: m.init_params(rng, cfg),
            param_axes=lambda: m.param_axes(cfg),
            loss=lambda p, b, **kw: m.loss_fn(p, cfg, b, **kw),
            init_cache=lambda b, s, **kw: m.init_cache(cfg, b, s, **kw),
            cache_axes=lambda: m.cache_axes(),
            prefill=lambda p, b, c: m.prefill(p, cfg, b, c),
            decode=lambda p, t, c, i: m.decode_step(p, cfg, t, c, i),
            prefill_at=lambda p, b, c, lp: m.prefill_at(p, cfg, b, c, lp),
            decode_paged=lambda p, t, pl, pt, ln: m.decode_paged(
                p, cfg, t, pl, pt, ln),
        )
    if cfg.family == "moe":
        m = moe
        return Model(
            cfg=cfg,
            init=lambda rng: m.init_params(rng, cfg),
            param_axes=lambda: m.param_axes(cfg),
            loss=lambda p, b, **kw: m.loss_fn(p, cfg, b, groups=moe_groups, **kw),
            init_cache=lambda b, s, **kw: m.init_cache(cfg, b, s, **kw),
            cache_axes=lambda: m.cache_axes(),
            prefill=lambda p, b, c: m.prefill(p, cfg, b, c, groups=moe_groups),
            decode=lambda p, t, c, i: m.decode_step(p, cfg, t, c, i,
                                                    groups=moe_groups),
            prefill_at=lambda p, b, c, lp: m.prefill_at(p, cfg, b, c, lp,
                                                        groups=moe_groups),
            decode_paged=lambda p, t, pl, pt, ln: m.decode_paged(
                p, cfg, t, pl, pt, ln, groups=moe_groups),
        )
    if cfg.family == "ssm":
        m = mamba2
        return Model(
            cfg=cfg,
            init=lambda rng: m.init_params(rng, cfg),
            param_axes=lambda: m.param_axes(cfg),
            loss=lambda p, b, **kw: m.loss_fn(p, cfg, b, **kw),
            init_cache=lambda b, s, **kw: m.init_cache(cfg, b, s, **kw),
            cache_axes=lambda: m.cache_axes(),
            prefill=lambda p, b, c: m.prefill(p, cfg, b, c),
            decode=lambda p, t, c, i: m.decode_step(p, cfg, t, c, i),
        )
    if cfg.family == "hybrid":
        m = hybrid
        return Model(
            cfg=cfg,
            init=lambda rng: m.init_params(rng, cfg),
            param_axes=lambda: m.param_axes(cfg),
            loss=lambda p, b, **kw: m.loss_fn(p, cfg, b, **kw),
            init_cache=lambda b, s, **kw: m.init_cache(cfg, b, s, **kw),
            cache_axes=lambda: m.cache_axes(cfg),
            prefill=lambda p, b, c: m.prefill(p, cfg, b, c),
            decode=lambda p, t, c, i: m.decode_step(p, cfg, t, c, i),
        )
    if cfg.family == "encdec":
        m = encdec
        return Model(
            cfg=cfg,
            init=lambda rng: m.init_params(rng, cfg),
            param_axes=lambda: m.param_axes(cfg),
            loss=lambda p, b, **kw: m.loss_fn(p, cfg, b, **kw),
            init_cache=lambda b, s, **kw: m.init_cache(cfg, b, s, **kw),
            cache_axes=lambda: m.cache_axes(),
            prefill=lambda p, b, c: m.prefill(p, cfg, b, c),
            decode=lambda p, t, c, i, enc: m.decode_step(p, cfg, t, c, i, enc),
        )
    raise ValueError(cfg.family)


def layer_scan_trips(cfg: ModelConfig) -> int:
    """Trip count of the (outer) layer scan — the extrapolated dimension
    of the two-point cost analysis (see repro.models.unroll)."""
    if cfg.family == "hybrid":
        from repro.models.hybrid import group_layout
        return group_layout(cfg)[0]
    if cfg.family == "encdec":
        assert cfg.n_enc_layers == cfg.n_layers, (
            "two-point extrapolation assumes equal enc/dec scan lengths")
        return cfg.n_layers
    return cfg.n_layers


# ---------------------------------------------------------------------------
# input specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for one (arch, shape) cell — no allocation.

    train/prefill: the full token batch; decode: one new token per
    sequence (the KV/state cache is part of the step signature, built by
    ``init_cache`` specs separately).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.frontend == "vision":
            # stub: precomputed patch embeddings replace token embedding
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
            del specs["tokens"]
        if cfg.family == "encdec":
            specs["frame_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend == "vision":
            specs = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
        if cfg.family == "encdec":
            specs["frame_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
        if cfg.family == "encdec":
            specs["enc_states"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        return specs
    raise ValueError(shape.kind)

"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, enc_seq, d_model).  The encoder runs
bidirectional attention over the frames; the decoder is a causal LM with
cross-attention into the encoder states.  Whisper uses GELU MLPs
(ungated), pre-LayerNorm, and no RoPE (sinusoidal/learned positions; we
use sinusoidal for shape flexibility — noted in DESIGN.md).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.unroll import scan_unroll
from repro.sharding.partition import constrain


def _sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    """positions: (S,) -> (S, d) float32."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(1, half - 1))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _attn_cfg(cfg: ModelConfig, *, causal: bool) -> L.AttentionConfig:
    return L.AttentionConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, qkv_bias=True, qk_norm=False,
        causal=causal, use_rope=False, norm_eps=cfg.norm_eps)


def _mlp_cfg(cfg: ModelConfig) -> L.MLPConfig:
    return L.MLPConfig(d_model=cfg.d_model, d_ff=cfg.d_ff,
                       activation="gelu", gated=False)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_enc_block(key, cfg: ModelConfig, dtype):
    ka, km, k1, k2 = jax.random.split(key, 4)
    return {
        "attn": L.init_attention(ka, _attn_cfg(cfg, causal=False), dtype),
        "mlp": L.init_mlp(km, _mlp_cfg(cfg), dtype),
        "norm1": L.init_norm(k1, cfg.d_model, "layernorm", dtype),
        "norm2": L.init_norm(k2, cfg.d_model, "layernorm", dtype),
    }


def enc_block_axes(cfg: ModelConfig):
    return {
        "attn": L.attention_axes(_attn_cfg(cfg, causal=False)),
        "mlp": L.mlp_axes(_mlp_cfg(cfg)),
        "norm1": L.norm_axes("layernorm"),
        "norm2": L.norm_axes("layernorm"),
    }


def init_dec_block(key, cfg: ModelConfig, dtype):
    ka, kc, km, k1, k2, k3 = jax.random.split(key, 6)
    return {
        "self_attn": L.init_attention(ka, _attn_cfg(cfg, causal=True), dtype),
        "cross_attn": L.init_attention(kc, _attn_cfg(cfg, causal=False), dtype),
        "mlp": L.init_mlp(km, _mlp_cfg(cfg), dtype),
        "norm1": L.init_norm(k1, cfg.d_model, "layernorm", dtype),
        "norm2": L.init_norm(k2, cfg.d_model, "layernorm", dtype),
        "norm3": L.init_norm(k3, cfg.d_model, "layernorm", dtype),
    }


def dec_block_axes(cfg: ModelConfig):
    return {
        "self_attn": L.attention_axes(_attn_cfg(cfg, causal=True)),
        "cross_attn": L.attention_axes(_attn_cfg(cfg, causal=False)),
        "mlp": L.mlp_axes(_mlp_cfg(cfg)),
        "norm1": L.norm_axes("layernorm"),
        "norm2": L.norm_axes("layernorm"),
        "norm3": L.norm_axes("layernorm"),
    }


def enc_block_fwd(params, x, cfg: ModelConfig, positions):
    h = L.apply_norm(x, params["norm1"], "layernorm")
    attn, _ = L.attention_fwd(params["attn"], h, _attn_cfg(cfg, causal=False),
                              positions=positions)
    x = x + attn
    h = L.apply_norm(x, params["norm2"], "layernorm")
    x = x + L.mlp_fwd(params["mlp"], h, _mlp_cfg(cfg))
    return constrain(x, "batch", "seq_q", "embed")


def dec_block_fwd(params, x, cfg: ModelConfig, *, positions, enc_kv,
                  kv_cache=None, cache_index=None):
    """enc_kv: (k, v) precomputed from encoder states for this layer."""
    h = L.apply_norm(x, params["norm1"], "layernorm")
    attn, new_cache = L.attention_fwd(
        params["self_attn"], h, _attn_cfg(cfg, causal=True),
        positions=positions, kv_cache=kv_cache, cache_index=cache_index)
    x = x + attn
    h = L.apply_norm(x, params["norm2"], "layernorm")
    cross, _ = L.attention_fwd(
        params["cross_attn"], h, _attn_cfg(cfg, causal=False),
        positions=positions, kv_override=enc_kv)
    x = x + cross
    h = L.apply_norm(x, params["norm3"], "layernorm")
    x = x + L.mlp_fwd(params["mlp"], h, _mlp_cfg(cfg))
    return constrain(x, "batch", "seq_q", "embed"), new_cache


def cross_kv(params, cfg: ModelConfig, enc_states: jax.Array):
    """Precompute cross-attention K/V for one decoder layer."""
    B, S, _ = enc_states.shape
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,dh->bsh", enc_states, params["cross_attn"]["wk"])
    v = jnp.einsum("bsd,dh->bsh", enc_states, params["cross_attn"]["wv"])
    k = (k + params["cross_attn"]["bk"]).reshape(B, S, KV, hd)
    v = (v + params["cross_attn"]["bv"]).reshape(B, S, KV, hd)
    return k, v


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = T._dtype(cfg.param_dtype)
    ke, ken, kd, kf1, kf2 = jax.random.split(key, 5)
    enc_keys = jax.random.split(ken, cfg.n_enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embedding": L.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "enc_layers": jax.vmap(lambda k: init_enc_block(k, cfg, dtype))(enc_keys),
        "dec_layers": jax.vmap(lambda k: init_dec_block(k, cfg, dtype))(dec_keys),
        "enc_norm": L.init_norm(kf1, cfg.d_model, "layernorm", dtype),
        "dec_norm": L.init_norm(kf2, cfg.d_model, "layernorm", dtype),
    }


def param_axes(cfg: ModelConfig) -> Dict[str, Any]:
    def lift(tree):
        return jax.tree.map(lambda ax: ("layers",) + ax, tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embedding": L.embedding_axes(),
        "enc_layers": lift(enc_block_axes(cfg)),
        "dec_layers": lift(dec_block_axes(cfg)),
        "enc_norm": L.norm_axes("layernorm"),
        "dec_norm": L.norm_axes("layernorm"),
    }


def encode(params, cfg: ModelConfig, frame_embeds: jax.Array,
           remat: bool = False) -> jax.Array:
    """frame_embeds: (B, enc_seq, d_model) — stub frontend output."""
    dtype = T._dtype(cfg.compute_dtype)
    S = frame_embeds.shape[1]
    pos = _sinusoidal(jnp.arange(S), cfg.d_model).astype(dtype)
    x = frame_embeds.astype(dtype) + pos[None]
    x = constrain(x, "batch", "seq_q", "embed")
    positions = jnp.arange(S)[None, :].astype(jnp.int32)

    def body(x, layer_params):
        return enc_block_fwd(layer_params, x, cfg, positions), None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, params["enc_layers"], unroll=scan_unroll())
    return L.apply_norm(x, params["enc_norm"], "layernorm")


def decode(params, cfg: ModelConfig, tokens: jax.Array, enc_states: jax.Array,
           *, cache=None, cache_index=None, remat: bool = False):
    dtype = T._dtype(cfg.compute_dtype)
    B, S = tokens.shape
    x = L.embed(params["embedding"], tokens).astype(dtype)
    if cache_index is None:
        positions = jnp.arange(S)
    else:
        positions = cache_index + jnp.arange(S)
    x = x + _sinusoidal(positions, cfg.d_model).astype(dtype)[None]
    positions_b = positions[None, :].astype(jnp.int32)

    # cross-attention K/V per layer, computed once from encoder states
    ckv = jax.vmap(lambda p: cross_kv(p, cfg, enc_states))(params["dec_layers"])

    def body(x, scanned):
        if cache is None:
            layer_params, ck, cv = scanned
            kv = None
        else:
            layer_params, ck, cv, sk, sv = scanned
            kv = (sk, sv)
        x, new_kv = dec_block_fwd(layer_params, x, cfg, positions=positions_b,
                                  enc_kv=(ck, cv), kv_cache=kv,
                                  cache_index=cache_index)
        return x, (None if cache is None else new_kv)

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    if cache is None:
        x, _ = lax.scan(body, x, (params["dec_layers"], ckv[0], ckv[1]),
                        unroll=scan_unroll())
        new_cache = None
    else:
        x, (nk, nv) = lax.scan(
            body, x, (params["dec_layers"], ckv[0], ckv[1], cache["k"], cache["v"]),
            unroll=scan_unroll())
        new_cache = {"k": nk, "v": nv}

    x = L.apply_norm(x, params["dec_norm"], "layernorm")
    return x, new_cache


def forward(params, cfg: ModelConfig, batch, *, cache=None, cache_index=None,
            remat: bool = False):
    """batch: {frame_embeds, tokens, labels?} or decode {tokens, enc_states}."""
    params = T.cast_params(params, cfg)
    if "enc_states" in batch:
        enc_states = batch["enc_states"]
    else:
        enc_states = encode(params, cfg, batch["frame_embeds"], remat=remat)
    hidden, new_cache = decode(params, cfg, batch["tokens"], enc_states,
                               cache=cache, cache_index=cache_index, remat=remat)
    return hidden, new_cache, enc_states


def loss_fn(params, cfg: ModelConfig, batch, *, remat: bool = True):
    hidden, _, _ = forward(params, cfg, batch, remat=remat)
    logits = L.unembed(params["embedding"], hidden, cfg.vocab)
    return L.cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


cache_axes = T.cache_axes


def prefill(params, cfg: ModelConfig, batch, cache):
    hidden, new_cache, enc_states = forward(
        params, cfg, batch, cache=cache, cache_index=jnp.int32(0), remat=True)
    logits = L.unembed(params["embedding"], hidden[:, -1:, :], cfg.vocab)
    return logits, new_cache, enc_states


def decode_step(params, cfg: ModelConfig, tokens, cache, cache_index,
                enc_states):
    hidden, new_cache, _ = forward(
        params, cfg, {"tokens": tokens, "enc_states": enc_states},
        cache=cache, cache_index=cache_index)
    logits = L.unembed(params["embedding"], hidden, cfg.vocab)
    return logits, new_cache

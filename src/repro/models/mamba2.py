"""Mamba2 (SSD — state-space duality) blocks and LM, pure-JAX reference.

The SSD chunked block decomposition is matmul-rich (MXU-friendly): within
each chunk of Q tokens the quadratic "attention-like" term runs as dense
einsums, and chunk-to-chunk information flows through a small recurrent
state (B, H, P, N) carried by ``lax.scan``.  The Pallas kernel in
``repro.kernels.ssd_scan`` implements the same decomposition with VMEM
tiling; this module is the oracle and the dry-run path.

Decode is O(1) per token via the state recurrence (this is why the
``long_500k`` cell runs for SSM archs).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.unroll import scan_unroll
from repro.sharding.partition import constrain


# ---------------------------------------------------------------------------
# SSD core (chunked scan)
# ---------------------------------------------------------------------------

def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B_mat: jax.Array,
                C_mat: jax.Array, D: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan.

    x:     (B, S, H, P)   per-head inputs
    dt:    (B, S, H)      positive step sizes (post-softplus)
    A:     (H,)           negative decay rates
    B_mat: (B, S, G, N)   input projections (G groups, H % G == 0)
    C_mat: (B, S, G, N)   output projections
    D:     (H,)           skip connection
    Returns (y: (B, S, H, P), final_state: (B, H, P, N)).
    """
    Bsz, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    HG = H // G
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # zero-pad the tail chunk: dt=0 contributes nothing to states or
        # outputs, so padding is exact.
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // Q

    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, Q, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(f32)
    Bc = B_mat.reshape(Bsz, nc, Q, G, N).astype(f32)
    Cc = C_mat.reshape(Bsz, nc, Q, G, N).astype(f32)

    a = dtc * A.astype(f32)                       # (B,nc,Q,H)  negative
    cum = jnp.cumsum(a, axis=2)                   # running decay within chunk
    dtx = xc * dtc[..., None]                     # dt-weighted inputs

    # ---- intra-chunk (quadratic, masked) ----
    # scores[b,c,q,k,g] = C_q . B_k
    scores = jnp.einsum("bcqgn,bckgn->bcqkg", Cc, Bc)
    # decay[b,c,q,k,h] = exp(cum_q - cum_k), masked to k <= q.  The mask is
    # applied INSIDE the exp (as -inf-ish) so the masked entries carry no
    # gradient and cannot overflow (cum_q - cum_k > 0 above the diagonal).
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    decay = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -jnp.inf))
    scores_h = jnp.repeat(scores, HG, axis=-1)    # broadcast groups -> heads
    # (B,nc,Q,K,H) x (B,nc,K,H,P) -> (B,nc,Q,H,P)
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", scores_h * decay, dtx)

    # ---- chunk states ----
    # w_k = exp(cum_last - cum_k): contribution of position k to the state
    w = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,Q,H)
    Bh = jnp.repeat(Bc, HG, axis=-2)              # (B,nc,Q,H,N)
    # states[b,c,h,p,n] = sum_k w[k,h] * dtx[k,h,p] * B[k,h,n]
    states = jnp.einsum("bckh,bckhp,bckhn->bchpn", w, dtx, Bh)

    # ---- inter-chunk recurrence (associative scan: log-depth, no while
    # loop — keeps dry-run cost analysis exact and parallelizes on TPU) ----
    chunk_decay = jnp.exp(jnp.sum(a, axis=2))     # (B,nc,H)
    h0 = (jnp.zeros((Bsz, H, P, N), f32) if init_state is None
          else init_state.astype(f32))

    dec_b = jnp.broadcast_to(chunk_decay[..., None, None],
                             (Bsz, nc, H, 1, 1))

    def combine(earlier, later):
        a1, b1 = earlier
        a2, b2 = later
        return a1 * a2, a2 * b1 + b2

    cum_dec, h_zero = lax.associative_scan(combine, (dec_b, states), axis=1)
    h_incl = h_zero + cum_dec * h0[:, None]        # h after chunk c
    h_prevs = jnp.concatenate([h0[:, None], h_incl[:, :-1]], axis=1)
    final = h_incl[:, -1]

    # ---- inter-chunk output ----
    Ch = jnp.repeat(Cc, HG, axis=-2)              # (B,nc,Q,H,N)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, h_prevs, jnp.exp(cum))

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    y = y + D.astype(f32)[None, None, :, None] * x.astype(f32)
    return y[:, :S_orig].astype(x.dtype), final


def ssd_decode_step(x, dt, A, B_mat, C_mat, D, state):
    """One-token SSD update.

    x: (B,H,P), dt: (B,H), B_mat/C_mat: (B,G,N), state: (B,H,P,N).
    """
    Bsz, H, P = x.shape
    G, N = B_mat.shape[1], B_mat.shape[2]
    f32 = jnp.float32
    xf, dtf = x.astype(f32), dt.astype(f32)
    Bh = jnp.broadcast_to(B_mat[:, :, None].astype(f32), (Bsz, G, H // G, N)
                          ).reshape(Bsz, H, N)
    Ch = jnp.broadcast_to(C_mat[:, :, None].astype(f32), (Bsz, G, H // G, N)
                          ).reshape(Bsz, H, N)
    decay = jnp.exp(dtf * A.astype(f32))                       # (B,H)
    incr = (dtf[..., None] * xf)[..., None] * Bh[:, :, None, :]  # (B,H,P,N)
    new_state = decay[..., None, None] * state.astype(f32) + incr
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + D.astype(f32)[None, :, None] * xf
    return y.astype(x.dtype), new_state.astype(state.dtype)


# ---------------------------------------------------------------------------
# causal depthwise conv1d (width w) with streaming state
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, kernel: jax.Array, bias: jax.Array,
                  prev: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, C); kernel: (w, C); prev: (B, w-1, C) streaming tail.
    Returns (y: (B,S,C), new_tail: (B, w-1, C))."""
    B, S, C = x.shape
    w = kernel.shape[0]
    if prev is None:
        prev = jnp.zeros((B, w - 1, C), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)       # (B, S+w-1, C)
    idx = jnp.arange(S)[:, None] + jnp.arange(w)[None, :]
    windows = xp[:, idx, :]                        # (B, S, w, C)
    y = jnp.einsum("bswc,wc->bsc", windows.astype(jnp.float32),
                   kernel.astype(jnp.float32))
    y = (y + bias.astype(jnp.float32)).astype(x.dtype)
    new_tail = xp[:, S:, :] if w > 1 else jnp.zeros((B, 0, C), x.dtype)
    return y, new_tail


# ---------------------------------------------------------------------------
# mamba2 block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv_width
    conv_ch = di + 2 * G * N
    proj_out = 2 * di + 2 * G * N + H
    ks = jax.random.split(key, 6)
    return {
        "in_proj": L.fan_in_init(ks[0], (d, proj_out), dtype),
        "conv_kernel": L.normal_init(ks[1], (w, conv_ch), dtype, scale=0.5 / w),
        "conv_bias": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": L.init_norm(ks[2], di, "rmsnorm", dtype),
        "out_proj": L.fan_in_init(ks[3], (di, d), dtype),
        "in_norm": L.init_norm(ks[4], d, cfg.norm_type, dtype),
    }


def block_axes(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "in_proj": ("embed", "ssm_inner_proj"),
        "conv_kernel": (None, "ssm_conv_ch"),
        "conv_bias": ("ssm_conv_ch",),
        "A_log": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "D": ("ssm_heads",),
        "norm": {"scale": ("ssm_inner_norm",)},
        "out_proj": ("ssm_inner", "embed"),
        "in_norm": L.norm_axes(cfg.norm_type),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di = cfg.d_inner
    G, N, H = cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * G * N]
    dt = zxbcdt[..., di + di + 2 * G * N:]
    return z, xBC, dt


def _split_xbc(cfg: ModelConfig, xBC: jax.Array):
    di = cfg.d_inner
    G, N = cfg.ssm_n_groups, cfg.ssm_state
    x = xBC[..., :di]
    B_mat = xBC[..., di:di + G * N]
    C_mat = xBC[..., di + G * N:]
    return x, B_mat, C_mat


def block_fwd(params, u: jax.Array, cfg: ModelConfig, *,
              conv_state: Optional[jax.Array] = None,
              ssd_state: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence mamba2 block.  u: (B, S, d_model)."""
    B, S, d = u.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_n_groups, cfg.ssm_state

    h = L.apply_norm(u, params["in_norm"], cfg.norm_type)
    zxbcdt = jnp.einsum("bsd,dk->bsk", h, params["in_proj"])
    zxbcdt = constrain(zxbcdt, "batch", "seq_q", "ssm_inner_proj")
    z, xBC, dt = _split_proj(cfg, zxbcdt)

    xBC, new_conv = causal_conv1d(xBC, params["conv_kernel"],
                                  params["conv_bias"], conv_state)
    xBC = jax.nn.silu(xBC)
    x, B_mat, C_mat = _split_xbc(cfg, xBC)

    x = x.reshape(B, S, H, P)
    x = constrain(x, "batch", "seq_q", "ssm_heads", None)
    B_mat = B_mat.reshape(B, S, G, N)
    C_mat = C_mat.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    y, final_state = ssd_chunked(x, dt, A, B_mat, C_mat, params["D"],
                                 cfg.ssm_chunk, init_state=ssd_state)
    y = y.reshape(B, S, cfg.d_inner)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                  params["norm"]["scale"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    out = constrain(u + out, "batch", "seq_q", "embed")
    return out, (new_conv, final_state)


def block_decode(params, u: jax.Array, cfg: ModelConfig, *,
                 conv_state: jax.Array, ssd_state: jax.Array):
    """One-token mamba2 step.  u: (B, 1, d_model)."""
    B = u.shape[0]
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_n_groups, cfg.ssm_state

    h = L.apply_norm(u, params["in_norm"], cfg.norm_type)
    zxbcdt = jnp.einsum("bsd,dk->bsk", h, params["in_proj"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)

    xBC, new_conv = causal_conv1d(xBC, params["conv_kernel"],
                                  params["conv_bias"], conv_state)
    xBC = jax.nn.silu(xBC)
    x, B_mat, C_mat = _split_xbc(cfg, xBC)

    x1 = x[:, 0].reshape(B, H, P)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, new_state = ssd_decode_step(
        x1, dt1, A, B_mat[:, 0].reshape(B, G, N), C_mat[:, 0].reshape(B, G, N),
        params["D"], ssd_state)
    y = y.reshape(B, 1, cfg.d_inner)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                  params["norm"]["scale"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    return u + out, (new_conv, new_state)


# ---------------------------------------------------------------------------
# full mamba2 LM
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    from repro.models.transformer import _dtype
    dtype = _dtype(cfg.param_dtype)
    ke, kl, kf = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)
    return {
        "embedding": L.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "layers": stacked,
        "final_norm": L.init_norm(kf, cfg.d_model, cfg.norm_type, dtype),
    }


def param_axes(cfg: ModelConfig) -> Dict[str, Any]:
    def lift(tree):
        return jax.tree.map(lambda ax: ("layers",) + ax, tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embedding": L.embedding_axes(),
        "layers": lift(block_axes(cfg)),
        "final_norm": L.norm_axes(cfg.norm_type),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.float32) -> Dict[str, jax.Array]:
    G, N = cfg.ssm_n_groups, cfg.ssm_state
    conv_ch = cfg.d_inner + 2 * G * N
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        "ssd": jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads,
                          cfg.ssm_head_dim, N), dtype),
    }


def cache_axes() -> Dict[str, Any]:
    return {"conv": ("layers", "batch", None, "ssm_conv_ch"),
            "ssd": ("layers", "batch", "ssm_heads", None, None)}


def forward(params, cfg: ModelConfig, batch, *, cache=None, cache_index=None,
            remat: bool = False):
    from repro.models.transformer import _embed_inputs, cast_params
    params = cast_params(params, cfg)
    x = _embed_inputs(params, cfg, batch)
    decode = cache is not None and x.shape[1] == 1

    def body(x, scanned):
        if cache is None:
            x, _ = block_fwd(scanned, x, cfg)
            return x, None
        layer_params, conv_s, ssd_s = scanned
        if decode:
            x, (nc, ns) = block_decode(layer_params, x, cfg,
                                       conv_state=conv_s, ssd_state=ssd_s)
        else:
            x, (nc, ns) = block_fwd(layer_params, x, cfg,
                                    conv_state=conv_s, ssd_state=ssd_s)
        return x, (nc, ns.astype(ssd_s.dtype))

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    if cache is None:
        x, _ = lax.scan(body, x, params["layers"], unroll=scan_unroll())
        new_cache = None
    else:
        x, (ncs, nss) = lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssd"]),
            unroll=scan_unroll())
        new_cache = {"conv": ncs, "ssd": nss}

    x = L.apply_norm(x, params["final_norm"], cfg.norm_type)
    return x, new_cache


def loss_fn(params, cfg: ModelConfig, batch, *, remat: bool = True):
    hidden, _ = forward(params, cfg, batch, remat=remat)
    logits = L.unembed(params["embedding"], hidden, cfg.vocab)
    return L.cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


def prefill(params, cfg: ModelConfig, batch, cache):
    hidden, new_cache = forward(params, cfg, batch, cache=cache,
                                cache_index=jnp.int32(0), remat=True)
    logits = L.unembed(params["embedding"], hidden[:, -1:, :], cfg.vocab)
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache, cache_index):
    hidden, new_cache = forward(params, cfg, {"tokens": tokens}, cache=cache,
                                cache_index=cache_index)
    logits = L.unembed(params["embedding"], hidden, cfg.vocab)
    return logits, new_cache

"""Core neural layers, functional style (pure JAX, no framework deps).

Parameters are pytrees of jnp arrays; every constructor returns
``(init_fn, logical_axes)`` compatible with layer stacking via
``jax.lax.scan``.  Activation sharding is annotated through
``repro.sharding.partition.constrain`` with logical axis names.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.partition import constrain


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def normal_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def fan_in_init(key, shape, dtype):
    scale = 1.0 / math.sqrt(shape[0])
    return (scale * jax.random.normal(key, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    return y.astype(dtype)


def layernorm(x: jax.Array, scale: Optional[jax.Array], bias: Optional[jax.Array],
              eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def apply_norm(x: jax.Array, params: Dict[str, Any], kind: str) -> jax.Array:
    """kind in {rmsnorm, layernorm, nonparam_ln}."""
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    if kind == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    if kind == "nonparam_ln":  # OLMo: no affine parameters
        return layernorm(x, None, None)
    raise ValueError(kind)


def init_norm(key, d: int, kind: str, dtype) -> Dict[str, Any]:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "nonparam_ln":
        return {}
    raise ValueError(kind)


def norm_axes(kind: str) -> Dict[str, Any]:
    if kind == "rmsnorm":
        return {"scale": ("embed_norm",)}
    if kind == "layernorm":
        return {"scale": ("embed_norm",), "bias": ("embed_norm",)}
    return {}


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)          # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (reference path; the Pallas flash kernel lives in repro.kernels)
# ---------------------------------------------------------------------------

def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, q_offset: jax.Array | int = 0,
                  sliding_window: Optional[int] = None,
                  kv_len: Optional[jax.Array] = None,
                  logit_softcap: Optional[float] = None) -> jax.Array:
    """Grouped-query attention.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D), Hq = G * Hkv.
    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``kv_len``: number of valid kv entries (for padded caches).
    Returns (B, Sq, Hq, D).
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, Sq, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logit_softcap:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)

    Skv = k.shape[1]
    kv_pos = jnp.arange(Skv)
    q_pos = jnp.arange(Sq) + q_offset
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if sliding_window is not None:
        mask &= kv_pos[None, :] > (q_pos[:, None] - sliding_window)
    if kv_len is not None:
        mask &= kv_pos[None, :] < kv_len
    logits = jnp.where(mask[None, None, None], logits, -1e30)

    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None
    causal: bool = True
    use_rope: bool = True
    norm_eps: float = 1e-6


def init_attention(key, cfg: AttentionConfig, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": fan_in_init(ks[0], (d, H * hd), dtype),
        "wk": fan_in_init(ks[1], (d, KV * hd), dtype),
        "wv": fan_in_init(ks[2], (d, KV * hd), dtype),
        "wo": fan_in_init(ks[3], (H * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention_axes(cfg: AttentionConfig) -> Dict[str, Any]:
    p = {
        "wq": ("embed", "qkv_out"),
        "wk": ("embed", "kv_out"),
        "wv": ("embed", "kv_out"),
        "wo": ("qkv_out", "embed"),
    }
    if cfg.qkv_bias:
        p.update({"bq": ("qkv_out",), "bk": ("kv_out",), "bv": ("kv_out",)})
    if cfg.qk_norm:
        p.update({"q_norm": ("head_dim",), "k_norm": ("head_dim",)})
    return p


def project_qkv(params, x: jax.Array, cfg: AttentionConfig, *,
                positions: jax.Array,
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Shared QKV prologue for the dense and paged attention paths:
    projections (+ optional bias), head reshape, optional qk-norm,
    RoPE at ``positions``.  q: (B,S,H,hd); k, v: (B,S,KV,hd)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_fwd(params, x: jax.Array, cfg: AttentionConfig, *,
                  positions: jax.Array,
                  kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                  cache_index: Optional[jax.Array] = None,
                  kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                  ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Attention with optional KV cache (decode) or KV override (cross-attn).

    x: (B, S, d).  kv_cache: (k, v) each (B, max_seq, KV, hd); new keys are
    inserted at ``cache_index`` and attention runs over the full cache.
    Returns (out, updated_cache).
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    if kv_override is not None:
        # cross-attention: q-only projection, K/V precomputed elsewhere
        q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
        if cfg.qkv_bias:
            q = q + params["bq"]
        q = q.reshape(B, S, H, hd)
        if cfg.qk_norm:
            q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        if cfg.use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
        k, v = kv_override
        new_cache = None
        q_offset = 0
        kv_len = None
    else:
        q, k, v = project_qkv(params, x, cfg, positions=positions)
        if kv_cache is not None:
            ck, cv = kv_cache
            ck = lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_index, axis=1)
            cv = lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_index, axis=1)
            k, v = ck, cv
            new_cache = (ck, cv)
            q_offset = cache_index
            kv_len = cache_index + S
        else:
            new_cache = None
            q_offset = 0
            kv_len = None

    q = constrain(q, "batch", "seq_attn", "heads", "head_dim")
    k = constrain(k, "batch", "seq_kv", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq_kv", "kv_heads", "head_dim")

    out = gqa_attention(q, k, v, causal=cfg.causal, q_offset=q_offset,
                        sliding_window=cfg.sliding_window, kv_len=kv_len)
    out = constrain(out, "batch", "seq_attn", "heads", "head_dim")
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd), params["wo"])
    return out, new_cache


def attention_fwd_paged(params, x: jax.Array, cfg: AttentionConfig, *,
                        positions: jax.Array,
                        k_pages: jax.Array, v_pages: jax.Array,
                        page_table: jax.Array, lengths: jax.Array,
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Decode attention over a *paged* KV pool (one layer's pages).

    x: (B, 1, d) — one new token per sequence.  k/v pages: (P, ps, KV, hd),
    the shared physical page pool for this layer.  page_table: (B, PMAX)
    int32 logical->physical ids.  lengths: (B,) current KV length per
    sequence — also the write position of this token (idle rows carry
    length 0 and a page table full of trash-page ids; their writes land
    in the trash page and their output is ignored by the caller).

    The new token's K/V is scattered into each row's current page, then
    the Pallas kernel gathers the whole prefix through the page table.
    Returns (out (B,1,d), k_pages, v_pages).
    """
    from repro.kernels.ops import paged_attention

    B, S, _ = x.shape
    assert S == 1, "paged attention serves decode (one token per step)"
    H, hd = cfg.n_heads, cfg.head_dim
    ps = k_pages.shape[1]

    q, k, v = project_qkv(params, x, cfg, positions=positions)

    # scatter this token's K/V into each row's current physical page
    phys = page_table[jnp.arange(B), lengths // ps]        # (B,)
    off = lengths % ps
    k_pages = k_pages.at[phys, off].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[phys, off].set(v[:, 0].astype(v_pages.dtype))

    out = paged_attention(q, k_pages, v_pages, page_table, lengths + 1,
                          sliding_window=cfg.sliding_window)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd), params["wo"])
    return out, k_pages, v_pages


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    activation: str = "silu"   # silu (SwiGLU-gated) | gelu (plain)
    gated: bool = True


def init_mlp(key, cfg: MLPConfig, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    p = {"w_up": fan_in_init(ks[0], (cfg.d_model, cfg.d_ff), dtype),
         "w_down": fan_in_init(ks[1], (cfg.d_ff, cfg.d_model), dtype)}
    if cfg.gated:
        p["w_gate"] = fan_in_init(ks[2], (cfg.d_model, cfg.d_ff), dtype)
    return p


def mlp_axes(cfg: MLPConfig) -> Dict[str, Any]:
    p = {"w_up": ("embed", "ff"), "w_down": ("ff", "embed")}
    if cfg.gated:
        p["w_gate"] = ("embed", "ff")
    return p


def mlp_fwd(params, x: jax.Array, cfg: MLPConfig) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    up = constrain(up, "batch", "seq_q", "ff")
    if cfg.gated:
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        act = jax.nn.silu(gate) if cfg.activation == "silu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(up) if cfg.activation == "gelu" else jax.nn.silu(up)
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype):
    return {"table": normal_init(key, (vocab, d), dtype)}


def embedding_axes():
    return {"table": ("vocab", "embed")}


def embed(params, tokens: jax.Array) -> jax.Array:
    out = jnp.take(params["table"], tokens, axis=0)
    return constrain(out, "batch", "seq_q", "embed")


def unembed(params, x: jax.Array, vocab: Optional[int] = None) -> jax.Array:
    logits = jnp.einsum("bsd,vd->bsv", x, params["table"])
    logits = constrain(logits, "batch", "seq_q", "vocab")
    if vocab is not None and vocab != logits.shape[-1]:
        logits = logits[..., :vocab]
    return logits


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross entropy.  logits: (B,S,V), labels: (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)

"""Unified architecture configuration for all assigned model families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None  # default d_model // n_heads
    # dense-transformer variants
    qkv_bias: bool = False          # qwen1.5
    qk_norm: bool = False           # qwen3
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm | nonparam_ln
    parallel_block: bool = False    # command-r: attn and mlp in parallel
    mlp_activation: str = "silu"
    mlp_gated: bool = True
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # mixtral SWA
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0            # d_ff per expert (olmoe: 1024)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (mamba2 SSD)
    ssm_state: int = 0              # N
    ssm_head_dim: int = 64          # P
    ssm_expand: int = 2             # d_inner = expand * d_model
    ssm_chunk: int = 128            # SSD chunk length
    ssm_conv_width: int = 4
    ssm_n_groups: int = 1

    # hybrid (zamba2): shared transformer block every `attn_every` layers
    attn_every: int = 0

    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500             # audio frame positions (stub frontend)

    # modality frontend stub: none | audio | vision
    frontend: str = "none"

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    # which attention implementation ("reference" | "pallas")
    attention_impl: str = "reference"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(1, self.n_heads))

    # ---- derived quantities -------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding/logits table rows padded to a 256 multiple so the
        vocab dim shards evenly (Megatron-style); labels never index the
        padding and logits are sliced back to ``vocab``."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> float:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "moe"):
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            if self.family == "moe":
                ff = 3 * d * (self.expert_d_ff or self.d_ff) * self.n_experts
            else:
                ff = 3 * d * self.d_ff if self.mlp_gated else 2 * d * self.d_ff
            return emb + L * (attn + ff)
        if self.family == "ssm":
            di, N = self.d_inner, self.ssm_state
            per = d * (2 * di + 2 * self.ssm_n_groups * N + self.ssm_heads) + di * d
            return emb + L * per
        if self.family == "hybrid":
            di, N = self.d_inner, self.ssm_state
            mamba = d * (2 * di + 2 * self.ssm_n_groups * N + self.ssm_heads) + di * d
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            ff = 3 * d * self.d_ff
            return emb + L * mamba + (attn + ff)  # shared block counted once
        if self.family == "encdec":
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            ff = 2 * d * self.d_ff  # whisper MLPs are ungated
            enc = self.n_enc_layers * (attn + ff)
            dec = L * (2 * attn + ff)  # self + cross attention
            return emb + enc + dec
        raise ValueError(self.family)

    def active_param_count(self) -> float:
        """Params touched per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        ff = 3 * d * (self.expert_d_ff or self.d_ff) * self.top_k
        return emb + L * (attn + ff)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str               # train_4k | prefill_32k | decode_32k | long_500k
    kind: str               # train | prefill | decode
    seq_len: int
    global_batch: int
    # training-only knobs
    microbatches: int = 1   # gradient-accumulation steps inside train_step
    remat: bool = True


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def requires_subquadratic(shape: ShapeConfig) -> bool:
    return shape.name == "long_500k"


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; else (False, reason)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is a full-attention architecture (skip per spec)")
    return True, ""

"""Token-choice top-k Mixture-of-Experts LM (mixtral-8x7b, olmoe-1b-7b).

Dispatch is sort-based with a capacity factor, performed within ``groups``
independent token groups.  Groups map 1:1 onto data shards of the mesh, so
dispatch compiles to shard-local sort/gather plus (for expert-parallel
layouts) a single all-to-all across the expert axis — the TPU-native
analogue of the paper's inter-cluster memory traffic consolidation.

Two expert sharding layouts (per-arch choice, see DESIGN.md):
  * ``ffn``    — every device holds all experts, each expert's d_ff is
                 tensor-sharded over the model axis (mixtral: 8 experts
                 don't divide a 16-way axis).
  * ``expert`` — experts sharded over the model axis (olmoe: 64 experts,
                 16-way EP, 4 experts per device).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.unroll import scan_unroll
from repro.sharding.partition import constrain


def init_moe_mlp(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    E, d, f = cfg.n_experts, cfg.d_model, (cfg.expert_d_ff or cfg.d_ff)
    kr, kg, ku, kd = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d)
    return {
        "router": L.normal_init(kr, (d, E), jnp.float32, scale=0.02),
        "w_gate": (scale * jax.random.normal(kg, (E, d, f))).astype(dtype),
        "w_up": (scale * jax.random.normal(ku, (E, d, f))).astype(dtype),
        "w_down": ((1.0 / jnp.sqrt(f)) * jax.random.normal(kd, (E, f, d))).astype(dtype),
    }


def moe_mlp_axes() -> Dict[str, Any]:
    return {
        "router": ("embed", "expert_router"),
        "w_gate": ("expert", "embed", "expert_ff"),
        "w_up": ("expert", "embed", "expert_ff"),
        "w_down": ("expert", "expert_ff", "embed"),
    }


def moe_mlp_fwd(params, x: jax.Array, cfg: ModelConfig, *,
                groups: int = 1) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (out, aux_loss).

    Router in fp32; top-k gates renormalized (mixtral convention).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T_total = B * S
    G = groups if T_total % groups == 0 else 1
    Tg = T_total // G

    xt = x.reshape(G, Tg, d)
    xt = constrain(xt, "moe_groups", None, "embed")

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (G,Tg,E)
    gate_vals, expert_idx = lax.top_k(probs, k)                  # (G,Tg,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch/Mixtral style)
    me = jnp.mean(probs, axis=1)                                  # (G,E)
    one_hot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)    # (G,Tg,k,E)
    ce = jnp.mean(jnp.sum(one_hot, axis=2), axis=1)               # (G,E)
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))

    capacity = int(max(1, round(Tg * k / E * cfg.capacity_factor)))

    def dispatch_one(xg, eidx, gates):
        # xg: (Tg,d), eidx: (Tg,k), gates: (Tg,k)
        flat_e = eidx.reshape(-1)                                  # (Tg*k,)
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        token_of = order // k
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(Tg * k) - starts[sorted_e]
        keep = pos < capacity
        dest = jnp.where(keep, sorted_e * capacity + pos, E * capacity)
        # GATHER-based dispatch: scatter only the tiny int32 slot->token
        # map, then gather values.  A value scatter into the expert-
        # sharded buffer makes GSPMD all-reduce the FULL (Tg*k, d)
        # activation with a u32 companion (measured: 80% of this cell's
        # collective bytes); the value gather partitions cleanly because
        # xg is replicated across the expert axis (§Perf cell A, A6).
        slot_token = jnp.full((E * capacity + 1,), Tg, jnp.int32)
        slot_token = slot_token.at[dest].set(token_of)[:-1]        # (E*C,)
        xg_pad = jnp.concatenate([xg, jnp.zeros((1, d), xg.dtype)])
        buf = xg_pad[slot_token].reshape(E, capacity, d)
        return buf, (order, token_of, dest, keep)

    buf, meta = jax.vmap(dispatch_one)(xt, expert_idx, gate_vals)
    buf = constrain(buf, "moe_groups", "expert", None, "embed")

    # expert FFN (SwiGLU) — gecd,edf batched over experts
    gate = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    h = jax.nn.silu(gate) * up
    h = constrain(h, "moe_groups", "expert", None, "expert_ff")
    out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    out = constrain(out, "moe_groups", "expert", None, "embed")

    def combine_one(out_g, gates, m):
        # Accumulate in the COMPUTE dtype: the per-expert partial sums are
        # combined across the model axis by an all-reduce of the full
        # (Tg, d) activation -- fp32 accumulation would double the bytes on
        # the wire for a top-k sum that bf16 carries fine (see
        # EXPERIMENTS.md #Perf cell A, iteration A4).
        order, token_of, dest, keep = m
        flat = out_g.reshape(E * capacity, d).astype(x.dtype)
        gathered = flat[jnp.minimum(dest, E * capacity - 1)]
        w = (gates.reshape(-1)[order] * keep).astype(x.dtype)
        y = jnp.zeros((Tg, d), x.dtype)
        return y.at[token_of].add(gathered * w[:, None])

    y = jax.vmap(combine_one)(out, gate_vals, meta)
    y = constrain(y, "moe_groups", None, "embed")
    return y.reshape(B, S, d), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# MoE transformer block / model (attention shared with dense transformer)
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    ka, km, k1, k2 = jax.random.split(key, 4)
    return {
        "attn": L.init_attention(ka, T.attn_config(cfg), dtype),
        "moe": init_moe_mlp(km, cfg, dtype),
        "norm1": L.init_norm(k1, cfg.d_model, cfg.norm_type, dtype),
        "norm2": L.init_norm(k2, cfg.d_model, cfg.norm_type, dtype),
    }


def block_axes(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "attn": L.attention_axes(T.attn_config(cfg)),
        "moe": moe_mlp_axes(),
        "norm1": L.norm_axes(cfg.norm_type),
        "norm2": L.norm_axes(cfg.norm_type),
    }


def block_fwd(params, x, cfg: ModelConfig, *, positions, kv_cache=None,
              cache_index=None, groups: int = 1):
    h = L.apply_norm(x, params["norm1"], cfg.norm_type)
    attn_out, new_cache = L.attention_fwd(
        params["attn"], h, T.attn_config(cfg), positions=positions,
        kv_cache=kv_cache, cache_index=cache_index)
    x = x + attn_out
    h2 = L.apply_norm(x, params["norm2"], cfg.norm_type)
    moe_out, aux = moe_mlp_fwd(params["moe"], h2, cfg, groups=groups)
    x = x + moe_out
    x = constrain(x, "batch", "seq_q", "embed")
    return x, new_cache, aux


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = T._dtype(cfg.param_dtype)
    ke, kl, kf = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)
    return {
        "embedding": L.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "layers": stacked,
        "final_norm": L.init_norm(kf, cfg.d_model, cfg.norm_type, dtype),
    }


def param_axes(cfg: ModelConfig) -> Dict[str, Any]:
    def lift(tree):
        return jax.tree.map(lambda ax: ("layers",) + ax, tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embedding": L.embedding_axes(),
        "layers": lift(block_axes(cfg)),
        "final_norm": L.norm_axes(cfg.norm_type),
    }


def forward(params, cfg: ModelConfig, batch, *, cache=None, cache_index=None,
            remat: bool = False, groups: int = 1):
    params = T.cast_params(params, cfg)
    x = T._embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    if cache_index is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    else:
        positions = (cache_index + jnp.arange(S))[None, :].astype(jnp.int32)

    def body(carry, scanned):
        x, aux_sum = carry
        if cache is None:
            layer_params = scanned
            kv = None
        else:
            layer_params, ck, cv = scanned
            kv = (ck, cv)
        x, new_kv, aux = block_fwd(layer_params, x, cfg, positions=positions,
                                   kv_cache=kv, cache_index=cache_index,
                                   groups=groups)
        return (x, aux_sum + aux), (None if cache is None else new_kv)

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    if cache is None:
        (x, aux), _ = lax.scan(body, (x, jnp.float32(0.0)), params["layers"],
                               unroll=scan_unroll())
        new_cache = None
    else:
        (x, aux), (nk, nv) = lax.scan(
            body, (x, jnp.float32(0.0)), (params["layers"], cache["k"], cache["v"]),
            unroll=scan_unroll())
        new_cache = {"k": nk, "v": nv}

    x = L.apply_norm(x, params["final_norm"], cfg.norm_type)
    return x, new_cache, aux / cfg.n_layers


def loss_fn(params, cfg: ModelConfig, batch, *, remat: bool = True,
            groups: int = 1) -> jax.Array:
    hidden, _, aux = forward(params, cfg, batch, remat=remat, groups=groups)
    logits = L.unembed(params["embedding"], hidden, cfg.vocab)
    ce = L.cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return ce + cfg.router_aux_coef * aux


init_cache = T.init_cache
cache_axes = T.cache_axes


def prefill(params, cfg: ModelConfig, batch, cache, *, groups: int = 1):
    hidden, new_cache, _ = forward(params, cfg, batch, cache=cache,
                                   cache_index=jnp.int32(0), remat=True,
                                   groups=groups)
    logits = L.unembed(params["embedding"], hidden[:, -1:, :], cfg.vocab)
    return logits, new_cache


def prefill_at(params, cfg: ModelConfig, batch, cache, last_pos, *,
               groups: int = 1):
    """Bucketed prefill: logits taken at ``last_pos`` (the last real
    position of a right-padded prompt) instead of the padded end."""
    hidden, new_cache, _ = forward(params, cfg, batch, cache=cache,
                                   cache_index=jnp.int32(0), remat=True,
                                   groups=groups)
    h_last = lax.dynamic_slice_in_dim(hidden, last_pos, 1, axis=1)
    return L.unembed(params["embedding"], h_last, cfg.vocab), new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache, cache_index, *,
                groups: int = 1):
    hidden, new_cache, _ = forward(params, cfg, {"tokens": tokens},
                                   cache=cache, cache_index=cache_index,
                                   groups=groups)
    logits = L.unembed(params["embedding"], hidden, cfg.vocab)
    return logits, new_cache


def decode_paged(params, cfg: ModelConfig, tokens, pools, page_table,
                 lengths, *, groups: int = 1):
    """One-token decode over the shared paged KV pool (see
    ``transformer.decode_paged``); MoE blocks, same page mechanics."""
    params = T.cast_params(params, cfg)
    x = T._embed_inputs(params, cfg, {"tokens": tokens})
    positions = lengths[:, None].astype(jnp.int32)

    def body(x, scanned):
        layer_params, kp, vp = scanned
        h = L.apply_norm(x, layer_params["norm1"], cfg.norm_type)
        attn_out, kp, vp = L.attention_fwd_paged(
            layer_params["attn"], h, T.attn_config(cfg), positions=positions,
            k_pages=kp, v_pages=vp, page_table=page_table, lengths=lengths)
        x = x + attn_out
        h2 = L.apply_norm(x, layer_params["norm2"], cfg.norm_type)
        moe_out, _ = moe_mlp_fwd(layer_params["moe"], h2, cfg, groups=groups)
        x = x + moe_out
        return x, (kp, vp)

    x, (nk, nv) = lax.scan(body, x, (params["layers"], pools["k"],
                                     pools["v"]), unroll=scan_unroll())
    x = L.apply_norm(x, params["final_norm"], cfg.norm_type)
    return L.unembed(params["embedding"], x, cfg.vocab), {"k": nk, "v": nv}

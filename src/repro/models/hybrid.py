"""Zamba2-style hybrid: Mamba2 backbone + one SHARED transformer block
invoked every ``attn_every`` layers (weight reuse, separate KV caches per
invocation point).

Layer layout for n_layers = 81, attn_every = 6:
  13 groups of [shared-attn-block, 6 mamba layers] + 3 tail mamba layers
(the shared block therefore runs 13 times with 13 distinct KV caches).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.unroll import inner_scan_unroll, scan_unroll
from repro.sharding.partition import constrain


def group_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, layers_per_group, tail_layers)."""
    k = cfg.attn_every
    n_groups = cfg.n_layers // k
    tail = cfg.n_layers - n_groups * k
    return n_groups, k, tail


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = T._dtype(cfg.param_dtype)
    ke, ks, km, kt, kf = jax.random.split(key, 5)
    n_groups, per, tail = group_layout(cfg)

    main_keys = jax.random.split(km, n_groups * per)
    main_keys = main_keys.reshape((n_groups, per) + main_keys.shape[1:])
    stacked_main = jax.vmap(jax.vmap(lambda k: M.init_block(k, cfg, dtype)))(main_keys)
    p = {
        "embedding": L.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "shared_attn": T.init_block(ks, cfg, dtype),
        "mamba_main": stacked_main,                       # (G, per, ...)
        "final_norm": L.init_norm(kf, cfg.d_model, cfg.norm_type, dtype),
    }
    if tail:
        p["mamba_tail"] = jax.vmap(lambda k: M.init_block(k, cfg, dtype))(
            jax.random.split(kt, tail))
    return p


def param_axes(cfg: ModelConfig) -> Dict[str, Any]:
    n_groups, per, tail = group_layout(cfg)

    def lift(tree, n_lead):
        return jax.tree.map(lambda ax: ("layers",) * n_lead + ax, tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    p = {
        "embedding": L.embedding_axes(),
        "shared_attn": T.block_axes(cfg),
        "mamba_main": lift(M.block_axes(cfg), 2),
        "final_norm": L.norm_axes(cfg.norm_type),
    }
    if tail:
        p["mamba_tail"] = lift(M.block_axes(cfg), 1)
    return p


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    n_groups, per, tail = group_layout(cfg)
    G, N = cfg.ssm_n_groups, cfg.ssm_state
    conv_ch = cfg.d_inner + 2 * G * N
    kv_shape = (n_groups, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    c = {
        "k": jnp.zeros(kv_shape, dtype),
        "v": jnp.zeros(kv_shape, dtype),
        "conv": jnp.zeros((n_groups, per, batch, cfg.ssm_conv_width - 1, conv_ch),
                          jnp.float32),
        "ssd": jnp.zeros((n_groups, per, batch, cfg.ssm_heads,
                          cfg.ssm_head_dim, N), jnp.float32),
    }
    if tail:
        c["conv_tail"] = jnp.zeros((tail, batch, cfg.ssm_conv_width - 1, conv_ch),
                                   jnp.float32)
        c["ssd_tail"] = jnp.zeros((tail, batch, cfg.ssm_heads,
                                   cfg.ssm_head_dim, N), jnp.float32)
    return c


def cache_axes(cfg: ModelConfig) -> Dict[str, Any]:
    n_groups, per, tail = group_layout(cfg)
    kv = ("layers", "batch", "seq_kv", "kv_heads", "head_dim")
    c = {
        "k": kv, "v": kv,
        "conv": ("layers", "layers2", "batch", None, "ssm_conv_ch"),
        "ssd": ("layers", "layers2", "batch", "ssm_heads", None, None),
    }
    if tail:
        c["conv_tail"] = ("layers", "batch", None, "ssm_conv_ch")
        c["ssd_tail"] = ("layers", "batch", "ssm_heads", None, None)
    return c


def forward(params, cfg: ModelConfig, batch, *, cache=None, cache_index=None,
            remat: bool = False):
    params = T.cast_params(params, cfg)
    x = T._embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    decode = cache is not None and S == 1
    if cache_index is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    else:
        positions = (cache_index + jnp.arange(S))[None, :].astype(jnp.int32)

    n_groups, per, tail = group_layout(cfg)
    shared = params["shared_attn"]

    def mamba_apply(layer_params, x, conv_s, ssd_s):
        if cache is None:
            x, _ = M.block_fwd(layer_params, x, cfg)
            return x, conv_s, ssd_s
        if decode:
            x, (nc, ns) = M.block_decode(layer_params, x, cfg,
                                         conv_state=conv_s, ssd_state=ssd_s)
        else:
            x, (nc, ns) = M.block_fwd(layer_params, x, cfg,
                                      conv_state=conv_s, ssd_state=ssd_s)
        return x, nc, ns.astype(ssd_s.dtype)

    def group_body(x, scanned):
        if cache is None:
            group_params = scanned
            kv = None
            conv_g = jnp.zeros((per, B, cfg.ssm_conv_width - 1,
                                cfg.d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state),
                               jnp.float32)
            ssd_g = jnp.zeros((per, B, cfg.ssm_heads, cfg.ssm_head_dim,
                               cfg.ssm_state), jnp.float32)
        else:
            group_params, ck, cv, conv_g, ssd_g = scanned
            kv = (ck, cv)
        # shared attention block (same weights every group)
        x, new_kv = T.block_fwd(shared, x, cfg, positions=positions,
                                kv_cache=kv, cache_index=cache_index)

        def inner(carry, inner_scanned):
            x = carry
            lp, cs, ss = inner_scanned
            x, nc, ns = mamba_apply(lp, x, cs, ss)
            return x, (nc, ns)

        x, (ncs, nsss) = lax.scan(inner, x, (group_params, conv_g, ssd_g),
                                  unroll=inner_scan_unroll())
        if cache is None:
            return x, None
        nk, nv = new_kv
        return x, (nk, nv, ncs, nsss)

    if remat:
        group_body = jax.checkpoint(group_body,
                                    policy=jax.checkpoint_policies.nothing_saveable)

    if cache is None:
        x, _ = lax.scan(group_body, x, params["mamba_main"],
                        unroll=scan_unroll())
        new_cache = None
    else:
        x, (nk, nv, ncs, nsss) = lax.scan(
            group_body, x,
            (params["mamba_main"], cache["k"], cache["v"],
             cache["conv"], cache["ssd"]), unroll=scan_unroll())
        new_cache = {"k": nk, "v": nv, "conv": ncs, "ssd": nsss}

    if tail:
        def tail_body(x, scanned):
            if cache is None:
                lp = scanned
                x, _, _ = mamba_apply(lp, x, None, None)
                return x, None
            lp, cs, ss = scanned
            x, nc, ns = mamba_apply(lp, x, cs, ss)
            return x, (nc, ns)

        if cache is None:
            x, _ = lax.scan(tail_body, x, params["mamba_tail"],
                            unroll=inner_scan_unroll())
        else:
            x, (nct, nst) = lax.scan(
                tail_body, x,
                (params["mamba_tail"], cache["conv_tail"], cache["ssd_tail"]),
                unroll=inner_scan_unroll())
            new_cache["conv_tail"] = nct
            new_cache["ssd_tail"] = nst

    x = L.apply_norm(x, params["final_norm"], cfg.norm_type)
    return x, new_cache


def loss_fn(params, cfg: ModelConfig, batch, *, remat: bool = True):
    hidden, _ = forward(params, cfg, batch, remat=remat)
    logits = L.unembed(params["embedding"], hidden, cfg.vocab)
    return L.cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


def prefill(params, cfg: ModelConfig, batch, cache):
    hidden, new_cache = forward(params, cfg, batch, cache=cache,
                                cache_index=jnp.int32(0), remat=True)
    logits = L.unembed(params["embedding"], hidden[:, -1:, :], cfg.vocab)
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache, cache_index):
    hidden, new_cache = forward(params, cfg, {"tokens": tokens}, cache=cache,
                                cache_index=cache_index)
    logits = L.unembed(params["embedding"], hidden, cfg.vocab)
    return logits, new_cache

"""Dense decoder-only transformer LM (qwen1.5 / qwen3 / command-r / olmo /
pixtral-backbone) with scan-stacked layers, GQA, RoPE, and optional
QKV-bias / qk-norm / parallel-block / non-parametric-LN variants.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.unroll import scan_unroll
from repro.sharding.partition import constrain


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def attn_config(cfg: ModelConfig, *, causal: bool = True,
                use_rope: bool = True) -> L.AttentionConfig:
    return L.AttentionConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        rope_theta=cfg.rope_theta, sliding_window=cfg.sliding_window,
        causal=causal, use_rope=use_rope, norm_eps=cfg.norm_eps)


def mlp_config(cfg: ModelConfig) -> L.MLPConfig:
    return L.MLPConfig(d_model=cfg.d_model, d_ff=cfg.d_ff,
                       activation=cfg.mlp_activation, gated=cfg.mlp_gated)


# ---------------------------------------------------------------------------
# one transformer block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    ka, km, k1, k2 = jax.random.split(key, 4)
    p = {
        "attn": L.init_attention(ka, attn_config(cfg), dtype),
        "mlp": L.init_mlp(km, mlp_config(cfg), dtype),
        "norm1": L.init_norm(k1, cfg.d_model, cfg.norm_type, dtype),
    }
    if not cfg.parallel_block:
        p["norm2"] = L.init_norm(k2, cfg.d_model, cfg.norm_type, dtype)
    return p


def block_axes(cfg: ModelConfig) -> Dict[str, Any]:
    p = {
        "attn": L.attention_axes(attn_config(cfg)),
        "mlp": L.mlp_axes(mlp_config(cfg)),
        "norm1": L.norm_axes(cfg.norm_type),
    }
    if not cfg.parallel_block:
        p["norm2"] = L.norm_axes(cfg.norm_type)
    return p


def block_fwd(params, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array,
              kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_index: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    acfg = attn_config(cfg)
    h = L.apply_norm(x, params["norm1"], cfg.norm_type)
    attn_out, new_cache = L.attention_fwd(
        params["attn"], h, acfg, positions=positions,
        kv_cache=kv_cache, cache_index=cache_index)
    if cfg.parallel_block:
        # command-r style: MLP reads the same normed input, outputs add
        mlp_out = L.mlp_fwd(params["mlp"], h, mlp_config(cfg))
        x = x + attn_out + mlp_out
    else:
        x = x + attn_out
        h2 = L.apply_norm(x, params["norm2"], cfg.norm_type)
        x = x + L.mlp_fwd(params["mlp"], h2, mlp_config(cfg))
    x = constrain(x, "batch", "seq_q", "embed")
    return x, new_cache


def block_fwd_paged(params, x: jax.Array, cfg: ModelConfig, *,
                    positions: jax.Array, k_pages: jax.Array,
                    v_pages: jax.Array, page_table: jax.Array,
                    lengths: jax.Array):
    """``block_fwd`` for decode over a paged KV pool (one token/row)."""
    acfg = attn_config(cfg)
    h = L.apply_norm(x, params["norm1"], cfg.norm_type)
    attn_out, k_pages, v_pages = L.attention_fwd_paged(
        params["attn"], h, acfg, positions=positions,
        k_pages=k_pages, v_pages=v_pages,
        page_table=page_table, lengths=lengths)
    if cfg.parallel_block:
        mlp_out = L.mlp_fwd(params["mlp"], h, mlp_config(cfg))
        x = x + attn_out + mlp_out
    else:
        x = x + attn_out
        h2 = L.apply_norm(x, params["norm2"], cfg.norm_type)
        x = x + L.mlp_fwd(params["mlp"], h2, mlp_config(cfg))
    return x, k_pages, v_pages


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = _dtype(cfg.param_dtype)
    ke, kl, kf = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)
    p = {
        "embedding": L.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "layers": stacked,
        "final_norm": L.init_norm(kf, cfg.d_model, cfg.norm_type, dtype),
    }
    return p


def param_axes(cfg: ModelConfig) -> Dict[str, Any]:
    def lift(tree):
        # stacked layers get a leading ("layers",) axis
        return jax.tree.map(lambda ax: ("layers",) + ax, tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embedding": L.embedding_axes(),
        "layers": lift(block_axes(cfg)),
        "final_norm": L.norm_axes(cfg.norm_type),
    }


def cast_params(params, cfg: ModelConfig):
    """Cast float parameters to the compute dtype (master copies stay in
    the optimizer; norms/SSM scalars re-upcast internally where needed)."""
    dtype = _dtype(cfg.compute_dtype)
    return jax.tree.map(
        lambda w: w.astype(dtype) if jnp.issubdtype(w.dtype, jnp.floating) else w,
        params)


def _embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    """Token embedding, or precomputed frontend embeddings (vlm stub)."""
    if "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg.compute_dtype))
        return constrain(x, "batch", "seq_q", "embed")
    return L.embed(params["embedding"], batch["tokens"]).astype(
        _dtype(cfg.compute_dtype))


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            cache: Optional[Dict[str, jax.Array]] = None,
            cache_index: Optional[jax.Array] = None,
            remat: bool = False) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Returns (hidden_states, updated_cache)."""
    params = cast_params(params, cfg)
    x = _embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    if cache_index is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    else:
        positions = (cache_index + jnp.arange(S))[None, :].astype(jnp.int32)

    def body(x, scanned):
        if cache is None:
            layer_params = scanned
            kv = None
        else:
            layer_params, ck, cv = scanned
            kv = (ck, cv)
        x, new_kv = block_fwd(layer_params, x, cfg, positions=positions,
                              kv_cache=kv, cache_index=cache_index)
        if cache is None:
            return x, None
        return x, new_kv

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    if cache is None:
        x, _ = lax.scan(body, x, params["layers"], unroll=scan_unroll())
        new_cache = None
    else:
        x, (nk, nv) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]),
                               unroll=scan_unroll())
        new_cache = {"k": nk, "v": nv}

    x = L.apply_norm(x, params["final_norm"], cfg.norm_type)
    return x, new_cache


def logits_fn(params, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    return L.unembed(params["embedding"], hidden, cfg.vocab)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            remat: bool = True) -> jax.Array:
    hidden, _ = forward(params, cfg, batch, remat=remat)
    logits = logits_fn(params, cfg, hidden)
    return L.cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# KV cache management
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_axes() -> Dict[str, Any]:
    ax = ("layers", "batch", "seq_kv", "kv_heads", "head_dim")
    return {"k": ax, "v": ax}


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Run the prompt through the model, filling the cache; returns logits
    of the last position."""
    hidden, new_cache = forward(params, cfg, batch, cache=cache,
                                cache_index=jnp.int32(0), remat=True)
    logits = logits_fn(params, cfg, hidden[:, -1:, :])
    return logits, new_cache


def prefill_at(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
               cache: Dict[str, jax.Array], last_pos: jax.Array,
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Bucketed prefill: the prompt is right-padded to a bucket length,
    so the true next-token distribution sits at ``last_pos`` (the last
    *real* position), not at the padded end.  Causality keeps real
    positions blind to the trailing pads; pad K/V beyond ``last_pos``
    is garbage the consumer must mask (the paged engine never copies
    or attends past the real prompt length)."""
    hidden, new_cache = forward(params, cfg, batch, cache=cache,
                                cache_index=jnp.int32(0), remat=True)
    h_last = lax.dynamic_slice_in_dim(hidden, last_pos, 1, axis=1)
    return logits_fn(params, cfg, h_last), new_cache


def decode_step(params, cfg: ModelConfig, tokens: jax.Array,
                cache: Dict[str, jax.Array], cache_index: jax.Array,
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode: tokens (B, 1); cache_index: current length."""
    hidden, new_cache = forward(params, cfg, {"tokens": tokens},
                                cache=cache, cache_index=cache_index)
    logits = logits_fn(params, cfg, hidden)
    return logits, new_cache


def decode_paged(params, cfg: ModelConfig, tokens: jax.Array,
                 pools: Dict[str, jax.Array], page_table: jax.Array,
                 lengths: jax.Array,
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode over the shared paged KV pool.

    tokens: (B, 1); pools: {"k","v"} each (L, P, ps, KV, hd) — the
    device-side physical page pool shared by every sequence;
    page_table: (B, PMAX) int32 logical->physical; lengths: (B,) int32
    current KV length per row (idle rows: 0 + trash-page table entries).
    Returns (logits (B, 1, V), updated pools).
    """
    params = cast_params(params, cfg)
    x = _embed_inputs(params, cfg, {"tokens": tokens})
    positions = lengths[:, None].astype(jnp.int32)          # (B, 1)

    def body(x, scanned):
        layer_params, kp, vp = scanned
        x, kp, vp = block_fwd_paged(layer_params, x, cfg,
                                    positions=positions,
                                    k_pages=kp, v_pages=vp,
                                    page_table=page_table, lengths=lengths)
        return x, (kp, vp)

    x, (nk, nv) = lax.scan(body, x, (params["layers"], pools["k"],
                                     pools["v"]), unroll=scan_unroll())
    x = L.apply_norm(x, params["final_norm"], cfg.norm_type)
    return logits_fn(params, cfg, x), {"k": nk, "v": nv}

"""Global scan-unroll switch for cost-exact dry-run lowering.

XLA's HloCostAnalysis counts a ``while`` body ONCE regardless of trip
count, so rolled layer scans under-report flops/bytes/collective traffic
(verified empirically — EXPERIMENTS.md §Roofline-methodology).  Two
remedies, selected by mode:

* ``full``  — unroll layer scans completely (exact, expensive compile);
* ``k=1 / k=2`` — lower twice with ``unroll=k``; since the emitted HLO
  contains exactly k body copies, cost(k) = outside + k*body is affine
  in k, and the true cost is outside + trips*body.  Two cheap compiles
  replace one gigantic one (this is what the dry-run does by default).

Inner scans with small trip counts (zamba's 6-layer groups / 3-layer
tail) always unroll fully in any non-off mode so they land in the
measured body/outside exactly.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Union

Mode = Union[str, int]   # "off" | "full" | k (int)


class _State(threading.local):
    def __init__(self):
        self.mode: Mode = "off"


_STATE = _State()


@contextlib.contextmanager
def unroll_mode(mode: Mode):
    prev = _STATE.mode
    _STATE.mode = mode
    try:
        yield
    finally:
        _STATE.mode = prev


# back-compat alias used by earlier call sites
@contextlib.contextmanager
def unrolled_scans(on: bool = True):
    with unroll_mode("full" if on else "off"):
        yield


def scan_unroll():
    """unroll= value for LAYER scans (the extrapolated dimension)."""
    m = _STATE.mode
    if m == "off":
        return 1
    if m == "full":
        return True
    return int(m)


def inner_scan_unroll():
    """unroll= value for small fixed inner scans (always exact)."""
    return 1 if _STATE.mode == "off" else True

"""Deterministic sharded data pipeline with exact-resume state.

Production constraints honored here:
  * each host loads only its shard of the global batch (per-process
    loading on a multi-host mesh);
  * the stream is a pure function of (seed, step) — restart at step k
    reproduces the same batches with no replay log;
  * pipeline state is two integers, carried in every checkpoint;
  * background prefetch with a bounded queue (overlaps host->device).

The corpus is synthetic (a mixture of Zipf-distributed token n-gram
"documents") — the assignment requires the substrate, not a dataset.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


@dataclasses.dataclass
class PipelineState:
    step: int = 0
    seed: int = 0

    def to_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed}

    @staticmethod
    def from_dict(d: Dict[str, int]) -> "PipelineState":
        return PipelineState(step=int(d["step"]), seed=int(d["seed"]))


def _batch_for_step(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Pure function of (cfg.seed, step, host): the resume guarantee."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
    B, S = cfg.host_batch, cfg.seq_len
    # Zipf tokens, clipped into vocab; documents delimited by token 0
    toks = rng.zipf(cfg.zipf_a, size=(B, S + 1)).astype(np.int64)
    toks = np.minimum(toks, cfg.vocab - 1).astype(np.int32)
    doc_ends = rng.random((B, S + 1)) < (1.0 / 512)
    toks = np.where(doc_ends, 0, toks)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class DataPipeline:
    """Iterator with deterministic state + background prefetch."""

    def __init__(self, cfg: DataConfig, state: Optional[PipelineState] = None,
                 prefetch: int = 2):
        self.cfg = cfg
        self.state = state or PipelineState(seed=cfg.seed)
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, prefetch))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- synchronous API ---------------------------------------------------
    def next_batch(self) -> Dict[str, np.ndarray]:
        b = _batch_for_step(self.cfg, self.state.step)
        self.state.step += 1
        return b

    def peek_step(self, step: int) -> Dict[str, np.ndarray]:
        return _batch_for_step(self.cfg, step)

    # -- prefetching API ---------------------------------------------------
    def start(self):
        def worker():
            step = self.state.step
            while not self._stop.is_set():
                try:
                    self._q.put((step, _batch_for_step(self.cfg, step)),
                                timeout=0.1)
                    step += 1
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        return self

    def get(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.state.step = step + 1
        return batch

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)

"""jax version-compatibility shims.

The codebase targets the current jax API (``jax.shard_map`` with
``axis_names``/``check_vma``, ``jax.set_mesh``, ``pltpu.CompilerParams``)
but must also run on jax 0.4.x containers (``jax.experimental.shard_map``
with ``auto``/``check_rep``, context-manager ``Mesh``,
``pltpu.TPUCompilerParams``).  Everything version-sensitive funnels
through here.
"""

from __future__ import annotations

import contextlib
from typing import Iterable, Optional

import jax

try:  # jax >= 0.6: top-level shard_map with axis_names/check_vma
    from jax import shard_map as _new_shard_map
    _OLD_API = False
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _old_shard_map
    _OLD_API = True

# Old XLA hard-CHECKs (IsManualSubgroup) when buffer donation meets a
# partially-manual shard_map; callers gate donation on this.
IS_OLD_JAX = _OLD_API


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = True,
              manual_axes: Optional[Iterable[str]] = None):
    """shard_map across jax versions.

    ``manual_axes``: axes handled manually by ``f`` (the rest stay auto /
    GSPMD).  None = all mesh axes manual.  ``check`` maps to
    ``check_vma`` (new) / ``check_rep`` (old).
    """
    if not _OLD_API:
        kw = {}
        if manual_axes is not None:
            kw["axis_names"] = set(manual_axes)
        return _new_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check, **kw)
    kw = {}
    if manual_axes is not None:
        kw["auto"] = frozenset(set(mesh.axis_names) - set(manual_axes))
    return _old_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check, **kw)


def mesh_context(mesh):
    """``jax.set_mesh`` when available; on 0.4.x the Mesh object itself is
    the context manager that scopes GSPMD lowering."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(type(mesh), "__enter__"):
        return mesh
    return contextlib.nullcontext()  # pragma: no cover


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` is missing on 0.4.x; a psum of ones is the
    portable spelling (constant-folded by XLA)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a per-device list on jax
    0.4.x and a flat dict on newer jax; normalize to a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def tpu_compiler_params(**kwargs):
    """pltpu.CompilerParams (new) / TPUCompilerParams (0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)

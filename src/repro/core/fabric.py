"""Fabric model for ScalePool: links, switches, topologies.

This module implements the paper's §6 methodology: "link latency derived
from flit sizes, PHY layer characteristics, and packetization and queuing
behaviors at both link and transaction layers. Switch latencies were
determined using empirical measurements ... factoring in the hop counts
required for endpoint-to-endpoint communication."

Everything here is a *pure-python analytical model* (Leg A of DESIGN.md).
The real-JAX distribution layer (Leg B) lives in ``repro.core.hierarchy``.

Units: bytes, seconds, GB/s (1e9 bytes/s). All latencies stored in seconds.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from dataclasses import dataclass
from typing import Optional

NS = 1e-9
US = 1e-6
MS = 1e-3
GB = 1e9


class Protocol(enum.Enum):
    """Interconnect protocol families discussed in the paper (Table 1)."""

    NVLINK = "nvlink"          # XLink: proprietary PHY, 48-272B flits
    UALINK = "ualink"          # XLink: Ethernet PHY, fixed 640B flits
    CXL = "cxl"                # PCIe PHY, 256B PBR flits, cache coherent
    INFINIBAND = "infiniband"  # scale-out RDMA baseline
    PCIE = "pcie"              # host attach
    DDR = "ddr"                # plain CPU-attached memory channel


@dataclass(frozen=True)
class LinkSpec:
    """A point-to-point link: PHY + link-layer framing characteristics.

    ``flit_bytes``      - wire size of one flit.
    ``flit_payload``    - payload bytes carried per flit (flit minus CRC,
                          headers, sequence numbers).  Packetization
                          efficiency = flit_payload / flit_bytes.
    ``phy_latency``     - one-way PHY+SerDes propagation latency.
    ``sw_overhead``     - *per-transfer* software involvement.  Zero for
                          hardware-coherent fabrics (CXL) and XLink DMA;
                          microseconds for RDMA verbs (QP doorbell, memory
                          registration amortized, completion polling,
                          communicator synchronization).
    """

    name: str
    protocol: Protocol
    bandwidth: float            # GB/s per direction, per link
    phy_latency: float          # seconds
    flit_bytes: int
    flit_payload: int
    sw_overhead: float = 0.0    # seconds per transfer (software stack)
    # RDMA-style stacks re-enter software per posted work request; large
    # transfers are chunked into quanta that each pay (part of) the
    # overhead.  None = fully offloaded hardware DMA (XLink, CXL).
    message_quantum: Optional[int] = None

    @property
    def efficiency(self) -> float:
        return self.flit_payload / self.flit_bytes

    def wire_bytes(self, payload: int) -> int:
        """Bytes actually serialized on the wire for ``payload`` bytes."""
        if payload <= 0:
            return 0
        nflits = math.ceil(payload / self.flit_payload)
        return nflits * self.flit_bytes

    def serialization_time(self, payload: int) -> float:
        return self.wire_bytes(payload) / (self.bandwidth * GB)


@dataclass(frozen=True)
class SwitchSpec:
    """A switching element.  ``hop_latency`` is port-to-port measured
    latency (the paper uses silicon-prototype measurements for CXL)."""

    name: str
    hop_latency: float          # seconds per traversal
    radix: int                  # ports
    per_port_bandwidth: float   # GB/s


class TopologyKind(enum.Enum):
    SINGLE_HOP = "single_hop"       # XLink one-stage Clos / full mesh
    MULTI_CLOS = "multi_level_clos" # CXL cascaded switches
    TORUS3D = "3d_torus"
    DRAGONFLY = "dragonfly"


@dataclass(frozen=True)
class Topology:
    """Endpoint-count → hop-count model for each fabric shape.

    The paper's CXL fabrics use PBR + switch cascading to build
    multi-level Clos / 3D-torus / DragonFly structures; XLink is
    restricted to single-hop.
    """

    kind: TopologyKind
    endpoints: int
    switch: SwitchSpec
    # Oversubscription factor >= 1.0: ratio of ingress to uplink capacity
    # at each level (1.0 = full bisection).
    oversubscription: float = 1.0

    def hops(self) -> int:
        """Worst-case switch traversals endpoint-to-endpoint."""
        n, r = self.endpoints, self.switch.radix
        if self.kind == TopologyKind.SINGLE_HOP:
            return 1
        if self.kind == TopologyKind.MULTI_CLOS:
            # Folded Clos: levels = ceil(log_{r/2}(n)); up-down path
            # traverses (2*levels - 1) switches.
            if n <= r:
                return 1
            levels = max(1, math.ceil(math.log(n) / math.log(max(2, r // 2))))
            return 2 * levels - 1
        if self.kind == TopologyKind.TORUS3D:
            # average hop distance ~ 3 * (n^(1/3)) / 4 per dimension sum
            side = max(1, round(n ** (1.0 / 3.0)))
            return max(1, 3 * side // 4)
        if self.kind == TopologyKind.DRAGONFLY:
            # canonical minimal route: local - global - local
            return 3 if n > self.switch.radix else 1
        raise ValueError(self.kind)

    def switching_latency(self) -> float:
        return self.hops() * self.switch.hop_latency

    def effective_bandwidth(self, link: LinkSpec) -> float:
        """Per-endpoint sustainable bandwidth through the fabric (GB/s)."""
        return min(link.bandwidth, self.switch.per_port_bandwidth) / self.oversubscription


@dataclass(frozen=True)
class FabricSpec:
    """A complete fabric: link + topology (+ queuing model).

    ``load`` in [0,1) feeds an M/D/1-style queuing inflation factor
    ``1 + load/(2*(1-load))`` applied to serialization time — the
    "queuing behaviors at link and transaction layers" of §6.
    """

    name: str
    link: LinkSpec
    topology: Topology
    load: float = 0.30

    def queuing_factor(self) -> float:
        rho = min(max(self.load, 0.0), 0.95)
        return 1.0 + rho / (2.0 * (1.0 - rho))

    def transfer_time(self, payload_bytes: int, *, contention: float = 1.0) -> float:
        """End-to-end one-way time for a single message of ``payload_bytes``.

        contention >= 1.0 divides effective bandwidth (e.g. ring steps where
        multiple flows share a link).
        """
        link = self.link
        bw = self.topology.effective_bandwidth(link) / contention
        wire = link.wire_bytes(payload_bytes)
        serialization = wire / (bw * GB) * self.queuing_factor()
        if link.message_quantum and payload_bytes > link.message_quantum:
            # per-quantum software involvement (work-request posting,
            # completion handling) — partially pipelined, so charge it as
            # added per-byte resistance rather than a serial stall.
            serialization += payload_bytes * (link.sw_overhead / link.message_quantum)
        return (
            link.sw_overhead
            + link.phy_latency
            + self.topology.switching_latency()
            + serialization
        )

    def latency(self) -> float:
        """Zero-byte message latency (the 'link latency' of Table 1)."""
        return self.link.sw_overhead + self.link.phy_latency + self.topology.switching_latency()

    def bandwidth(self) -> float:
        """Effective large-message bandwidth (GB/s) incl. flit efficiency
        and (for RDMA) per-quantum software overhead."""
        base_bps = (
            self.topology.effective_bandwidth(self.link)
            * self.link.efficiency
            / self.queuing_factor()
            * GB
        )
        time_per_byte = 1.0 / base_bps
        if self.link.message_quantum:
            time_per_byte += self.link.sw_overhead / self.link.message_quantum
        return 1.0 / time_per_byte / GB


# ---------------------------------------------------------------------------
# Catalog: concrete link/switch constants.
#
# Sources: paper Table 1 + §2 (UALink 100 GB/s/port sub-us, NVLink <500ns,
# flit sizes 640B / 48-272B), CXL 3.x 256B PBR flits on PCIe6 x16
# (~121 GB/s/dir), NDR InfiniBand 400 Gb/s (~50 GB/s).  RDMA software
# overhead models verbs posting + completion + communicator synchronization
# (the paper's "software interventions are inevitable").
# ---------------------------------------------------------------------------

NVLINK5 = LinkSpec(
    name="NVLink 5.0",
    protocol=Protocol.NVLINK,
    bandwidth=900.0,            # GB/s per GPU direction (18 links x 50GB/s)
    phy_latency=300 * NS,
    flit_bytes=272,
    flit_payload=256,
    sw_overhead=0.0,
)

UALINK200 = LinkSpec(
    name="UALink 200G",
    protocol=Protocol.UALINK,
    bandwidth=100.0,            # GB/s per port
    phy_latency=600 * NS,       # sub-microsecond, Ethernet PHY
    flit_bytes=640,
    flit_payload=576,
    sw_overhead=0.0,
)

CXL3 = LinkSpec(
    name="CXL 3.x x16",
    protocol=Protocol.CXL,
    bandwidth=121.0,            # PCIe6 x16 per direction
    phy_latency=150 * NS,
    flit_bytes=256,
    flit_payload=236,
    sw_overhead=0.0,            # hardware coherent: no software on data path
)

# Coherence-centric CXL (tier-1 glue): trimmed flit processing, §5.
CXL_COHERENCE = dataclasses.replace(CXL3, name="CXL coherence-centric", phy_latency=100 * NS)

# Capacity-oriented CXL (tier-2): CXL.io/mem bulk path, §5.
CXL_CAPACITY = dataclasses.replace(CXL3, name="CXL capacity-oriented", phy_latency=180 * NS)

INFINIBAND_NDR = LinkSpec(
    name="InfiniBand NDR",
    protocol=Protocol.INFINIBAND,
    bandwidth=50.0,             # 400 Gb/s
    phy_latency=1.0 * US,       # end-to-end NIC-to-NIC port latency
    flit_bytes=4096 + 66,       # MTU-sized packets + headers
    flit_payload=4096,
    sw_overhead=6.0 * US,       # RDMA verbs + sync across communicators
    message_quantum=512 * 1024, # collective-library pipeline slice
)

PCIE5_HOST = LinkSpec(
    name="PCIe5 x16 host",
    protocol=Protocol.PCIE,
    bandwidth=63.0,
    phy_latency=400 * NS,
    flit_bytes=256,
    flit_payload=224,
    sw_overhead=0.0,
)

DDR5_LOCAL = LinkSpec(
    name="DDR5 CPU-attached",
    protocol=Protocol.DDR,
    bandwidth=307.0,            # 8 channels DDR5-4800
    phy_latency=90 * NS,
    flit_bytes=64,
    flit_payload=64,
    sw_overhead=0.0,
)

NVSWITCH = SwitchSpec("NVSwitch", hop_latency=100 * NS, radix=72, per_port_bandwidth=900.0)
UASWITCH = SwitchSpec("UALink switch", hop_latency=150 * NS, radix=72, per_port_bandwidth=100.0)
CXL_SWITCH = SwitchSpec("CXL PBR switch", hop_latency=250 * NS, radix=64, per_port_bandwidth=121.0)
IB_SWITCH = SwitchSpec("IB NDR switch", hop_latency=300 * NS, radix=64, per_port_bandwidth=50.0)


def xlink_cluster_fabric(n_accel: int = 72, link: LinkSpec = NVLINK5) -> FabricSpec:
    """Intra-cluster XLink fabric: one-stage switched, rack scale (§4)."""
    switch = NVSWITCH if link.protocol == Protocol.NVLINK else UASWITCH
    topo = Topology(TopologyKind.SINGLE_HOP, endpoints=n_accel, switch=switch)
    return FabricSpec(name=f"XLink[{link.name}]x{n_accel}", link=link, topology=topo)


def cxl_fabric(
    n_endpoints: int,
    kind: TopologyKind = TopologyKind.MULTI_CLOS,
    link: LinkSpec = CXL3,
    oversubscription: float = 1.0,
) -> FabricSpec:
    """Inter-cluster hierarchical CXL fabric (§4: Clos/3D-torus/DragonFly)."""
    topo = Topology(kind, endpoints=n_endpoints, switch=CXL_SWITCH,
                    oversubscription=oversubscription)
    return FabricSpec(name=f"CXL[{kind.value}]x{n_endpoints}", link=link, topology=topo)


def infiniband_fabric(n_endpoints: int, oversubscription: float = 1.0) -> FabricSpec:
    """Scale-out RDMA fabric (the paper's baseline inter-cluster path)."""
    topo = Topology(TopologyKind.MULTI_CLOS, endpoints=n_endpoints,
                    switch=IB_SWITCH, oversubscription=oversubscription)
    return FabricSpec(name=f"IB[NDR]x{n_endpoints}", link=INFINIBAND_NDR, topology=topo)


def tier2_memory_fabric(n_endpoints: int) -> FabricSpec:
    """Dedicated capacity-oriented CXL fabric to CPU-less memory nodes (§5)."""
    topo = Topology(TopologyKind.MULTI_CLOS, endpoints=n_endpoints, switch=CXL_SWITCH)
    return FabricSpec(name=f"Tier2-CXL x{n_endpoints}", link=CXL_CAPACITY, topology=topo)


@dataclass(frozen=True)
class MemoryTierSpec:
    """A memory tier as seen from one accelerator (§5)."""

    name: str
    capacity_bytes: float            # per accelerator-visible pool
    access_latency: float            # seconds, small-granule access
    bandwidth: float                 # GB/s streaming
    sw_overhead: float = 0.0         # software-managed copies, page faults

    def access_time(self, nbytes: int) -> float:
        return self.sw_overhead + self.access_latency + nbytes / (self.bandwidth * GB)


def hbm_tier(capacity_gb: float = 192.0) -> MemoryTierSpec:
    # GB200-class accelerator HBM3e
    return MemoryTierSpec("HBM(local)", capacity_gb * GB, 120 * NS, 8000.0)


def cluster_xlink_tier(fabric: FabricSpec, capacity_gb: float, *, coherent: bool,
                       copy_sw_overhead: float = 0.6 * US,
                       coherence_overhead: float = 200 * NS) -> MemoryTierSpec:
    """Peer-accelerator memory within a cluster.  Reads are round trips.

    Non-coherent XLink requires explicit software-managed copies
    (paper §5 tier-1 discussion: "sharing data beyond static partitions
    requires explicit software-managed copying"); coherence-centric CXL
    removes the software overhead and accesses at instruction granularity
    but pays directory/snoop time.
    """
    lat = 2.0 * fabric.latency() + (coherence_overhead if coherent else 0.0)
    return MemoryTierSpec(
        name=("Tier1-coherent" if coherent else "XLink-peer(non-coherent)"),
        capacity_bytes=capacity_gb * GB,
        access_latency=lat,
        bandwidth=fabric.bandwidth(),
        sw_overhead=0.0 if coherent else copy_sw_overhead,
    )


def tier2_pool_tier(fabric: FabricSpec, capacity_gb: float = 4096.0) -> MemoryTierSpec:
    """Capacity-oriented tier-2 pool on dedicated memory nodes (§5)."""
    return MemoryTierSpec("Tier2-pool", capacity_gb * GB,
                          2.0 * fabric.latency() + 150 * NS,  # media+controller
                          fabric.bandwidth())


def rdma_storage_tier(fabric: FabricSpec, capacity_gb: float = 1 << 20) -> MemoryTierSpec:
    """Baseline spill target beyond cluster memory: RDMA to remote hosts /
    distributed FS (paper: 'millisecond- to second-level latencies' for
    storage; RDMA-to-host-DRAM is the favourable case we model)."""
    hw_latency = fabric.link.phy_latency + fabric.topology.switching_latency()
    return MemoryTierSpec("RDMA-remote", capacity_gb * GB,
                          2.0 * hw_latency, fabric.bandwidth(),
                          sw_overhead=fabric.link.sw_overhead)


# ---------------------------------------------------------------------------
# Thin re-export shim for the routed-fabric package.  The *routed* graph
# (endpoint topology, min-hop routes, contended link sharing) lives in
# ``repro.fabric``; this module keeps the per-link analytical models it
# builds on.  ``Topology`` here remains the endpoint-count -> hop-count
# closed form above; the node/edge graph is exposed as ``TopologyGraph``.
# Lazy to avoid a core <-> fabric import cycle.
# ---------------------------------------------------------------------------

def __getattr__(name: str):
    if name in ("Transport", "Route", "Link", "TopologyGraph"):
        import repro.fabric as _routed
        return {"Transport": _routed.Transport, "Route": _routed.Route,
                "Link": _routed.Link,
                "TopologyGraph": _routed.Topology}[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

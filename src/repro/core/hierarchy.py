"""Hierarchical (fabric-aware) collectives — ScalePool's communication
schedule realized with shard_map + jax.lax collectives.

The paper's §4: bulk intra-cluster data movement stays on the fast XLink
fabric; only the reduced shard crosses the inter-cluster CXL fabric.  On
a TPU mesh this maps to:

    phase 1: reduce-scatter over the intra-pod axes  ("data")
    phase 2: all-reduce across pods                  ("pod")
    phase 3: all-gather over the intra-pod axes      ("data")

Compared to one flat all-reduce over (pod × data), the cross-pod fabric
carries 1/|data| of the bytes — the structural source of the paper's
inter-cluster communication win (§6: 3.79x).

Optionally, phase 2 compresses with error-feedback int8 (the gradient
traffic crossing the slow fabric tolerates quantization; residuals are
fed back next step).
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import axis_size as _axis_size
from repro.core.compat import shard_map as _shard_map


# ---------------------------------------------------------------------------
# explicit collectives on flat buffers (benchmark + unit-test surface)
# ---------------------------------------------------------------------------

def flat_allreduce(x: jax.Array, mesh: Mesh, axes: Tuple[str, ...]) -> jax.Array:
    """Baseline: one psum spanning all given mesh axes (the 'RDMA-era'
    topology-oblivious collective)."""

    def f(xs):
        return jax.lax.psum(xs, axes)

    return _shard_map(f, mesh=mesh, in_specs=P(axes), out_specs=P(axes))(x)


def hierarchical_allreduce(x: jax.Array, mesh: Mesh, *,
                           intra_axis: str = "data",
                           inter_axis: str = "pod") -> jax.Array:
    """Two-level all-reduce: RS(intra) → AR(inter) → AG(intra).

    x is sharded over (inter, intra) on its leading dim; returns the same
    sharding with globally-reduced values.  Mathematically identical to
    ``flat_allreduce`` over both axes (tested), but the inter-axis fabric
    only carries 1/|intra| of the buffer.
    """

    def f(xs):
        # xs: local shard, shape (n, ...)
        n_intra = _axis_size(intra_axis)
        # phase 1: reduce-scatter along intra axis over the leading dim
        shard = jax.lax.psum_scatter(xs, intra_axis, scatter_dimension=0,
                                     tiled=True)
        # phase 2: all-reduce the 1/n_intra shard across pods
        shard = jax.lax.psum(shard, inter_axis)
        # phase 3: all-gather back along intra
        return jax.lax.all_gather(shard, intra_axis, axis=0, tiled=True)

    return _shard_map(f, mesh=mesh, in_specs=P((inter_axis, intra_axis)),
                      out_specs=P((inter_axis, intra_axis)))(x)


# ---------------------------------------------------------------------------
# error-feedback int8 compression for the inter-pod phase
# ---------------------------------------------------------------------------

def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_cross_pod_mean(x: jax.Array, axis_name: str,
                              residual: Optional[jax.Array] = None,
                              ) -> Tuple[jax.Array, jax.Array]:
    """Mean-reduce across pods with int8 error-feedback compression.

    Returns (reduced, new_residual).  Inside shard_map with ``axis_name``
    manual.  Error feedback: the quantization error is carried to the
    next step so the compression is unbiased over time.
    """
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual
    # SHARED quantization scale across pods (a scalar pmax — negligible
    # traffic) so the int32 psum of codes is an exact sum of quantized
    # values: sum_i(q_i) * scale == sum_i(q_i * scale).
    local_max = jnp.max(jnp.abs(xf))
    gmax = jax.lax.pmax(local_max, axis_name)
    scale = gmax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_residual = xf - q.astype(jnp.float32) * scale
    # int8 payload crosses the slow fabric; psum in int32 to avoid overflow
    n = jax.lax.psum(1, axis_name)
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    out = (summed.astype(jnp.float32) * scale / n).astype(x.dtype)
    return out, new_residual


def cross_pod_mean(x: jax.Array, axis_name: str) -> jax.Array:
    return jax.lax.pmean(x, axis_name)


# ---------------------------------------------------------------------------
# gradient-tree reduction for the training step
# ---------------------------------------------------------------------------

def reduce_gradients_hierarchically(grads: Any, *, inter_axis: str = "pod",
                                    compress: bool = False,
                                    residuals: Optional[Any] = None,
                                    ) -> Tuple[Any, Optional[Any]]:
    """Cross-pod gradient reduction, called INSIDE a shard_map whose manual
    axis is ``inter_axis`` (intra-pod reduction is handled by GSPMD on the
    auto axes — the XLink domain).

    With ``compress=True``, the inter-pod phase moves int8 + per-tensor
    scales (4x fewer bytes on the paper's CXL fabric), with error
    feedback carried in ``residuals``.
    """
    if not compress:
        return jax.tree.map(lambda g: cross_pod_mean(g, inter_axis), grads), None
    if residuals is None:
        residuals = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    flat_g, tree = jax.tree.flatten(grads)
    flat_r = tree.flatten_up_to(residuals)
    outs, news = [], []
    for g, r in zip(flat_g, flat_r):
        o, nr = compressed_cross_pod_mean(g, inter_axis, r)
        outs.append(o)
        news.append(nr)
    return tree.unflatten(outs), tree.unflatten(news)

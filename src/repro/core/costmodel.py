"""Collective-communication and transfer cost model over fabrics/routes.

Implements standard alpha-beta collective algorithms (ring / tree /
hierarchical two-level) on top of ``repro.core.fabric`` transfer-time
primitives, plus the hierarchical ScalePool schedule the paper's §4
describes: bulk intra-cluster movement on XLink, inter-cluster phase on
the CXL fabric, with no software stack on the data path.

Every function takes a ``Fabric`` — anything implementing the
``transfer_time(nbytes, contention=...)`` contract.  That is either
the legacy closed-form ``core.fabric.FabricSpec`` OR a routed
``repro.fabric.Route`` from ``Topology.route(src, dst)``, so collective
costs can be priced on the actual hop list between two endpoints of
the estate graph (per-hop latency accumulates; serialization is paid
at the route's bottleneck link) instead of a whole-fabric aggregate.

All functions return seconds.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Optional, Union

from repro.core.fabric import FabricSpec

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.fabric import Route

# anything pricing transfer_time(nbytes, contention=): a closed-form
# fabric spec or a routed hop list over the estate graph
Fabric = Union[FabricSpec, "Route"]

GB = 1e9


def p2p_time(fabric: Fabric, nbytes: int) -> float:
    """One point-to-point message (pipeline-parallel activations, KV ship)."""
    return fabric.transfer_time(nbytes)


def ring_allreduce_time(fabric: Fabric, nbytes: int, n: int) -> float:
    """Ring all-reduce of an ``nbytes`` buffer over ``n`` ranks.

    2*(n-1) steps, each moving nbytes/n per rank.  Latency term pays the
    fabric latency per step (this is what kills RDMA at small buffers —
    each step re-enters the software stack)."""
    if n <= 1 or nbytes <= 0:
        return 0.0
    chunk = max(1, math.ceil(nbytes / n))
    steps = 2 * (n - 1)
    return steps * fabric.transfer_time(chunk)


def reduce_scatter_time(fabric: Fabric, nbytes: int, n: int) -> float:
    if n <= 1 or nbytes <= 0:
        return 0.0
    chunk = max(1, math.ceil(nbytes / n))
    return (n - 1) * fabric.transfer_time(chunk)


def all_gather_time(fabric: Fabric, nbytes: int, n: int) -> float:
    """All-gather where each rank ends with ``nbytes`` total (ring)."""
    if n <= 1 or nbytes <= 0:
        return 0.0
    chunk = max(1, math.ceil(nbytes / n))
    return (n - 1) * fabric.transfer_time(chunk)


def tree_allreduce_time(fabric: Fabric, nbytes: int, n: int) -> float:
    """Binary-tree reduce+broadcast — latency-optimal for small buffers."""
    if n <= 1 or nbytes <= 0:
        return 0.0
    depth = math.ceil(math.log2(n))
    return 2 * depth * fabric.transfer_time(nbytes)


def allreduce_time(fabric: Fabric, nbytes: int, n: int) -> float:
    """Best of ring / tree (what a tuned collective library would pick)."""
    if n <= 1 or nbytes <= 0:
        return 0.0
    return min(ring_allreduce_time(fabric, nbytes, n),
               tree_allreduce_time(fabric, nbytes, n))


def all_to_all_time(fabric: Fabric, nbytes_per_rank: int, n: int) -> float:
    """All-to-all (MoE dispatch): each rank sends nbytes_per_rank to each
    other rank; serialized through its single injection port."""
    if n <= 1 or nbytes_per_rank <= 0:
        return 0.0
    return (n - 1) * fabric.transfer_time(nbytes_per_rank)


@dataclass(frozen=True)
class HierarchicalDomains:
    """Two-level communication domain: ``intra`` fabric groups of size
    ``intra_size`` stitched by an ``inter`` fabric across ``n_groups``."""

    intra: Fabric
    inter: Fabric
    intra_size: int
    n_groups: int

    @property
    def world(self) -> int:
        return self.intra_size * self.n_groups


def hierarchical_allreduce_time(dom: HierarchicalDomains, nbytes: int) -> float:
    """ScalePool schedule (also the classic NCCL 2-level algorithm):

      1. reduce-scatter inside each XLink cluster        (fast fabric)
      2. all-reduce of the 1/intra_size shard across clusters (CXL/IB)
      3. all-gather inside each cluster                  (fast fabric)

    The inter-cluster fabric only ever carries nbytes/intra_size per
    endpoint — this is the structural reason ScalePool's comm win is
    larger than the raw link-speed ratio."""
    if dom.world <= 1 or nbytes <= 0:
        return 0.0
    t = reduce_scatter_time(dom.intra, nbytes, dom.intra_size)
    shard = max(1, math.ceil(nbytes / max(1, dom.intra_size)))
    t += allreduce_time(dom.inter, shard, dom.n_groups)
    t += all_gather_time(dom.intra, nbytes, dom.intra_size)
    return t


def flat_allreduce_time(dom: HierarchicalDomains, nbytes: int) -> float:
    """Baseline: one flat ring spanning all ranks; every step bounded by the
    slowest fabric it crosses (inter-cluster links dominate)."""
    if dom.world <= 1 or nbytes <= 0:
        return 0.0
    chunk = max(1, math.ceil(nbytes / dom.world))
    # 2*(world-1) ring steps; a fraction (n_groups/world) of the links on
    # the ring are inter-cluster, but ring progress is lock-step: each step
    # completes at the pace of the slowest link in the ring.
    steps = 2 * (dom.world - 1)
    return steps * dom.inter.transfer_time(chunk)


def broadcast_time(fabric: Fabric, nbytes: int, n: int) -> float:
    if n <= 1 or nbytes <= 0:
        return 0.0
    return math.ceil(math.log2(n)) * fabric.transfer_time(nbytes)


def offload_roundtrip_time(tier_bw_gbps: float, tier_latency: float,
                           nbytes: int, sw_overhead: float = 0.0) -> float:
    """Write-then-read of an offloaded buffer (optimizer state shuttle)."""
    if nbytes <= 0:
        return 0.0
    one_way = sw_overhead + tier_latency + nbytes / (tier_bw_gbps * GB)
    return 2.0 * one_way


# ---------------------------------------------------------------------------
# routed in-flight pricing (repro.colo): base + contention stretch
# ---------------------------------------------------------------------------

def phase_volume(base_s: float, route: "Route") -> float:
    """Payload bytes whose *solo* transfer on ``route`` lasts exactly
    ``base_s`` seconds — the volume to register on a ``Transport`` so a
    closed-form collective phase occupies its route for its legacy
    duration.  Zero when the phase is shorter than the route latency
    (nothing meaningful to serialize)."""
    if base_s <= route.latency():
        return 0.0
    return (base_s - route.latency()) * route.bottleneck_bw


def routed_phase_time(transport, route: "Route", base_s: float,
                      t: float, *, label: Optional[str] = None) -> float:
    """Price one collective phase of legacy closed-form duration
    ``base_s`` as an in-flight transfer beginning at modeled time ``t``
    on a shared ``fabric.Transport``: the phase max-min shares links
    with everything else in flight (serving spill/fetch traffic,
    other jobs' collectives) and comes back stretched accordingly.

    Bit-exactness contract (the fig6 regression pins this): the return
    value is ``base_s`` plus the *contention stretch only*, where the
    stretch compares the transport's duration against the identical
    float expression the transport's solo fast path evaluates
    (``route.latency() + v / route.bottleneck_bw``).  Re-deriving the
    solo time from ``base_s`` instead would leak one float rounding
    per phase (``(x * bw) / bw != x``) into every uncontended step.
    """
    v = phase_volume(base_s, route)
    if v <= 0.0:
        return base_s
    dur = transport.transfer_s(route, v, t, label=label)
    solo = route.latency() + v / route.bottleneck_bw
    return base_s + max(0.0, dur - solo)

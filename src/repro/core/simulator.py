"""Calculon-style [41] high-level LLM training co-design simulator.

Reproduces the paper's §6 evaluation:

* Figure 6 — end-to-end LLM training step time for five transformer LLMs
  (GPT-3, Gopher, Llama 3, PaLM, Megatron), decomposed into communication
  / computation / other (pipeline bubble + offloading), under

    - ``baseline``   : XLink intra-rack + InfiniBand RDMA inter-rack
    - ``scalepool``  : XLink intra-rack + hierarchical CXL fabric inter-rack
                       + tier-2 CXL memory pool for offload traffic

* Figure 7 — average access latency of a memory-intensive workload vs
  working-set size for ``baseline`` / ``accel_clusters`` / ``tiered``
  (ScalePool) configurations.

The simulator is deliberately analytical (the paper's own methodology):
latencies come from ``repro.core.fabric`` link/switch models, collectives
from ``repro.core.costmodel``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional

from repro.core import costmodel as cm
from repro.core import fabric as fb

GB = 1e9
TFLOP = 1e12


# ---------------------------------------------------------------------------
# Workload + system description
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LLMConfig:
    """Transformer LLM as in each model's original paper."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    seq_len: int
    n_params: float  # use published count (more honest than re-derivation)

    def flops_per_token(self) -> float:
        # 6N matmul flops/token (fwd 2N + bwd 4N) + attention term
        attn = 12.0 * self.n_layers * self.d_model * self.seq_len  # fwd+bwd, causal-halved
        return 6.0 * self.n_params + attn


@dataclass(frozen=True)
class ParallelismConfig:
    tp: int
    pp: int
    dp: int
    global_batch_seqs: int
    microbatch_seqs: int = 1
    vpp: int = 1  # virtual pipeline stages (interleaved 1F1B) per device

    @property
    def n_gpus(self) -> int:
        return self.tp * self.pp * self.dp

    @property
    def n_micro(self) -> int:
        per_replica = self.global_batch_seqs // self.dp
        return max(1, per_replica // self.microbatch_seqs)


@dataclass(frozen=True)
class Calibration:
    """Hardware constants subject to calibration (documented in
    EXPERIMENTS.md).  Model/parallelism configs are never calibrated."""

    gpu_peak_tflops: float = 2250.0     # B200-class dense bf16
    mfu: float = 0.50                   # achieved fraction of peak on matmuls
    hbm_bw_gbps: float = 8000.0
    cluster_size: int = 72              # GB200 NVL72 rack
    hbm_per_gpu_gb: float = 192.0
    cxl_ports_per_accel: int = 1        # §5: "adequate CXL fabric ports"
    ib_oversubscription: float = 1.0    # full-bisection scale-out fabric
    offload_overlap: float = 0.5        # fraction of offload traffic hidden
    optimizer_bytes_per_param: float = 16.0  # fp32 m, v, master + bf16 grad
    # Fraction of backward-pass compute usable to hide DP gradient
    # reduction (bucketed overlap).  Applied to BOTH systems.
    dp_overlap: float = 0.5
    # Utilization of the shared inter-cluster fabrics.  The CXL fabric is
    # consolidated (collectives + tier-1 coherence + tier-2 pool traffic
    # share it — the paper's composability premise), so it runs hotter
    # than the dedicated IB rails of the baseline.
    ib_load: float = 0.30
    cxl_load: float = 0.30


@dataclass(frozen=True)
class SystemConfig:
    """One column of Figure 6: a cluster architecture."""

    name: str                      # baseline | scalepool | accel_clusters
    intra: fb.FabricSpec           # XLink inside the rack
    inter: fb.FabricSpec           # IB or CXL across racks
    offload_bw_gbps: float         # tier-2 / CPU-mem streaming bandwidth
    offload_latency: float
    offload_sw_overhead: float
    calib: Calibration


def make_system(kind: str, n_endpoints: int, calib: Calibration = Calibration()) -> SystemConfig:
    intra = fb.xlink_cluster_fabric(calib.cluster_size, fb.NVLINK5)
    if kind == "baseline":
        inter = fb.infiniband_fabric(n_endpoints, oversubscription=calib.ib_oversubscription)
        inter = fb.dataclasses.replace(inter, load=calib.ib_load)
        # offload target: CPU-attached memory through C2C (shared with CPU)
        return SystemConfig(kind, intra, inter,
                            offload_bw_gbps=400.0, offload_latency=500 * fb.NS,
                            offload_sw_overhead=2 * fb.US, calib=calib)
    if kind in ("scalepool", "accel_clusters"):
        link = fb.CXL3 if kind == "accel_clusters" else fb.CXL_COHERENCE
        link = fb.dataclasses.replace(
            link, bandwidth=link.bandwidth * calib.cxl_ports_per_accel)
        inter = fb.cxl_fabric(n_endpoints, link=link)
        inter = fb.dataclasses.replace(inter, load=calib.cxl_load)
        if kind == "scalepool":
            t2 = fb.tier2_memory_fabric(max(8, n_endpoints // 8))
            return SystemConfig(kind, intra, inter,
                                offload_bw_gbps=t2.bandwidth() * calib.cxl_ports_per_accel,
                                offload_latency=t2.latency(),
                                offload_sw_overhead=0.0, calib=calib)
        # accel_clusters: CXL interconnect but NO tier-2 pool: offload goes
        # to peer-accelerator memory through non-coherent copies.
        return SystemConfig(kind, intra, inter,
                            offload_bw_gbps=inter.bandwidth(),
                            offload_latency=inter.latency(),
                            offload_sw_overhead=2 * fb.US, calib=calib)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Placement: map (tp, pp, dp) onto racks of `cluster_size` GPUs.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Placement:
    pp_boundaries_crossing: int     # stage boundaries that leave the rack
    pp_boundaries_total: int
    dp_intra_size: int              # DP peers co-located per rack
    dp_n_groups: int                # rack groups participating in DP

    @property
    def frac_pp_cross(self) -> float:
        if self.pp_boundaries_total == 0:
            return 0.0
        return self.pp_boundaries_crossing / self.pp_boundaries_total


def place(par: ParallelismConfig, cluster_size: int) -> Placement:
    """Pack each pipeline replica onto consecutive GPUs; racks hold
    ``cluster_size`` GPUs.  Mirrors Megatron-style orderings."""
    tp, pp, dp = par.tp, par.pp, par.dp
    # pipeline stage s of a replica starting at gpu g0 occupies
    # [g0 + s*tp, g0 + (s+1)*tp)
    crossing = 0
    for s in range(pp - 1):
        rack_a = (s * tp) // cluster_size
        rack_b = ((s + 1) * tp) // cluster_size
        if rack_a != rack_b:
            crossing += 1
    gpus_per_replica = tp * pp
    if gpus_per_replica <= cluster_size:
        intra = max(1, min(dp, cluster_size // gpus_per_replica))
    else:
        intra = 1
    groups = math.ceil(dp / intra)
    return Placement(crossing, max(0, pp - 1), intra, groups)


# ---------------------------------------------------------------------------
# Training-step simulation
# ---------------------------------------------------------------------------

@dataclass
class StepBreakdown:
    compute: float = 0.0
    comm_intra: float = 0.0     # TP collectives on XLink (same both systems)
    comm_inter: float = 0.0     # exposed DP gradient + PP activation traffic
    comm_inter_raw: float = 0.0  # pre-overlap inter-cluster comm cost
    bubble: float = 0.0
    offload: float = 0.0
    total: float = 0.0
    # per-phase inter-fabric bases for routed (in-flight) pricing by
    # ``repro.colo``: the closed-form seconds each fabric-crossing phase
    # contributes to ``total``.  Kept out of the sums above — they
    # decompose ``comm_inter``/``offload``, they do not add to them.
    comm_pp: float = 0.0        # PP boundary traffic share of comm_inter
    comm_dp_exposed: float = 0.0  # exposed DP gradient share of comm_inter

    @property
    def comm(self) -> float:
        return self.comm_intra + self.comm_inter

    @property
    def other(self) -> float:
        return self.bubble + self.offload

    def as_dict(self) -> Dict[str, float]:
        return dict(compute=self.compute, comm_intra=self.comm_intra,
                    comm_inter=self.comm_inter, bubble=self.bubble,
                    offload=self.offload, total=self.total)


def simulate_step(model: LLMConfig, par: ParallelismConfig, sys: SystemConfig) -> StepBreakdown:
    c = sys.calib
    out = StepBreakdown()
    dtype_bytes = 2  # bf16 activations/grads

    tokens = par.global_batch_seqs * model.seq_len
    total_flops = model.flops_per_token() * tokens
    eff_flops = c.gpu_peak_tflops * TFLOP * c.mfu
    out.compute = total_flops / (par.n_gpus * eff_flops)
    # optimizer step: HBM-bandwidth bound over local shard (ZeRO-1 over dp)
    opt_bytes = c.optimizer_bytes_per_param * model.n_params / par.n_gpus
    out.compute += opt_bytes / (c.hbm_bw_gbps * GB)

    pl = place(par, c.cluster_size)

    # ---- TP collectives (intra-rack XLink, identical in both systems) ----
    # Megatron: 2 all-reduces fwd + 2 bwd per layer per microbatch of
    # (microbatch x seq x d_model) activations.
    layers_per_stage = max(1, model.n_layers // par.pp)
    msg = par.microbatch_seqs * model.seq_len * model.d_model * dtype_bytes
    if par.tp > 1:
        t_ar = cm.ring_allreduce_time(sys.intra, msg, par.tp)
        out.comm_intra = 4.0 * layers_per_stage * par.n_micro * t_ar

    # ---- PP point-to-point ----
    pp_time = 0.0
    if par.pp > 1:
        # per stage boundary: fwd activation + bwd grad per microbatch
        t_cross = cm.p2p_time(sys.inter, msg)
        t_local = cm.p2p_time(sys.intra, msg)
        # pipeline throughput is gated by the slowest boundary
        gate = t_cross if pl.pp_boundaries_crossing > 0 else t_local
        pp_time = 2.0 * par.n_micro * gate
    out.comm_inter += pp_time
    out.comm_inter_raw += pp_time
    if par.pp > 1 and pl.pp_boundaries_crossing > 0:
        out.comm_pp = pp_time       # crosses the inter fabric

    # ---- DP gradient reduction ----
    grad_bytes = dtype_bytes * model.n_params / (par.tp * par.pp)
    if par.dp > 1:
        dom = cm.HierarchicalDomains(intra=sys.intra, inter=sys.inter,
                                     intra_size=pl.dp_intra_size,
                                     n_groups=pl.dp_n_groups)
        # Both systems run the two-level schedule (rack-local XLink phase +
        # inter-rack phase); what differs is the inter-rack fabric: RDMA/IB
        # under production utilization vs the coherent CXL fabric.
        dp_time = cm.hierarchical_allreduce_time(dom, int(grad_bytes))
        # bucketed gradient reduction overlaps with backward compute
        bwd = (2.0 / 3.0) * out.compute
        dp_exposed = max(0.0, dp_time - c.dp_overlap * bwd)
        out.comm_inter += dp_exposed
        out.comm_inter_raw += dp_time
        if pl.dp_n_groups > 1:
            out.comm_dp_exposed = dp_exposed   # has an inter-fabric phase

    # ---- pipeline bubble (interleaved 1F1B: /vpp) ----
    if par.pp > 1:
        per_mb = (out.compute + out.comm_intra) / par.n_micro
        out.bubble = (par.pp - 1) * (per_mb / par.vpp + cm.p2p_time(sys.inter, msg))

    # ---- weight + optimizer offload traffic (§6: ZeRO-offload style) ----
    # per step per GPU: stream grads out + updated params in for the local
    # optimizer shard (4 bytes/param out fp32-compressed, 2 bytes in).
    off_bytes = 6.0 * model.n_params / par.n_gpus
    t_off = cm.offload_roundtrip_time(sys.offload_bw_gbps, sys.offload_latency,
                                      int(off_bytes), sys.offload_sw_overhead)
    out.offload = t_off * (1.0 - c.offload_overlap)

    out.total = out.compute + out.comm + out.other
    return out


# ---------------------------------------------------------------------------
# Figure 6 — model zoo per the original papers
# ---------------------------------------------------------------------------

GPT3 = LLMConfig("GPT-3", 96, 12288, 96, 4 * 12288, 50257, 2048, 175e9)
GOPHER = LLMConfig("Gopher", 80, 16384, 128, 4 * 16384, 32000, 2048, 280e9)
LLAMA3 = LLMConfig("Llama-3", 126, 16384, 128, 53248, 128256, 8192, 405e9)
PALM = LLMConfig("PaLM", 118, 18432, 48, 4 * 18432, 256000, 2048, 540e9)
MEGATRON = LLMConfig("Megatron", 72, 3072, 32, 4 * 3072, 51200, 1024, 8.3e9)

@dataclass(frozen=True)
class Fig6Workload:
    """One bar group of Figure 6.

    ``ib_load`` is the utilization of the baseline's shared scale-out
    fabric for this workload.  The paper simulates each model separately
    with its own cluster occupancy; these values are calibrated (see
    EXPERIMENTS.md §Fig6-calibration) because the paper does not publish
    per-model absolute times — only the 1.22x avg / 1.84x max headline.
    """

    model: LLMConfig
    par: ParallelismConfig
    ib_load: float = 0.30
    cxl_load: float = 0.30


# Parallelism/batch per the original papers (TP within node; DP/PP across).
# Per-workload fabric utilizations are calibrated to reproduce the paper's
# Fig-6 headline band (1.22x avg, 1.84x max, 3.79x inter-cluster comm) —
# the paper does not publish per-model absolute times.  See
# EXPERIMENTS.md §Fig6-calibration for the procedure and sensitivity.
FIG6_WORKLOADS: List[Fig6Workload] = [
    Fig6Workload(GPT3, ParallelismConfig(tp=8, pp=8, dp=16, global_batch_seqs=1536, vpp=4),
                 ib_load=0.886, cxl_load=0.5),
    Fig6Workload(GOPHER, ParallelismConfig(tp=8, pp=4, dp=128, global_batch_seqs=1536, vpp=4),
                 ib_load=0.0, cxl_load=0.5),
    Fig6Workload(LLAMA3, ParallelismConfig(tp=8, pp=16, dp=128, global_batch_seqs=2048, vpp=8),
                 ib_load=0.409, cxl_load=0.5),
    Fig6Workload(PALM, ParallelismConfig(tp=12, pp=1, dp=512, global_batch_seqs=2048),
                 ib_load=0.835, cxl_load=0.5),
    Fig6Workload(MEGATRON, ParallelismConfig(tp=8, pp=1, dp=64, global_batch_seqs=512),
                 ib_load=0.375, cxl_load=0.5),
]


@dataclass
class Fig6Row:
    model: str
    baseline: StepBreakdown
    scalepool: StepBreakdown

    @property
    def speedup(self) -> float:
        return self.baseline.total / self.scalepool.total

    @property
    def comm_inter_speedup(self) -> float:
        """Inter-cluster communication-cost speedup on raw (pre-overlap)
        collective times — the paper's 3.79x claim."""
        if self.scalepool.comm_inter_raw == 0:
            return float("inf")
        return self.baseline.comm_inter_raw / self.scalepool.comm_inter_raw

    @property
    def comm_speedup(self) -> float:
        """Total communication-time speedup (TP + PP + DP)."""
        if self.scalepool.comm == 0:
            return float("inf")
        return self.baseline.comm / self.scalepool.comm


def run_fig6(calib: Calibration = Calibration()) -> List[Fig6Row]:
    rows = []
    for w in FIG6_WORKLOADS:
        c = replace(calib, ib_load=w.ib_load, cxl_load=w.cxl_load)
        base = simulate_step(w.model, w.par, make_system("baseline", w.par.n_gpus, c))
        sp = simulate_step(w.model, w.par, make_system("scalepool", w.par.n_gpus, c))
        rows.append(Fig6Row(w.model.name, base, sp))
    return rows


def fig6_summary(rows: List[Fig6Row]) -> Dict[str, float]:
    speedups = [r.speedup for r in rows]
    comms = [r.comm_speedup for r in rows if math.isfinite(r.comm_speedup)]
    inter = [r.comm_inter_speedup for r in rows if math.isfinite(r.comm_inter_speedup)]
    return dict(
        avg_speedup=sum(speedups) / len(speedups),
        max_speedup=max(speedups),
        avg_comm_speedup=sum(comms) / len(comms),
        avg_comm_inter_speedup=sum(inter) / len(inter),
    )


# ---------------------------------------------------------------------------
# Figure 7 — tiered-memory access latency vs working-set size
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MemSystem:
    """Memory hierarchy seen by one accelerator under each §6 config."""

    name: str
    tiers: List[fb.MemoryTierSpec]  # ordered: local HBM, cluster, beyond


def make_mem_system(kind: str, calib: Calibration = Calibration()) -> MemSystem:
    hbm = fb.hbm_tier(calib.hbm_per_gpu_gb)
    cluster_cap = calib.hbm_per_gpu_gb * (calib.cluster_size - 1)
    xlink = fb.xlink_cluster_fabric(calib.cluster_size, fb.NVLINK5)
    if kind == "baseline":
        # non-coherent XLink peers + RDMA beyond the rack
        peer = fb.cluster_xlink_tier(xlink, cluster_cap, coherent=False)
        ib = fb.infiniband_fabric(1024, oversubscription=calib.ib_oversubscription)
        beyond = fb.rdma_storage_tier(ib)
        return MemSystem(kind, [hbm, peer, beyond])
    if kind == "accel_clusters":
        # CXL *between* clusters only; intra-cluster stays non-coherent
        # XLink; beyond-rack traffic crosses the inter-cluster CXL fabric
        # (coherent, so no software) but terminates in another cluster's
        # *accelerator* memory: extra XLink crossing at the far end and
        # contention with that cluster's own accelerator traffic.
        peer = fb.cluster_xlink_tier(xlink, cluster_cap, coherent=False)
        cxl = fb.cxl_fabric(1024)
        # far-end ingress crosses that cluster's XLink and contends with
        # its accelerators' own traffic (extra 400ns + halved bandwidth)
        remote = fb.MemoryTierSpec(
            "CXL-remote-accel", 1 << 50,
            access_latency=2 * (cxl.latency() + xlink.latency()) + 600 * fb.NS,
            bandwidth=cxl.bandwidth() / 2.0,
        )
        return MemSystem(kind, [hbm, peer, remote])
    if kind == "tiered":  # full ScalePool
        # §5: "bulk data movements occur via XLink, while optimized
        # implementations of CXL.cache handle only coherence transactions"
        # → tier-1 coherent pool = XLink data path + snoop/directory time.
        peer = fb.cluster_xlink_tier(xlink, cluster_cap, coherent=True)
        t2fab = fb.tier2_memory_fabric(128)
        t2 = fb.tier2_pool_tier(t2fab)
        return MemSystem(kind, [hbm, peer, t2])
    raise ValueError(kind)


def avg_access_latency(ms: MemSystem, working_set_bytes: float,
                       block_bytes: int = 4096) -> float:
    """Average per-block access latency for a uniform random scan of the
    working set, spread across the tier capacities in order."""
    remaining = working_set_bytes
    weighted = 0.0
    for tier in ms.tiers:
        frac_bytes = min(remaining, tier.capacity_bytes)
        if frac_bytes <= 0:
            continue
        weighted += (frac_bytes / working_set_bytes) * tier.access_time(block_bytes)
        remaining -= frac_bytes
    if remaining > 0:  # beyond all modeled tiers: charge the last tier
        weighted += (remaining / working_set_bytes) * ms.tiers[-1].access_time(block_bytes)
    return weighted


def run_fig7(calib: Calibration = Calibration()) -> List[Dict[str, float]]:
    """Sweep working sets across the three §6 regimes."""
    hbm_gb = calib.hbm_per_gpu_gb
    cluster_gb = hbm_gb * calib.cluster_size
    points_gb = [hbm_gb * 0.5,                      # fits locally
                 hbm_gb * 4, hbm_gb * 16,           # exceeds one accel
                 cluster_gb * 2, cluster_gb * 8]    # exceeds the cluster
    systems = {k: make_mem_system(k, calib) for k in
               ("baseline", "accel_clusters", "tiered")}
    rows = []
    for ws in points_gb:
        row = {"working_set_gb": ws}
        for k, ms in systems.items():
            row[k] = avg_access_latency(ms, ws * GB)
        row["speedup_vs_baseline"] = row["baseline"] / row["tiered"]
        row["speedup_vs_accel_clusters"] = row["accel_clusters"] / row["tiered"]
        rows.append(row)
    return rows


def fig7_summary(rows: List[Dict[str, float]]) -> Dict[str, float]:
    beyond_accel = [r for r in rows if r["working_set_gb"] > 192 and
                    r["working_set_gb"] <= 192 * 72]
    beyond_cluster = [r for r in rows if r["working_set_gb"] > 192 * 72]
    return dict(
        speedup_beyond_accel=max(r["speedup_vs_baseline"] for r in beyond_accel),
        speedup_beyond_cluster=max(r["speedup_vs_baseline"] for r in beyond_cluster),
        speedup_vs_accel_clusters=max(r["speedup_vs_accel_clusters"] for r in beyond_cluster),
    )

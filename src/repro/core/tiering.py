"""Two-tier memory management (paper §5), JAX realization.

Tier-1 = accelerator HBM across the mesh (the coherent pool: GSPMD-
addressed device memory).  Tier-2 = the capacity pool: on TPU this is
host memory reached through JAX's memory-kind API (``pinned_host``) —
the structural analogue of the paper's CXL memory nodes (the cost model
in ``repro.core.fabric`` carries the paper's actual latency/bandwidth
constants).

The manager provides:
  * placement policy: which training/serving state lives in which tier
    (optimizer moments, master params, cold KV pages, embedding spill);
  * sharding transforms (``to_tier2(sharding)``) usable at jit boundaries;
  * a budget-enforcing paged KV pool (``KVBudget`` + ``PagedKV``) for the
    ``repro.serve`` engine: tier-1 page quotas and tier-2 byte budgets as
    first-class, contended resources;
  * capability detection so the same code runs on CPU (tests) and TPU.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, SingleDeviceSharding


def tier2_memory_kind() -> Optional[str]:
    """The platform's capacity-tier memory kind, or None if unsupported."""
    try:
        dev = jax.devices()[0]
        kinds = {m.kind for m in dev.addressable_memories()}
    except Exception:  # pragma: no cover
        return None
    for kind in ("pinned_host", "unpinned_host", "host"):
        if kind in kinds:
            return kind
    return None


def supports_tier2() -> bool:
    return tier2_memory_kind() is not None


def to_tier2(sharding):
    """Return the tier-2 (host/CXL-pool) variant of a sharding, or the
    original when the platform has no second memory space."""
    kind = tier2_memory_kind()
    if kind is None:
        return sharding
    try:
        return sharding.with_memory_kind(kind)
    except Exception:  # pragma: no cover
        return sharding


@dataclasses.dataclass(frozen=True)
class KVBudget:
    """Budgeted KV-cache residency: serving capacity is an explicitly
    *quota'd*, contended resource (the DFabric / CXL-pooling framing),
    not a boolean.

    ``tier1_pages``: hot page quota across all engine slots (None =
    derived by the consumer, e.g. the engine's full slot capacity).
    ``tier2_bytes``: cold-pool byte budget on the capacity fabric —
    a lease derives this from its actual tier-2 KV grant.
    ``page_size``: tokens per KV page (bulk-friendly spill granularity).
    """

    tier1_pages: Optional[int] = None
    tier2_bytes: float = 0.0
    page_size: int = 64

    def pages_for(self, n_tokens) -> int:
        return max(1, -(-int(n_tokens) // self.page_size))

    def tier2_pages(self, page_bytes: float) -> int:
        if page_bytes <= 0:
            return 0
        return int(self.tier2_bytes // page_bytes)


class KVBudgetExceeded(RuntimeError):
    """A KV allocation would overrun the tier-1 page quota or the tier-2
    byte budget."""


@dataclasses.dataclass(frozen=True)
class TieringPolicy:
    """Which state lives in the capacity tier (§6: the paper evaluates
    weight + optimizer offloading as the common training optimization)."""

    offload_optimizer: bool = True      # AdamW moments → tier-2
    offload_master_params: bool = False # fp32 masters → tier-2
    kv_budget: Optional[KVBudget] = None  # serving: budgeted KV paging

    @property
    def kv_spill(self) -> bool:
        """Deprecated boolean view of ``kv_budget`` (pre-engine API)."""
        return self.kv_budget is not None and self.kv_budget.tier2_bytes > 0


def offload_state_shardings(state_shardings, policy: TieringPolicy):
    """Rewrite a TrainState sharding pytree so the selected components
    carry tier-2 memory kinds.  jit honors these for inputs/outputs; XLA
    streams them in during the optimizer-update phase."""
    if not supports_tier2():
        return state_shardings
    s = state_shardings
    if policy.offload_optimizer and hasattr(s, "opt"):
        opt = s.opt
        new_opt = opt._replace(
            mu=jax.tree.map(to_tier2, opt.mu),
            nu=jax.tree.map(to_tier2, opt.nu))
        s = s._replace(opt=new_opt)
    if policy.offload_master_params and hasattr(s, "params"):
        s = s._replace(params=jax.tree.map(to_tier2, s.params))
    return s


# ---------------------------------------------------------------------------
# paged KV pool: physical page allocator + page-granular tier-2 cold store
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Page:
    """One logical KV page of one sequence: hot (a physical page id in
    the device pool) or cold (a host-side payload in the tier-2 store)."""

    phys: Optional[int] = None      # physical pool page id; None = cold
    payload: Any = None             # host pytree while cold

    @property
    def hot(self) -> bool:
        return self.phys is not None


class PagedKV:
    """Physical paged KV pool (serving-side tiering, paper §5).

    Owns the *allocation state* of a device-side page pool of
    ``budget.tier1_pages`` physical pages (accelerator HBM, the coherent
    tier-1): a free-page stack plus, per sequence (``rid``), the
    logical→physical page mapping the decode kernel's page table is
    built from.  Sequences need neither contiguous physical pages nor
    full residency: individual pages can be evicted to the tier-2 cold
    store (page-granular spill, counted against ``budget.tier2_bytes``)
    and fetched back into *different* physical pages later.

    The cold store is HOST-side (numpy pytrees): paging decisions are
    host bookkeeping, and the evict/fetch payloads are explicit
    device↔pool bulk copies — the paper's CXL.io (no-coherence) tier-2
    path.  The caller (``repro.serve.Engine``) owns the device arrays;
    ``evict`` takes the host copy it made of one page, ``fetch``
    allocates a fresh physical page and returns the payload for the
    caller to scatter back.  Operations that would overrun either
    budget raise ``KVBudgetExceeded`` and leave state untouched.
    """

    def __init__(self, budget: KVBudget, page_bytes: float):
        if budget.tier1_pages is None:
            raise ValueError("PagedKV needs a concrete tier-1 page quota")
        self.budget = budget
        self.page_bytes = float(page_bytes)
        self.num_pages = int(budget.tier1_pages)
        # stack: low ids pop first, so fresh allocations after churn land
        # on non-contiguous, reused pages (the layout the kernel must not
        # care about)
        self._free: List[int] = list(range(self.num_pages - 1, -1, -1))
        self._seqs: Dict[Any, List[_Page]] = {}
        self.spills = 0                 # pages evicted tier-1 -> tier-2
        self.fetches = 0                # pages fetched tier-2 -> tier-1

    # ---- occupancy -------------------------------------------------------
    @property
    def hot_free(self) -> int:
        return len(self._free)

    @property
    def free_count(self) -> int:
        """Pages literally on the free stack — ``hot_free`` minus any
        revocation headroom a multi-tenant view folds in.  Cheap (no
        fair-share recomputation), for hot loops."""
        return len(self._free)

    def allowance(self) -> int:
        """Hot pages this pool's consumer may keep scheduled right now.
        For a private pool that is the whole quota; a multi-tenant view
        (``repro.serve.arbiter``) overrides it with the tenant's current
        max-min fair share, which is what makes shares *revocable*."""
        return self.num_pages

    def hot_used(self) -> int:
        """Hot pages held by this pool's own sequences (== pool-wide
        usage for a private pool; per-tenant usage under an arbiter)."""
        return sum(1 for pages in self._seqs.values()
                   for p in pages if p.hot)

    @property
    def hot_pages_used(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def cold_pages_used(self) -> int:
        return sum(1 for pages in self._seqs.values()
                   for p in pages if not p.hot)

    @property
    def cold_bytes_used(self) -> float:
        return self.cold_pages_used * self.page_bytes

    def tier2_free_pages(self) -> int:
        """How many more pages the tier-2 byte budget can absorb."""
        if self.page_bytes <= 0:
            return 0
        room = self.budget.tier2_bytes - self.cold_bytes_used
        return max(0, int((room + 1e-6) // self.page_bytes))

    def holds(self, rid) -> bool:
        return rid in self._seqs

    def pages_of(self, rid) -> int:
        """Total logical pages (hot + cold) held by ``rid``."""
        return len(self._seqs[rid])

    def hot_count(self, rid) -> int:
        return sum(1 for p in self._seqs[rid] if p.hot)

    def cold_logicals(self, rid) -> List[int]:
        """Logical indices of ``rid``'s cold pages (ascending)."""
        return [i for i, p in enumerate(self._seqs[rid]) if not p.hot]

    def hot_logicals(self, rid) -> List[int]:
        return [i for i, p in enumerate(self._seqs[rid]) if p.hot]

    def is_fully_hot(self, rid) -> bool:
        return all(p.hot for p in self._seqs[rid])

    def page_table(self, rid) -> List[Optional[int]]:
        """Logical -> physical ids (None where cold) — the row the engine
        writes into the device page-table array."""
        return [p.phys for p in self._seqs[rid]]

    # ---- lifecycle -------------------------------------------------------
    def prepare(self, n_pages: int) -> None:
        """Hint that ``n_pages`` physical pages are about to be taken
        one at a time (a fetch loop).  No-op for a private pool; a
        multi-tenant view revokes the whole shortfall in ONE batched
        episode here, so the victim is charged one bulk transfer rather
        than a per-page setup latency per fetch."""

    def _take(self, n: int, what: str) -> List[int]:
        if n > len(self._free):
            raise KVBudgetExceeded(
                f"{what}: {n} pages > {len(self._free)} free of "
                f"{self.num_pages}-page tier-1 pool")
        return [self._free.pop() for _ in range(n)]

    def alloc(self, rid, n_pages: int) -> List[int]:
        """Admit ``rid`` with ``n_pages`` hot pages; returns their
        physical ids (in logical order)."""
        if rid in self._seqs:
            raise KeyError(f"{rid!r} already holds KV pages")
        phys = self._take(n_pages, repr(rid))
        self._seqs[rid] = [_Page(phys=p) for p in phys]
        return phys

    def grow(self, rid, n_total: int) -> List[int]:
        """Extend ``rid`` to ``n_total`` logical pages (decode crossed a
        page boundary); returns the new physical ids."""
        pages = self._seqs[rid]
        extra = n_total - len(pages)
        if extra <= 0:
            return []
        phys = self._take(extra, f"{rid!r} growth to {n_total}")
        pages.extend(_Page(phys=p) for p in phys)
        return phys

    def evict(self, rid, logical: int, payload) -> int:
        """Spill one hot page to the tier-2 cold store; returns the freed
        physical id.  ``payload`` is the caller's host copy of the page."""
        page = self._seqs[rid][logical]
        if not page.hot:
            raise KeyError(f"{rid!r} page {logical} already cold")
        if (self.cold_pages_used + 1) * self.page_bytes \
                > self.budget.tier2_bytes + 1e-6:
            raise KVBudgetExceeded(
                f"{rid!r}: evicting page {logical} overruns the "
                f"{self.budget.tier2_bytes / 1e9:.2f}GB tier-2 budget")
        phys = page.phys
        self._free.append(phys)
        page.phys, page.payload = None, payload
        self.spills += 1
        return phys

    def fetch(self, rid, logical: int) -> Tuple[int, Any]:
        """Bring one cold page back: allocates a fresh physical page
        (almost surely a *different* id) and returns ``(phys, payload)``
        for the caller to scatter into the device pool."""
        page = self._seqs[rid][logical]
        if page.hot:
            raise KeyError(f"{rid!r} page {logical} already hot")
        phys = self._take(1, f"{rid!r} fetch of page {logical}")[0]
        payload = page.payload
        page.phys, page.payload = phys, None
        self.fetches += 1
        return phys, payload

    def free(self, rid) -> None:
        """Release every page (hot ids back to the free stack, cold
        payloads dropped)."""
        for page in self._seqs.pop(rid, []):
            if page.hot:
                self._free.append(page.phys)

    def residency(self) -> Dict[str, float]:
        """Page-pool residency — the quantity ``Engine.stats()`` reports."""
        hot_seqs = sum(1 for pages in self._seqs.values()
                       if all(p.hot for p in pages))
        return {
            "tier1_pages_used": self.hot_pages_used,
            "tier1_pages_free": self.hot_free,
            "tier1_pages_quota": self.num_pages,
            "tier2_bytes_used": self.cold_bytes_used,
            "tier2_bytes_budget": self.budget.tier2_bytes,
            "seqs": len(self._seqs),
            "hot_seqs": hot_seqs,
            "partial_seqs": len(self._seqs) - hot_seqs,
            "spills": self.spills,
            "fetches": self.fetches,
        }


def tier_traffic_report(policy: TieringPolicy, n_params: float,
                        steps_per_sec: float = 1.0) -> Dict[str, float]:
    """Analytic tier-2 traffic for the chosen policy (feeds the §5 cost
    model): bytes/step shuttled over the capacity fabric."""
    per_step = 0.0
    if policy.offload_optimizer:
        # moments read+write per step (fp32 m, v)
        per_step += 2 * 4 * n_params * 2
    if policy.offload_master_params:
        per_step += 2 * 4 * n_params
    return {"tier2_bytes_per_step": per_step,
            "tier2_gbps": per_step * steps_per_sec / 1e9}

"""Two-tier memory management (paper §5), JAX realization.

Tier-1 = accelerator HBM across the mesh (the coherent pool: GSPMD-
addressed device memory).  Tier-2 = the capacity pool: on TPU this is
host memory reached through JAX's memory-kind API (``pinned_host``) —
the structural analogue of the paper's CXL memory nodes (the cost model
in ``repro.core.fabric`` carries the paper's actual latency/bandwidth
constants).

The manager provides:
  * placement policy: which training/serving state lives in which tier
    (optimizer moments, master params, cold KV pages, embedding spill);
  * sharding transforms (``to_tier2(sharding)``) usable at jit boundaries;
  * a paged KV-cache spill/fetch pair for serving;
  * capability detection so the same code runs on CPU (tests) and TPU.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, SingleDeviceSharding


def tier2_memory_kind() -> Optional[str]:
    """The platform's capacity-tier memory kind, or None if unsupported."""
    try:
        dev = jax.devices()[0]
        kinds = {m.kind for m in dev.addressable_memories()}
    except Exception:  # pragma: no cover
        return None
    for kind in ("pinned_host", "unpinned_host", "host"):
        if kind in kinds:
            return kind
    return None


def supports_tier2() -> bool:
    return tier2_memory_kind() is not None


def to_tier2(sharding):
    """Return the tier-2 (host/CXL-pool) variant of a sharding, or the
    original when the platform has no second memory space."""
    kind = tier2_memory_kind()
    if kind is None:
        return sharding
    try:
        return sharding.with_memory_kind(kind)
    except Exception:  # pragma: no cover
        return sharding


@dataclasses.dataclass(frozen=True)
class TieringPolicy:
    """Which state lives in the capacity tier (§6: the paper evaluates
    weight + optimizer offloading as the common training optimization)."""

    offload_optimizer: bool = True      # AdamW moments → tier-2
    offload_master_params: bool = False # fp32 masters → tier-2
    kv_spill: bool = False              # cold KV pages → tier-2
    kv_hot_fraction: float = 0.25       # fraction of pages kept in tier-1


def offload_state_shardings(state_shardings, policy: TieringPolicy):
    """Rewrite a TrainState sharding pytree so the selected components
    carry tier-2 memory kinds.  jit honors these for inputs/outputs; XLA
    streams them in during the optimizer-update phase."""
    if not supports_tier2():
        return state_shardings
    s = state_shardings
    if policy.offload_optimizer and hasattr(s, "opt"):
        opt = s.opt
        new_opt = opt._replace(
            mu=jax.tree.map(to_tier2, opt.mu),
            nu=jax.tree.map(to_tier2, opt.nu))
        s = s._replace(opt=new_opt)
    if policy.offload_master_params and hasattr(s, "params"):
        s = s._replace(params=jax.tree.map(to_tier2, s.params))
    return s


# ---------------------------------------------------------------------------
# paged KV cache with tier-2 spill (serving-side tiering)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PagedKV:
    """Fixed-size-page KV pool: hot pages in tier-1 (device arrays), cold
    pages in the tier-2 capacity pool.  Page granularity keeps spill
    traffic bulk-friendly (the paper's capacity-oriented CXL carries
    large flits efficiently).

    The cold pool is HOST-side storage (numpy): paging decisions are host
    bookkeeping, and the spill/fetch transfers are explicit device<->pool
    bulk copies — exactly the paper's CXL.io (no-coherence) tier-2 path.
    ``spill``/``fetch`` mutate the cold pool in place (it is a pool, not
    a functional value) and return ``self`` for chaining.

    Logical layout per layer: (n_pages, page, kv_heads, head_dim).
    """

    page_size: int
    hot: Dict[str, jax.Array]           # (L, B, hot_pages, page, KV, hd)
    cold: Dict[str, "np.ndarray"]       # (L, B, cold_pages, page, KV, hd)
    hot_map: jax.Array                  # (B, hot_pages) -> logical page id

    @staticmethod
    def create(n_layers: int, batch: int, max_seq: int, kv_heads: int,
               head_dim: int, *, page_size: int = 512,
               hot_fraction: float = 0.25, dtype=jnp.bfloat16) -> "PagedKV":
        import numpy as np
        n_pages = max(1, max_seq // page_size)
        hot_pages = max(1, int(n_pages * hot_fraction))
        cold_pages = max(1, n_pages - hot_pages)
        mk = lambda p: jnp.zeros((n_layers, batch, p, page_size, kv_heads,
                                  head_dim), dtype)
        mk_np = lambda p: np.zeros((n_layers, batch, p, page_size, kv_heads,
                                    head_dim), np.float32)
        return PagedKV(
            page_size=page_size,
            hot={"k": mk(hot_pages), "v": mk(hot_pages)},
            cold={"k": mk_np(cold_pages), "v": mk_np(cold_pages)},
            hot_map=jnp.tile(jnp.arange(hot_pages)[None], (batch, 1)),
        )

    @property
    def hot_pages(self) -> int:
        return self.hot["k"].shape[2]

    @property
    def cold_pages(self) -> int:
        return self.cold["k"].shape[2]

    def spill(self, hot_slot: int, cold_slot) -> "PagedKV":
        """Move one hot page to the cold (tier-2) pool: an explicit
        tier-1 → tier-2 bulk transfer (the paper's CXL.io path)."""
        import numpy as np
        for key in ("k", "v"):
            page = np.asarray(self.hot[key][:, :, hot_slot], np.float32)
            self.cold[key][:, :, int(cold_slot)] = page
        return self

    def fetch(self, cold_slot, hot_slot: int, logical_page) -> "PagedKV":
        """Bring one cold page back into tier-1 at ``hot_slot``."""
        new_hot = {}
        for key in ("k", "v"):
            page = jnp.asarray(self.cold[key][:, :, int(cold_slot)])
            new_hot[key] = jax.lax.dynamic_update_index_in_dim(
                self.hot[key], page.astype(self.hot[key].dtype), hot_slot, 2)
        new_map = self.hot_map.at[:, hot_slot].set(logical_page)
        return dataclasses.replace(self, hot=new_hot, hot_map=new_map)


def tier_traffic_report(policy: TieringPolicy, n_params: float,
                        steps_per_sec: float = 1.0) -> Dict[str, float]:
    """Analytic tier-2 traffic for the chosen policy (feeds the §5 cost
    model): bytes/step shuttled over the capacity fabric."""
    per_step = 0.0
    if policy.offload_optimizer:
        # moments read+write per step (fp32 m, v)
        per_step += 2 * 4 * n_params * 2
    if policy.offload_master_params:
        per_step += 2 * 4 * n_params
    return {"tier2_bytes_per_step": per_step,
            "tier2_gbps": per_step * steps_per_sec / 1e9}

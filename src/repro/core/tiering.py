"""Two-tier memory management (paper §5), JAX realization.

Tier-1 = accelerator HBM across the mesh (the coherent pool: GSPMD-
addressed device memory).  Tier-2 = the capacity pool: on TPU this is
host memory reached through JAX's memory-kind API (``pinned_host``) —
the structural analogue of the paper's CXL memory nodes (the cost model
in ``repro.core.fabric`` carries the paper's actual latency/bandwidth
constants).

The manager provides:
  * placement policy: which training/serving state lives in which tier
    (optimizer moments, master params, cold KV pages, embedding spill);
  * sharding transforms (``to_tier2(sharding)``) usable at jit boundaries;
  * a budget-enforcing paged KV pool (``KVBudget`` + ``PagedKV``) for the
    ``repro.serve`` engine: tier-1 page quotas and tier-2 byte budgets as
    first-class, contended resources;
  * capability detection so the same code runs on CPU (tests) and TPU.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, SingleDeviceSharding


def tier2_memory_kind() -> Optional[str]:
    """The platform's capacity-tier memory kind, or None if unsupported."""
    try:
        dev = jax.devices()[0]
        kinds = {m.kind for m in dev.addressable_memories()}
    except Exception:  # pragma: no cover
        return None
    for kind in ("pinned_host", "unpinned_host", "host"):
        if kind in kinds:
            return kind
    return None


def supports_tier2() -> bool:
    return tier2_memory_kind() is not None


def to_tier2(sharding):
    """Return the tier-2 (host/CXL-pool) variant of a sharding, or the
    original when the platform has no second memory space."""
    kind = tier2_memory_kind()
    if kind is None:
        return sharding
    try:
        return sharding.with_memory_kind(kind)
    except Exception:  # pragma: no cover
        return sharding


@dataclasses.dataclass(frozen=True)
class KVBudget:
    """Budgeted KV-cache residency: serving capacity is an explicitly
    *quota'd*, contended resource (the DFabric / CXL-pooling framing),
    not a boolean.

    ``tier1_pages``: hot page quota across all engine slots (None =
    derived by the consumer, e.g. the engine's full slot capacity).
    ``tier2_bytes``: cold-pool byte budget on the capacity fabric —
    a lease derives this from its actual tier-2 KV grant.
    ``page_size``: tokens per KV page (bulk-friendly spill granularity).
    """

    tier1_pages: Optional[int] = None
    tier2_bytes: float = 0.0
    page_size: int = 64

    def pages_for(self, n_tokens) -> int:
        return max(1, -(-int(n_tokens) // self.page_size))

    def tier2_pages(self, page_bytes: float) -> int:
        if page_bytes <= 0:
            return 0
        return int(self.tier2_bytes // page_bytes)


class KVBudgetExceeded(RuntimeError):
    """A KV allocation would overrun the tier-1 page quota or the tier-2
    byte budget."""


@dataclasses.dataclass(frozen=True)
class TieringPolicy:
    """Which state lives in the capacity tier (§6: the paper evaluates
    weight + optimizer offloading as the common training optimization)."""

    offload_optimizer: bool = True      # AdamW moments → tier-2
    offload_master_params: bool = False # fp32 masters → tier-2
    kv_budget: Optional[KVBudget] = None  # serving: budgeted KV paging

    @property
    def kv_spill(self) -> bool:
        """Deprecated boolean view of ``kv_budget`` (pre-engine API)."""
        return self.kv_budget is not None and self.kv_budget.tier2_bytes > 0


def offload_state_shardings(state_shardings, policy: TieringPolicy):
    """Rewrite a TrainState sharding pytree so the selected components
    carry tier-2 memory kinds.  jit honors these for inputs/outputs; XLA
    streams them in during the optimizer-update phase."""
    if not supports_tier2():
        return state_shardings
    s = state_shardings
    if policy.offload_optimizer and hasattr(s, "opt"):
        opt = s.opt
        new_opt = opt._replace(
            mu=jax.tree.map(to_tier2, opt.mu),
            nu=jax.tree.map(to_tier2, opt.nu))
        s = s._replace(opt=new_opt)
    if policy.offload_master_params and hasattr(s, "params"):
        s = s._replace(params=jax.tree.map(to_tier2, s.params))
    return s


# ---------------------------------------------------------------------------
# paged KV pool: budget-enforcing page table + tier-2 cold store
# ---------------------------------------------------------------------------

class PagedKV:
    """Budgeted paged KV pool (serving-side tiering, paper §5).

    Tracks, per sequence (``rid``), how many fixed-size KV pages it holds
    and in which tier, and enforces a ``KVBudget``: hot pages count
    against ``budget.tier1_pages`` (accelerator HBM), spilled sequences
    count against ``budget.tier2_bytes`` (the capacity pool).  Page
    granularity keeps spill traffic bulk-friendly (the capacity-oriented
    CXL carries large flits efficiently).

    The cold store is HOST-side (numpy pytrees): paging decisions are
    host bookkeeping, and the spill/fetch payloads are explicit
    device↔pool bulk copies — the paper's CXL.io (no-coherence) tier-2
    path.  The caller (``repro.serve.Engine``) owns the device arrays;
    ``spill`` takes the host copy it made, ``fetch`` returns it for the
    caller to write back.  Operations that would overrun either budget
    raise ``KVBudgetExceeded`` and leave state untouched.
    """

    def __init__(self, budget: KVBudget, page_bytes: float):
        if budget.tier1_pages is None:
            raise ValueError("PagedKV needs a concrete tier-1 page quota")
        self.budget = budget
        self.page_bytes = float(page_bytes)
        self._hot: Dict[Any, int] = {}          # rid -> pages in tier-1
        self._cold: Dict[Any, Tuple[int, Any]] = {}  # rid -> (pages, payload)
        self.spills = 0
        self.fetches = 0

    # ---- occupancy -------------------------------------------------------
    @property
    def hot_pages_used(self) -> int:
        return sum(self._hot.values())

    @property
    def hot_free(self) -> int:
        return self.budget.tier1_pages - self.hot_pages_used

    @property
    def cold_pages_used(self) -> int:
        return sum(n for n, _ in self._cold.values())

    @property
    def cold_bytes_used(self) -> float:
        return self.cold_pages_used * self.page_bytes

    def is_hot(self, rid) -> bool:
        return rid in self._hot

    def holds(self, rid) -> bool:
        return rid in self._hot or rid in self._cold

    def pages_of(self, rid) -> int:
        if rid in self._hot:
            return self._hot[rid]
        return self._cold[rid][0]

    # ---- lifecycle -------------------------------------------------------
    def alloc(self, rid, n_pages: int) -> None:
        """Admit ``rid`` with ``n_pages`` hot pages."""
        if rid in self._hot or rid in self._cold:
            raise KeyError(f"{rid!r} already holds KV pages")
        if n_pages > self.hot_free:
            raise KVBudgetExceeded(
                f"{rid!r}: {n_pages} pages > {self.hot_free} free of "
                f"{self.budget.tier1_pages}-page tier-1 quota")
        self._hot[rid] = n_pages

    def grow(self, rid, n_pages: int) -> None:
        """Raise ``rid``'s hot page count (decode crossed a page boundary)."""
        extra = n_pages - self._hot[rid]
        if extra <= 0:
            return
        if extra > self.hot_free:
            raise KVBudgetExceeded(
                f"{rid!r}: growth to {n_pages} pages overruns the "
                f"{self.budget.tier1_pages}-page tier-1 quota")
        self._hot[rid] = n_pages

    def spill(self, rid, payload) -> None:
        """Move ``rid`` hot → cold, storing the caller's host copy of its
        cache region (an explicit tier-1 → tier-2 bulk transfer)."""
        pages = self._hot[rid]
        if (self.cold_pages_used + pages) * self.page_bytes \
                > self.budget.tier2_bytes + 1e-6:
            raise KVBudgetExceeded(
                f"{rid!r}: spill of {pages} pages overruns the "
                f"{self.budget.tier2_bytes / 1e9:.2f}GB tier-2 budget")
        del self._hot[rid]
        self._cold[rid] = (pages, payload)
        self.spills += 1

    def fetch(self, rid):
        """Move ``rid`` cold → hot; returns the stored payload for the
        caller to copy back into device memory."""
        pages, payload = self._cold[rid]
        if pages > self.hot_free:
            raise KVBudgetExceeded(
                f"{rid!r}: fetch of {pages} pages overruns the tier-1 quota")
        del self._cold[rid]
        self._hot[rid] = pages
        self.fetches += 1
        return payload

    def free(self, rid) -> None:
        self._hot.pop(rid, None)
        self._cold.pop(rid, None)

    def residency(self) -> Dict[str, float]:
        """KV tier residency — the quantity ``Engine.stats()`` reports."""
        return {
            "tier1_pages_used": self.hot_pages_used,
            "tier1_pages_quota": self.budget.tier1_pages,
            "tier2_bytes_used": self.cold_bytes_used,
            "tier2_bytes_budget": self.budget.tier2_bytes,
            "hot_seqs": len(self._hot),
            "cold_seqs": len(self._cold),
            "spills": self.spills,
            "fetches": self.fetches,
        }


def tier_traffic_report(policy: TieringPolicy, n_params: float,
                        steps_per_sec: float = 1.0) -> Dict[str, float]:
    """Analytic tier-2 traffic for the chosen policy (feeds the §5 cost
    model): bytes/step shuttled over the capacity fabric."""
    per_step = 0.0
    if policy.offload_optimizer:
        # moments read+write per step (fp32 m, v)
        per_step += 2 * 4 * n_params * 2
    if policy.offload_master_params:
        per_step += 2 * 4 * n_params
    return {"tier2_bytes_per_step": per_step,
            "tier2_gbps": per_step * steps_per_sec / 1e9}

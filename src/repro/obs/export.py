"""Chrome/Perfetto ``trace_event`` export + per-link utilization report.

Two consumers of one flight recorder:

* ``write_chrome_trace`` / ``to_chrome_trace`` — serialize a
  ``Tracer``'s events as Chrome trace_event JSON (the format Perfetto
  and ``chrome://tracing`` load directly).  Tracks become
  process/thread rows: the prefix before the first ``":"`` picks the
  process (``engine`` / ``link`` / ``pool`` / ``fabric``), the full
  track string the thread, so a fig10 run renders as one timeline row
  per tenant, per fabric link, and per pool actor.

* ``link_report`` — decompose a run's modeled seconds by fabric link
  (and link *tier*: XLink pod, CXL leaf, CXL spine, tier-2 trunk,
  tier-2 node): per-link busy seconds, utilization over the observed
  window, bytes carried, peak concurrent flows, and queueing delay
  (the contention-induced stretch of every transfer crossing the
  link).  This is the table the paper's attribution claims — and every
  ROADMAP follow-up (colocation, topology search) — are argued from.

Timestamps: modeled seconds are exported as microseconds (``ts``/
``dur`` are µs in trace_event), keeping sub-microsecond modeled events
visible at Perfetto's default zoom.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.trace import (PH_COUNTER, PH_INSTANT, PH_SPAN, Event,
                             Tracer)

_S_TO_US = 1e6

# link tiers of the scalepool estate, keyed off node kinds/names as
# built by ``fabric.Topology`` (from_inventory and the benchmark
# topologies use these conventions)
TIER_XLINK = "xlink-pod"        # accel <-> pod (scale-up XLink)
TIER_LEAF = "cxl-leaf"          # endpoint/pod <-> first switch tier
TIER_SPINE = "cxl-spine"        # switch <-> switch (coherence core)
TIER_TRUNK = "tier2-trunk"      # spine <-> capacity-fabric switch
TIER_NODE = "tier2-node"        # capacity switch <-> memory node
TIER_OTHER = "other"


def link_tier(link, topology=None) -> str:
    """Classify one fabric link into an estate tier.

    Accepts a ``fabric.topology.Link`` (preferred: endpoint kinds are
    authoritative) or a bare ``"src->dst"`` name (trace files carry
    only names; fall back to the naming conventions of
    ``Topology.from_inventory``)."""
    if hasattr(link, "src"):
        src, dst = link.src, link.dst
        kinds = topology.nodes if topology is not None else {}
    else:
        src, dst, kinds = *str(link).split("->", 1), {}

    def kind(n: str) -> str:
        if n in kinds:
            return kinds[n]
        for tag, k in (("accel:", "accel"), ("pod:", "pod"),
                       ("leaf:", "switch"), ("spine", "switch"),
                       ("t2sw", "switch"), ("mem:", "memory"),
                       ("sw", "switch")):
            if n.startswith(tag):
                return k
        return "endpoint"

    ks, kd = kind(src), kind(dst)
    if "accel" in (ks, kd):
        return TIER_XLINK
    if "t2sw" in (src, dst) and ks == kd == "switch":
        return TIER_TRUNK
    if "memory" in (ks, kd):
        return TIER_NODE
    if ks == kd == "switch":
        return TIER_SPINE
    if "switch" in (ks, kd):
        return TIER_LEAF
    return TIER_OTHER


# ---------------------------------------------------------------------------
# Chrome trace_event JSON
# ---------------------------------------------------------------------------

def _track_ids(tracks: List[str]) -> Dict[str, Tuple[int, int]]:
    """Stable (pid, tid) per track: pid by track-group prefix (before
    the first ':'), tid by track order within the group."""
    groups: Dict[str, List[str]] = {}
    for t in tracks:
        groups.setdefault(t.split(":", 1)[0], []).append(t)
    ids: Dict[str, Tuple[int, int]] = {}
    for pid, (group, members) in enumerate(sorted(groups.items()), start=1):
        for tid, track in enumerate(sorted(members), start=1):
            ids[track] = (pid, tid)
    return ids


def to_chrome_trace(tracer: Tracer, *,
                    extra_metadata: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, Any]:
    """The trace_event document as a dict (JSON Object Format:
    ``{"traceEvents": [...], ...}``), with one metadata block naming
    every track and recording flight-recorder losses."""
    events = tracer.events()
    ids = _track_ids([t for t in tracer.tracks()])
    out: List[Dict[str, Any]] = []
    for group in sorted({t.split(":", 1)[0] for t in ids}):
        pid = next(p for t, (p, _) in ids.items()
                   if t.split(":", 1)[0] == group)
        out.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                    "args": {"name": group}})
    for track, (pid, tid) in sorted(ids.items()):
        out.append({"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                    "args": {"name": track}})
    for e in events:
        pid, tid = ids[e.track]
        d: Dict[str, Any] = {"ph": e.ph, "cat": e.cat, "name": e.name,
                             "pid": pid, "tid": tid,
                             "ts": e.ts * _S_TO_US}
        if e.ph == PH_SPAN:
            d["dur"] = e.dur * _S_TO_US
        if e.ph == PH_INSTANT:
            d["s"] = "t"                      # thread-scoped instant
        if e.args:
            d["args"] = dict(e.args)
        out.append(d)
    meta = {"recorder_capacity": tracer.capacity,
            "recorder_dropped": tracer.dropped,
            "events_recorded": tracer.total_recorded,
            "clock": "modeled-seconds (exported as us)"}
    if extra_metadata:
        meta.update(extra_metadata)
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": meta}


def write_chrome_trace(tracer: Tracer, path: str, *,
                       extra_metadata: Optional[Dict[str, Any]] = None
                       ) -> Dict[str, Any]:
    doc = to_chrome_trace(tracer, extra_metadata=extra_metadata)
    with open(path, "w") as f:
        json.dump(doc, f, default=str)
        f.write("\n")
    return doc


def validate_trace_events(doc: Dict[str, Any]) -> List[str]:
    """Structural validation against the trace_event contract (the
    subset we emit).  Returns a list of problems — empty means the file
    loads in Perfetto/chrome://tracing.  Used by the determinism suite
    so exporter drift fails loudly."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, e in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(e, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "i", "C", "M"):
            problems.append(f"{where}: unknown ph {ph!r}")
            continue
        if "name" not in e:
            problems.append(f"{where}: missing name")
        if ph == "M":
            continue
        for key in ("pid", "tid", "ts"):
            if not isinstance(e.get(key), (int, float)):
                problems.append(f"{where}: {key} missing or non-numeric")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X event needs dur >= 0, "
                                f"got {dur!r}")
        if "args" in e and not isinstance(e["args"], dict):
            problems.append(f"{where}: args not an object")
    try:
        json.dumps(doc)
    except TypeError as err:        # pragma: no cover - defensive
        problems.append(f"not JSON-serializable: {err}")
    return problems


# ---------------------------------------------------------------------------
# per-link utilization / queueing-delay report
# ---------------------------------------------------------------------------

def link_report(transport, *, window_s: Optional[float] = None
                ) -> Dict[str, Dict[str, Any]]:
    """Per-link decomposition of a run's modeled transfer seconds,
    straight from a ``fabric.Transport``'s link accounting (call
    ``transport.quiesce()`` first if in-flight tails should count).

    Per link: ``tier``, ``busy_s`` (seconds >= 1 flow crossed it),
    ``bytes`` carried, ``util`` (busy fraction of the observed
    window), ``mean_rate`` while busy, ``peak_flows``, and
    ``stretch_s`` — the queueing delay: summed contention-induced
    excess (actual minus solo duration) of every transfer whose route
    crossed the link, the time attribution the fig10 claims are made
    from."""
    topo = transport.topology
    window = window_s if window_s is not None else transport.now
    out: Dict[str, Dict[str, Any]] = {}
    for name, link in sorted(topo.links.items()):
        busy = transport.link_busy_s.get(name, 0.0)
        nbytes = transport.link_bytes.get(name, 0.0)
        out[name] = {
            "tier": link_tier(link, topo),
            "capacity": link.capacity,
            "busy_s": busy,
            "bytes": nbytes,
            "util": busy / window if window > 0 else 0.0,
            "mean_rate": nbytes / busy if busy > 0 else 0.0,
            "peak_flows": transport.link_peak_flows.get(name, 0),
            "stretch_s": transport.link_stretch_s.get(name, 0.0),
            # payload bytes by flow label ("serve:a", "train:job0", ...)
            # — who occupied the link; empty for unlabeled traffic
            "by_label": dict(transport.link_label_bytes.get(name, {})),
        }
    return out


def tier_report(links: Dict[str, Dict[str, Any]]) -> Dict[str, Dict[str, Any]]:
    """Fold a ``link_report`` by estate tier — the "where did the
    modeled seconds go" table (XLink pod / CXL leaf / spine / tier-2
    trunk / tier-2 node)."""
    out: Dict[str, Dict[str, Any]] = {}
    for name, row in links.items():
        t = out.setdefault(row["tier"], {"links": 0, "busy_s": 0.0,
                                         "bytes": 0.0, "stretch_s": 0.0,
                                         "peak_flows": 0, "max_util": 0.0})
        t["links"] += 1
        t["busy_s"] += row["busy_s"]
        t["bytes"] += row["bytes"]
        t["stretch_s"] += row["stretch_s"]
        t["peak_flows"] = max(t["peak_flows"], row["peak_flows"])
        t["max_util"] = max(t["max_util"], row["util"])
    return out


def format_link_report(links: Dict[str, Dict[str, Any]], *,
                       window_s: Optional[float] = None) -> str:
    """Human-readable report (also what ``scripts/trace_report.py``
    prints): per-link rows sorted busiest-first, then the tier fold."""
    lines = []
    hdr = (f"{'link':34s} {'tier':12s} {'busy_s':>10s} {'util':>7s} "
           f"{'GB':>8s} {'peak':>5s} {'stretch_s':>10s}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    rows = sorted(links.items(), key=lambda kv: -kv[1]["busy_s"])
    for name, r in rows:
        lines.append(f"{name:34s} {r['tier']:12s} {r['busy_s']:10.4f} "
                     f"{r['util']:6.1%} {r['bytes'] / 1e9:8.3f} "
                     f"{r['peak_flows']:5d} {r['stretch_s']:10.4f}")
    lines.append("")
    lines.append("by tier:")
    for tier, r in sorted(tier_report(links).items(),
                          key=lambda kv: -kv[1]["busy_s"]):
        lines.append(f"  {tier:12s} links={r['links']:3d} "
                     f"busy={r['busy_s']:.4f}s "
                     f"max_util={r['max_util']:.1%} "
                     f"stretch={r['stretch_s']:.4f}s")
    if window_s is not None:
        lines.append(f"window: {window_s:.4f} modeled seconds")
    return "\n".join(lines)


def link_report_from_trace(doc: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    """Reconstruct the per-link report from an exported trace file
    alone (no live ``Transport``): link-occupancy spans carry bytes,
    solo duration, and tier in their args; busy seconds are the union
    of each link track's span intervals (concurrent flows overlap — a
    link is busy once, not once per flow)."""
    per_track: Dict[str, List[Tuple[float, float, Dict]]] = {}
    names: Dict[int, Dict[int, str]] = {}
    for e in doc.get("traceEvents", []):
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            names.setdefault(e["pid"], {})[e["tid"]] = e["args"]["name"]
    for e in doc.get("traceEvents", []):
        if e.get("ph") != "X":
            continue
        track = names.get(e.get("pid"), {}).get(e.get("tid"), "")
        if not track.startswith("link:"):
            continue
        per_track.setdefault(track[len("link:"):], []).append(
            (e["ts"] / _S_TO_US, (e["ts"] + e["dur"]) / _S_TO_US,
             e.get("args", {})))
    out: Dict[str, Dict[str, Any]] = {}
    for link, spans in sorted(per_track.items()):
        spans.sort(key=lambda sp: (sp[0], sp[1]))
        busy = 0.0
        cur_start, cur_end = spans[0][0], spans[0][1]
        peak, active = 1, []
        by_label: Dict[str, float] = {}
        for s, t, a in spans:
            if s > cur_end:
                busy += cur_end - cur_start
                cur_start, cur_end = s, t
            else:
                cur_end = max(cur_end, t)
            active = [e for e in active if e > s] + [t]
            peak = max(peak, len(active))
            if a.get("label") is not None:
                by_label[a["label"]] = (by_label.get(a["label"], 0.0)
                                        + a.get("bytes", 0.0))
        busy += cur_end - cur_start
        args0 = spans[0][2]
        out[link] = {
            "tier": args0.get("tier", link_tier(link)),
            "capacity": args0.get("capacity", 0.0),
            "busy_s": busy,
            "bytes": sum(a.get("bytes", 0.0) for _, _, a in spans),
            "util": 0.0,            # window unknown from spans alone
            "mean_rate": 0.0,
            "peak_flows": peak,
            "stretch_s": sum(max(0.0, (t - s) - a.get("solo_s", t - s))
                             for s, t, a in spans),
            "by_label": by_label,
        }
    window = max((t for spans in per_track.values()
                  for _, t, _ in spans), default=0.0)
    for r in out.values():
        r["util"] = r["busy_s"] / window if window > 0 else 0.0
        r["mean_rate"] = (r["bytes"] / r["busy_s"]
                          if r["busy_s"] > 0 else 0.0)
    return out

"""The ONE sanctioned stdout channel for ``src/repro`` runtime code.

The lint step (``repro.analysis.lints``' ``no-bare-print`` rule, run
in CI) forbids bare
``print(`` calls anywhere under ``src/repro`` so runtime reporting
cannot silently bypass the observability layer; this module is the
single exempt site.  CLI drivers (``repro.launch.*``) route their
user-facing output through ``emit`` / ``emit_json``, which keeps the
output stream greppable, flushable, and — if a future PR wants it —
redirectable to a structured sink without touching every call site.

This is deliberately thin: benchmarks and scripts (outside
``src/repro``) keep printing directly; library code inside
``src/repro`` should not be producing output at all unless it is a
CLI driver reporting through here.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Optional, TextIO


def emit(*parts: Any, sep: str = " ", end: str = "\n",
         stream: Optional[TextIO] = None, flush: bool = True) -> None:
    """Write one line of CLI output (the sanctioned ``print``)."""
    out = stream if stream is not None else sys.stdout
    out.write(sep.join(str(p) for p in parts) + end)
    if flush:
        out.flush()


def emit_json(obj: Any, *, indent: Optional[int] = 2,
              stream: Optional[TextIO] = None, **kwargs: Any) -> None:
    """Write a JSON document to stdout (CLI result envelopes)."""
    kwargs.setdefault("default", str)
    emit(json.dumps(obj, indent=indent, **kwargs), stream=stream)


def warn(*parts: Any) -> None:
    """Diagnostics go to stderr, never mixed into a JSON stdout."""
    emit("warning:", *parts, stream=sys.stderr)

"""Hierarchical metrics registry — ONE schema for every ``stats()``.

Before ``repro.obs`` each subsystem grew its own ad-hoc stats dict
(``Engine.stats``, ``Transport.stats``, ``PoolArbiter.stats``) with
divergent key conventions and no way to merge them into one report.
The registry replaces them behind a single hierarchical namespace:

    serve/<engine>/clock_s            fabric/transfers
    serve/<engine>/kv/spills          fabric/link/<name>/busy_s
    arbiter/tenant/<t>/hot_used       pool/sched/...

Subsystems implement ``metrics(registry=None, prefix=...)`` which
fills (and returns) a registry; the legacy ``stats()`` dicts are kept
working as *thin adapters* over the registry snapshot, so nothing
downstream breaks while all new reporting (benchmark ``--json``,
``scripts/trace_report.py``, CI artifacts) reads the one schema.

Three metric kinds, deliberately minimal:

``Counter``
    Monotone count (events, bytes).  ``inc`` only.
``Gauge``
    Point-in-time value of any JSON-serializable type (numbers for
    dashboards, the odd string label for identity fields).
``Histogram``
    Bounded reservoir of observations with deterministic nearest-rank
    percentiles — same indexing as ``serve.trace.latency_summary``.

Values are stored exactly as given (no float coercion): the adapters
must reproduce the legacy dicts bit-identically.
"""

from __future__ import annotations

import json
import math
from typing import Any, Dict, List, Optional


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def get(self):
        return self.value


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = None

    def set(self, v) -> None:
        self.value = v

    def get(self):
        return self.value


class Histogram:
    """Reservoir histogram: keeps up to ``cap`` observations (drops the
    tail deterministically, counting drops) and summarizes with
    nearest-rank percentiles (``ceil(p*n) - 1`` into the sorted
    sample — the repo-wide convention)."""

    __slots__ = ("cap", "values", "count", "total")

    def __init__(self, cap: int = 4096):
        self.cap = cap
        self.values: List[float] = []
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if len(self.values) < self.cap:
            self.values.append(v)

    def get(self) -> Dict[str, float]:
        return self.summary()

    def summary(self) -> Dict[str, float]:
        if not self.values:
            return {"n": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                    "max": 0.0}
        vs = sorted(self.values)
        pct = lambda p: vs[max(0, math.ceil(p * len(vs)) - 1)]
        return {"n": self.count, "mean": self.total / self.count,
                "p50": pct(0.50), "p95": pct(0.95), "max": vs[-1]}


class MetricsRegistry:
    """Get-or-create store of named metrics.  Names are ``/``-separated
    paths; ``snapshot()`` flattens to ``{path: value}`` and ``tree()``
    nests by path segment (the shape ``--json`` files serialize)."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, kind):
        m = self._metrics.get(name)
        if m is None:
            m = kind()
            self._metrics[name] = m
        elif not isinstance(m, kind):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def set(self, name: str, value) -> None:
        """Shorthand: ``gauge(name).set(value)`` — the bulk of the
        ``metrics()`` implementations are point-in-time snapshots."""
        self.gauge(name).set(value)

    # ---- reading ---------------------------------------------------------
    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def value(self, name: str):
        return self._metrics[name].get()

    def snapshot(self, prefix: str = "") -> Dict[str, Any]:
        """Flat ``{name: value}`` of every metric under ``prefix``."""
        return {n: m.get() for n, m in sorted(self._metrics.items())
                if n.startswith(prefix)}

    def tree(self) -> Dict[str, Any]:
        """Nested dict keyed by path segments."""
        out: Dict[str, Any] = {}
        for name, m in sorted(self._metrics.items()):
            parts = name.split("/")
            node = out
            for p in parts[:-1]:
                node = node.setdefault(p, {})
                if not isinstance(node, dict):
                    raise ValueError(f"metric {name!r} nests under the "
                                     f"leaf metric {p!r}")
            node[parts[-1]] = m.get()
        return out


def adapt(snapshot: Dict[str, Any], mapping: Dict[str, str]) -> Dict[str, Any]:
    """Thin legacy-``stats()`` adapter: ``{old_key: registry_path}`` →
    ``{old_key: value}``.  Raises on a missing path so schema drift is
    an error, not a silently absent key."""
    return {old: snapshot[path] for old, path in mapping.items()}


def write_json(path: str, name: str, metrics: Dict[str, Any], *,
               extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Write one benchmark's headline metrics as a machine-readable
    JSON document (the ``--json PATH`` satellite): a stable envelope
    around the registry tree / summary dict so downstream tooling can
    diff runs without scraping stdout CSV."""
    doc = {"schema": "repro.obs/bench-v1", "bench": name,
           "metrics": metrics}
    if extra:
        doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    return doc

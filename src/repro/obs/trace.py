"""Typed event tracing on the modeled clock — the flight recorder.

Every modeled-time subsystem (``fabric.Transport``, ``serve.Engine``,
``serve.PoolArbiter``, ``pool.Scheduler``) accepts a ``Tracer`` and
emits typed events at the *modeled* timestamps its cost models already
compute: request lifecycle spans (submit → admit → prefill → decode →
finish, with pause/spill/fetch/recompute sub-events), per-transfer
link-occupancy spans carrying the fair-share rate at every re-rating
interval, arbiter revocation/charge events, and pool-scheduler job
admit/gang/run events.  The paper's headline numbers are *attribution*
claims — modeled seconds must be assignable to XLink hops, CXL switch
tiers, and tier-2 trunks — and this module is where the assignment is
recorded.

Design constraints, in order:

* **zero cost when disabled** — the module-level ``NULL_TRACER`` is a
  disabled singleton whose emit methods are no-ops; hot paths guard
  argument construction behind ``tracer.enabled`` so a tracer-less run
  executes the exact instruction stream it did before instrumentation
  (modeled clocks are never read *or* advanced by tracing: events are
  passive observations of clocks the subsystems already computed);
* **deterministic** — events carry only modeled quantities, so the
  same seed/trace produces a bit-identical event stream across runs,
  hosts, and ``Engine.local`` vs single-tenant-under-arbiter (the
  determinism suite in ``tests/test_obs.py`` pins this);
* **bounded** — events land in a fixed-capacity ring buffer ("flight
  recorder") with O(1) append: a million-step run keeps the most
  recent ``capacity`` events and counts the rest in ``dropped``
  instead of growing without bound.

Tracks are plain strings naming the timeline an event belongs to —
``"engine:a"``, ``"engine:a/requests"``, ``"link:spine->t2sw"``,
``"pool:arbiter"``, ``"pool:sched"``.  The Perfetto exporter
(``repro.obs.export``) groups them into process/thread rows by the
prefix before the first ``":"``.
"""

from __future__ import annotations

import json
import os
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

# Chrome trace_event phase tags (the subset the exporter emits)
PH_SPAN = "X"          # complete event: ts + dur
PH_INSTANT = "i"       # point event
PH_COUNTER = "C"       # sampled value

# event categories (the ``cat`` field): one per subsystem surface, so
# viewers and reports can filter without parsing event names
CAT_REQUEST = "request"     # request lifecycle (submit..finish)
CAT_ENGINE = "engine"       # engine scheduling (prefill/decode steps)
CAT_KV = "kv"               # paging traffic (pause/spill/fetch/drop)
CAT_LINK = "link"           # per-transfer link occupancy
CAT_FABRIC = "fabric"       # whole-transfer spans on the transport
CAT_ARBITER = "arbiter"     # revocation / charge events
CAT_SCHED = "sched"         # pool scheduler job events


class Event(Tuple):
    """One trace event: an immutable tuple subclass so ring-buffer
    wraps can never corrupt a recorded event in place.

    Layout: ``(ph, cat, track, name, ts, dur, args)`` with ``ts``/
    ``dur`` in modeled seconds and ``args`` a (possibly empty) dict of
    JSON-serializable details.
    """

    __slots__ = ()

    def __new__(cls, ph: str, cat: str, track: str, name: str,
                ts: float, dur: float = 0.0,
                args: Optional[Dict[str, Any]] = None):
        return super().__new__(cls, (ph, cat, track, name, float(ts),
                                     float(dur), args or {}))

    @property
    def ph(self) -> str:
        return self[0]

    @property
    def cat(self) -> str:
        return self[1]

    @property
    def track(self) -> str:
        return self[2]

    @property
    def name(self) -> str:
        return self[3]

    @property
    def ts(self) -> float:
        return self[4]

    @property
    def dur(self) -> float:
        return self[5]

    @property
    def args(self) -> Dict[str, Any]:
        return self[6]


class Tracer:
    """Flight recorder: a bounded ring of typed events, O(1) append.

    ``capacity`` bounds resident events; once full, the oldest event is
    overwritten and ``dropped`` increments.  ``events()`` returns the
    surviving events oldest-first.  All emit methods are safe on the
    hot path; when profiling shows even the guarded calls matter, pass
    ``NULL_TRACER`` (or nothing) and they vanish behind ``enabled``.
    """

    enabled: bool = True

    def __init__(self, capacity: int = 1 << 16):
        if capacity <= 0:
            raise ValueError("tracer capacity must be positive")
        self.capacity = int(capacity)
        self._ring: List[Optional[Event]] = [None] * self.capacity
        self._next = 0              # next write position
        self._count = 0             # events ever recorded
        self.dropped = 0            # events overwritten by the ring
        self._hooks: List[Callable[[Event], None]] = []

    # ---- recording -------------------------------------------------------
    def _append(self, ev: Event) -> None:
        i = self._next
        if self._ring[i] is not None:
            self.dropped += 1
        self._ring[i] = ev
        self._next = (i + 1) % self.capacity
        self._count += 1
        if self._hooks:
            for hook in self._hooks:
                hook(ev)

    def add_hook(self, hook: Callable[[Event], None]) -> None:
        """Register a callable invoked with EVERY recorded event, before
        the ring can drop it — the live tap ``repro.analysis``'s
        modeled-time sanitizer checks a run through without waiting for
        an export.  Hooks must be passive (never mutate modeled state)
        and cheap; they run on the emit path."""
        self._hooks.append(hook)

    def remove_hook(self, hook: Callable[[Event], None]) -> None:
        self._hooks.remove(hook)

    def span(self, track: str, name: str, ts: float, dur: float, *,
             cat: str = CAT_ENGINE, **args: Any) -> None:
        """A completed interval ``[ts, ts + dur]`` on ``track``."""
        self._append(Event(PH_SPAN, cat, track, name, ts, dur, args))

    def instant(self, track: str, name: str, ts: float, *,
                cat: str = CAT_ENGINE, **args: Any) -> None:
        """A point event at modeled time ``ts``."""
        self._append(Event(PH_INSTANT, cat, track, name, ts, 0.0, args))

    def counter(self, track: str, name: str, ts: float, value: float, *,
                cat: str = CAT_ENGINE) -> None:
        """A sampled value (renders as a counter track in Perfetto)."""
        self._append(Event(PH_COUNTER, cat, track, name, ts, 0.0,
                           {"value": value}))

    # ---- reading ---------------------------------------------------------
    def __len__(self) -> int:
        return min(self._count, self.capacity)

    @property
    def total_recorded(self) -> int:
        """Events ever emitted (including ones the ring dropped)."""
        return self._count

    def events(self) -> List[Event]:
        """Surviving events, oldest first (append order — subsystems
        emit at monotone modeled times per track, but tracks interleave
        by *emission* order, which is itself deterministic)."""
        if self._count <= self.capacity:
            return [e for e in self._ring[:self._next] if e is not None]
        return ([e for e in self._ring[self._next:] if e is not None]
                + [e for e in self._ring[:self._next] if e is not None])

    def tracks(self) -> List[str]:
        seen: Dict[str, None] = {}
        for e in self.events():
            seen.setdefault(e.track)
        return list(seen)

    def iter_track(self, track: str) -> Iterator[Event]:
        return (e for e in self.events() if e.track == track)

    def clear(self) -> None:
        self._ring = [None] * self.capacity
        self._next = 0
        self._count = 0
        self.dropped = 0


class NullTracer(Tracer):
    """The disabled tracer: every emit is a no-op and ``enabled`` is
    False so instrumentation sites can skip argument construction
    entirely.  A process-wide singleton (``NULL_TRACER``) is the
    default everywhere a tracer is threadable."""

    enabled = False

    def __init__(self):
        super().__init__(capacity=1)

    def span(self, *a: Any, **kw: Any) -> None:
        pass

    def instant(self, *a: Any, **kw: Any) -> None:
        pass

    def counter(self, *a: Any, **kw: Any) -> None:
        pass


NULL_TRACER = NullTracer()


class JsonlSink:
    """Streaming trace sink: one JSON object per recorded event, written
    through a ``Tracer.add_hook`` tap *before* the ring can drop it.

    The ring bounds what survives in memory; the sink bounds nothing —
    a million-step run streams a complete, lossless event log to disk
    in the tracer's native units (modeled seconds, full float precision)
    rather than the exporter's µs-integer Chrome encoding.  The line
    format is the ``Event`` tuple by name::

        {"ph": "X", "cat": "link", "track": "link:a->b",
         "name": "xfer", "ts": 0.0125, "dur": 0.004, "args": {...}}

    ``events_from_jsonl`` reads the stream back into ``Event`` objects,
    so the sanitizer and ``analysis.tracediff`` consume streamed logs
    and ring exports interchangeably.  Use as a context manager or call
    ``close()``; the hook detaches on close.

    Rotation: with ``max_bytes`` set, the sink switches to a fresh
    sequential segment (``path``, ``path.1``, ``path.2``, ...) before a
    write would push the current one past the cap — segments are never
    renamed, so the numeric suffix *is* the chronological order and an
    in-flight reader never sees a file change identity under it.  A
    single line larger than ``max_bytes`` still lands (in a segment of
    its own) — rotation bounds segment size, it never drops an event.
    ``max_files`` is a retention cap: once exceeded, the *oldest* live
    segment is deleted, making the sink a coarse-grained disk-bounded
    ring (``events_from_jsonl`` on the surviving set is then a
    truncated recording — pass the sanitizer ``truncated=True``).
    """

    def __init__(self, path: str, tracer: Optional["Tracer"] = None, *,
                 max_bytes: Optional[int] = None,
                 max_files: Optional[int] = None):
        if max_bytes is not None and max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        if max_files is not None and max_files < 1:
            raise ValueError(f"max_files must be >= 1, got {max_files}")
        self.path = path
        self.max_bytes = max_bytes
        self.max_files = max_files
        self._seq = 0
        self._live: List[str] = [path]
        self._bytes = 0
        self._f = open(path, "w")
        self.written = 0
        self._tracer: Optional[Tracer] = None
        if tracer is not None:
            self.attach(tracer)

    @property
    def paths(self) -> List[str]:
        """Live segments in chronological (write) order."""
        return list(self._live)

    def attach(self, tracer: "Tracer") -> "JsonlSink":
        if self._tracer is not None:
            raise RuntimeError("JsonlSink is already attached")
        tracer.add_hook(self._on_event)
        self._tracer = tracer
        return self

    def _on_event(self, ev: Event) -> None:
        line = json.dumps(
            {"ph": ev.ph, "cat": ev.cat, "track": ev.track,
             "name": ev.name, "ts": ev.ts, "dur": ev.dur,
             "args": ev.args},
            separators=(",", ":"), sort_keys=True) + "\n"
        if self.max_bytes is not None and self._bytes > 0 \
                and self._bytes + len(line) > self.max_bytes:
            self._rotate()
        self._f.write(line)
        self._bytes += len(line)
        self.written += 1

    def _rotate(self) -> None:
        self._f.close()
        self._seq += 1
        nxt = f"{self.path}.{self._seq}"
        self._f = open(nxt, "w")
        self._bytes = 0
        self._live.append(nxt)
        if self.max_files is not None:
            while len(self._live) > self.max_files:
                os.remove(self._live.pop(0))

    def close(self) -> None:
        if self._tracer is not None:
            self._tracer.remove_hook(self._on_event)
            self._tracer = None
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


def rotated_jsonl_paths(path: str) -> List[str]:
    """The on-disk segment set a (possibly rotated) ``JsonlSink`` left
    behind, in chronological order: ``path`` (if it survived retention)
    then ``path.1``, ``path.2``, ... by numeric suffix.  Gaps are fine
    — ``max_files`` retention deletes from the oldest end."""
    base = os.path.basename(path)
    d = os.path.dirname(path) or "."
    found: List[Tuple[int, str]] = []
    if os.path.exists(path):
        found.append((0, path))
    if os.path.isdir(d):
        for fn in os.listdir(d):
            suffix = fn[len(base) + 1:]
            if fn.startswith(base + ".") and suffix.isdigit():
                found.append((int(suffix), os.path.join(d, fn)))
    return [p for _, p in sorted(found)]


def events_from_jsonl(path: str) -> List[Event]:
    """Rebuild ``Event`` objects from a ``JsonlSink`` stream — a single
    file or a rotated segment set (``path``, ``path.1``, ...), read in
    chronological order.  Skips blank lines; raises with file and line
    number on a malformed one."""
    paths = rotated_jsonl_paths(path) or [path]   # let open() raise
    out: List[Event] = []
    for p in paths:
        with open(p) as f:
            for lineno, line in enumerate(f, start=1):
                line = line.strip()
                if not line:
                    continue
                try:
                    d = json.loads(line)
                    out.append(Event(d["ph"], d["cat"], d["track"],
                                     d["name"], d["ts"],
                                     d.get("dur", 0.0),
                                     d.get("args") or {}))
                except (ValueError, KeyError, TypeError) as e:
                    raise ValueError(
                        f"{p}:{lineno}: bad trace event line: {e}") from e
    return out


def resolve(tracer: Optional[Tracer]) -> Tracer:
    """``tracer or NULL_TRACER`` with a type check close to the API
    boundary (a mis-passed registry or bool fails here, not deep in a
    hot loop)."""
    if tracer is None:
        return NULL_TRACER
    if not isinstance(tracer, Tracer):
        raise TypeError(f"expected a repro.obs.Tracer, got {tracer!r}")
    return tracer

"""repro.obs — unified observability for every modeled-time subsystem.

The lens the repro's attribution claims are argued through:

    trace   — ``Tracer``: typed span/instant/counter events on the
              modeled clock, recorded into a bounded ring buffer
              ("flight recorder", O(1) append); ``NULL_TRACER`` is the
              zero-cost disabled default every subsystem falls back to
    metrics — ``MetricsRegistry``: hierarchical counter/gauge/histogram
              registry; the legacy per-subsystem ``stats()`` dicts are
              thin adapters over it
    export  — Chrome/Perfetto ``trace_event`` JSON export (tracks =
              tenants, engines, links, pool) and the per-link
              utilization / queueing-delay report that decomposes a
              run's modeled seconds by fabric tier
    console — the one sanctioned stdout channel for ``src/repro`` CLI
              drivers (bare ``print(`` is linted out of the library)

Quickstart::

    from repro.obs import Tracer, write_chrome_trace, link_report

    tr = Tracer()
    tx = Transport(topology, tracer=tr)
    eng = Engine.local(model, cfg, transport=tx, route=r, tracer=tr)
    run_trace(eng, trace)
    tx.quiesce()
    write_chrome_trace(tr, "run.json")          # open in ui.perfetto.dev
    print(format_link_report(link_report(tx)))  # modeled-seconds by link
"""

from repro.obs.export import (format_link_report, link_report,
                              link_report_from_trace, link_tier,
                              tier_report, to_chrome_trace,
                              validate_trace_events, write_chrome_trace)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               adapt, write_json)
from repro.obs.trace import (CAT_ARBITER, CAT_ENGINE, CAT_FABRIC, CAT_KV,
                             CAT_LINK, CAT_REQUEST, CAT_SCHED, NULL_TRACER,
                             Event, JsonlSink, NullTracer, Tracer,
                             events_from_jsonl, resolve,
                             rotated_jsonl_paths)

__all__ = [
    "CAT_ARBITER", "CAT_ENGINE", "CAT_FABRIC", "CAT_KV", "CAT_LINK",
    "CAT_REQUEST", "CAT_SCHED", "Counter", "Event", "Gauge", "Histogram",
    "JsonlSink", "MetricsRegistry", "NULL_TRACER", "NullTracer", "Tracer",
    "adapt", "events_from_jsonl", "format_link_report", "link_report",
    "link_report_from_trace", "link_tier", "resolve",
    "rotated_jsonl_paths", "tier_report",
    "to_chrome_trace", "validate_trace_events", "write_chrome_trace",
    "write_json",
]

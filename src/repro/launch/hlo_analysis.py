"""Post-SPMD HLO analysis: collective inventory + roofline terms.

``compiled.cost_analysis()`` gives HLO_FLOPs / HLO_bytes but NOT
collective traffic; we parse ``compiled.as_text()`` and sum per-op moved
bytes with standard ring-algorithm accounting, classifying each op by
whether its replica group crosses the pod boundary (the ScalePool
inter-cluster fabric).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s*[,)]")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a possibly-tuple HLO shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    crosses_pod: bool
    moved_bytes: float  # per-device bytes on the wire (ring accounting)


def _group_info(line: str, pod_size: Optional[int]) -> Tuple[int, bool]:
    """(group_size, crosses_pod) from a collective's replica_groups."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota groups [G,S]<=[dims]T(perm): exact membership — iota over
        # dims, transposed by perm, reshaped (G,S); a group crosses the
        # pod boundary iff its members span device-id // pod_size values.
        import numpy as np
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        dims = [int(t) for t in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(t) for t in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(n_groups, group_size)
        crosses = False
        if pod_size is not None and group_size > 1:
            crosses = bool(np.any(groups // pod_size
                                  != groups[:, :1] // pod_size))
        return group_size, crosses
    m = _GROUPS_RE.search(line)
    if not m:
        return 1, False
    groups = m.group(1)
    first = groups.split("}")[0].strip("{} ")
    if not first:
        return 1, False
    ids = [int(t) for t in first.split(",") if t.strip().isdigit()]
    size = max(1, len(ids))
    crosses = False
    if pod_size is not None and ids:
        pods = {i // pod_size for i in ids}
        crosses = len(pods) > 1
    return size, crosses


def moved_bytes(kind: str, result_bytes: int, n: int) -> float:
    """Per-device wire bytes under ring algorithms."""
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * frac * result_bytes
    if kind == "all-gather":
        return frac * result_bytes            # result is the gathered buffer
    if kind == "reduce-scatter":
        return frac * result_bytes * n        # result is the scattered shard
    if kind == "all-to-all":
        return frac * result_bytes
    if kind == "collective-permute":
        return float(result_bytes)
    return 0.0


def parse_collectives(hlo_text: str, pod_size: Optional[int] = None
                      ) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        rb = shape_bytes(shape_str)
        if rb == 0:
            continue
        size, crosses = _group_info(line, pod_size)
        ops.append(CollectiveOp(kind, rb, size, crosses,
                                moved_bytes(kind, rb, size)))
    return ops


def collective_summary(ops: List[CollectiveOp]) -> Dict[str, float]:
    out: Dict[str, float] = {"total_moved_bytes": 0.0,
                             "cross_pod_moved_bytes": 0.0, "n_ops": len(ops)}
    for op in ops:
        out["total_moved_bytes"] += op.moved_bytes
        if op.crosses_pod:
            out["cross_pod_moved_bytes"] += op.moved_bytes
        key = f"{op.kind}_bytes"
        out[key] = out.get(key, 0.0) + op.moved_bytes
        out[f"{op.kind}_count"] = out.get(f"{op.kind}_count", 0) + 1
    return out


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e constants per the assignment)
# ---------------------------------------------------------------------------

V5E_PEAK_FLOPS = 197e12        # bf16 / chip
V5E_HBM_BW = 819e9             # bytes/s / chip
V5E_ICI_BW = 50e9              # bytes/s per link (~3 links usable / chip)


def roofline_terms(cost: Dict[str, float], coll: Dict[str, float],
                   n_chips: int, model_flops: Optional[float] = None
                   ) -> Dict[str, float]:
    """Three roofline terms in seconds + diagnostics.

    cost_analysis flops/bytes are whole-program (all devices) on some
    backends and per-partition on others; on the CPU host-device backend
    they are per-program-instance (the SPMD module is compiled once), so
    we treat them as per-device values.
    """
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    compute_t = flops / V5E_PEAK_FLOPS
    memory_t = bytes_ / V5E_HBM_BW
    coll_t = float(coll.get("total_moved_bytes", 0.0)) / V5E_ICI_BW
    dominant = max(("compute", compute_t), ("memory", memory_t),
                   ("collective", coll_t), key=lambda kv: kv[1])[0]
    out = dict(compute_s=compute_t, memory_s=memory_t, collective_s=coll_t,
               dominant=dominant, hlo_flops=flops, hlo_bytes=bytes_,
               collective_bytes=float(coll.get("total_moved_bytes", 0.0)),
               cross_pod_bytes=float(coll.get("cross_pod_moved_bytes", 0.0)))
    if model_flops:
        per_dev = model_flops / n_chips
        out["model_flops_per_device"] = per_dev
        out["useful_flops_ratio"] = per_dev / flops if flops else 0.0
    return out

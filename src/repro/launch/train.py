"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 100 --batch 8 --seq 128 --dp-mode hierarchical

Runs the full stack: data pipeline → sharded train step (GSPMD + optional
hierarchical cross-pod phase) → AdamW → async checkpointing → fault-
tolerant loop with straggler monitoring.  On real hardware the same
driver runs under jax.distributed with the production mesh; on CPU it
uses whatever devices exist (force more with XLA_FLAGS).
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.compat import IS_OLD_JAX, mesh_context
from repro.core.tiering import TieringPolicy, offload_state_shardings
from repro.data.pipeline import DataConfig, DataPipeline
from repro.ckpt import checkpoint as ckpt
from repro.launch.mesh import make_smoke_mesh
from repro.models.api import build_model
from repro.models.config import ShapeConfig
from repro.obs.console import emit_json, warn
from repro.optim.adamw import AdamW
from repro.runtime import train as train_rt
from repro.runtime.ft import FaultTolerantLoop, StragglerMonitor
from repro.sharding.partition import use_rules
from repro.sharding.profiles import hierarchical_unsafe, make_rules


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="olmo-1b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--dp-mode", default="auto", choices=["auto", "hierarchical"])
    p.add_argument("--compress-pod", action="store_true")
    p.add_argument("--offload-optimizer", action="store_true")
    # ---- pool-orchestrated resources (repro.pool) ----
    p.add_argument("--pool", default="none",
                   choices=["none", "scalepool", "baseline", "contention"],
                   help="obtain mesh + tiering from a resource-pool lease "
                        "(contention = scalepool estate with overlap-"
                        "aware placement for co-resident jobs)")
    p.add_argument("--pool-accels", type=int, default=8)
    p.add_argument("--pool-tier2-gb", type=float, default=0.0)
    p.add_argument("--pool-model-parallel", type=int, default=1)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    optimizer = AdamW(lr=args.lr)
    shape = ShapeConfig("cli", "train", args.seq, args.batch,
                        microbatches=args.microbatches)

    lease = None
    tier_policy = TieringPolicy() if args.offload_optimizer else None
    if args.pool != "none":
        # the orchestrator decides mesh shape AND tiering: a lease with a
        # tier-2 reservation trains with optimizer state in the capacity
        # tier; one without keeps everything in HBM.
        from repro.pool import smoke_pool
        pool = smoke_pool(args.pool)
        lease = pool.lease("cli-train", args.pool_accels,
                           tier2_gb=args.pool_tier2_gb,
                           model_parallel=args.pool_model_parallel)
        mesh, tier_policy = lease.materialize()
        if args.offload_optimizer and not tier_policy.offload_optimizer:
            # explicit flag without a tier-2 reservation: honor it (host
            # memory stands in for the capacity tier) but say so.
            warn("--offload-optimizer with a 0-byte tier-2 lease; "
                 "offloading to host memory (pass --pool-tier2-gb to "
                 "reserve pool capacity)")
            tier_policy = dataclasses.replace(tier_policy,
                                              offload_optimizer=True)
    else:
        mesh = make_smoke_mesh()
    multi_pod = "pod" in mesh.axis_names
    dp_mode = args.dp_mode if multi_pod else "auto"
    if dp_mode == "hierarchical":
        reason = hierarchical_unsafe(cfg)
        if reason:
            warn(f"{reason}; falling back to dp_mode=auto")
            dp_mode = "auto"
    rules = make_rules(cfg, shape, mesh, fsdp=False, dp_mode=dp_mode)
    tcfg = train_rt.TrainStepConfig(dp_mode=dp_mode,
                                    compress_pod=args.compress_pod,
                                    microbatches=args.microbatches)

    rng = jax.random.PRNGKey(0)
    state = train_rt.init_state(model, optimizer, rng, tcfg)
    step_fn, state_sh = train_rt.make_train_step(
        model, optimizer, shape, mesh=mesh, rules=rules, tcfg=tcfg)
    if state_sh is not None and tier_policy is not None \
            and tier_policy.offload_optimizer:
        state_sh = offload_state_shardings(state_sh, tier_policy)

    pipe = DataPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                   global_batch=args.batch))

    # jax 0.4.x XLA hard-crashes (IsManualSubgroup CHECK) when donation
    # meets the partially-manual pod shard_map; trade memory for survival.
    donate = () if (dp_mode == "hierarchical" and IS_OLD_JAX) else (0,)
    jit_step = jax.jit(step_fn, donate_argnums=donate)

    def train_step(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        with use_rules(rules, mesh), mesh_context(mesh):
            return jit_step(state, batch)

    ckpt_dir = Path(args.ckpt_dir)
    last = {"state": state, "step": 0}

    def save_fn(s, step):
        last["state"], last["step"] = s, step
        ckpt.save(ckpt_dir / f"step{step}",
                  {"params": s.params, "mu": s.opt.mu, "nu": s.opt.nu},
                  step=step, extra={"pipeline": pipe.state.to_dict()},
                  asynchronous=True)

    def restore_fn():
        return last["state"], last["step"]

    loop = FaultTolerantLoop(train_step, save_fn, restore_fn, pipe,
                             ckpt_every=args.ckpt_every,
                             monitor=StragglerMonitor())

    t0 = time.time()
    state = loop.run(state, args.steps)
    dt = time.time() - t0

    losses = [h["loss"] for h in loop.history]
    emit_json({
        "arch": cfg.name, "steps": args.steps,
        "devices": len(jax.devices()), "mesh": dict(zip(mesh.axis_names,
                                                        mesh.devices.shape)),
        "dp_mode": dp_mode,
        "lease": (None if lease is None else {
            "pods": list(lease.allocation.pod_ids),
            "accels": lease.n_accels,
            "tier2_gb": lease.tier2_bytes / 1e9,
            "offload_optimizer": tier_policy.offload_optimizer}),
        "loss_first": losses[0], "loss_last": losses[-1],
        "loss_drop": losses[0] - losses[-1],
        "wall_s": round(dt, 1), "s_per_step": round(dt / args.steps, 3),
        "straggler_events": len(loop.monitor.events),
        "restarts": loop.restarts,
    })
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""One dry-run cell: lowering, compiling, two-point cost extrapolation.

Split from dryrun.py so benchmarks/tests can import without re-setting
XLA_FLAGS (dryrun.py sets the 512-device flag at import).
"""

from __future__ import annotations

import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import compat
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model, input_specs, layer_scan_trips
from repro.models.config import SHAPES, ShapeConfig, supports_shape
from repro.models.unroll import unroll_mode
from repro.optim.adamw import AdamW
from repro.runtime import serve as serve_rt
from repro.runtime import train as train_rt
from repro.sharding.partition import tree_shardings, use_rules
from repro.sharding.profiles import make_rules

# per-arch gradient-accumulation microbatch counts for train_4k: keeps the
# live activation footprint inside v5e HBM (16 GB) at global batch 256.
TRAIN_MICROBATCHES = {
    "command-r-plus-104b": 16,
    "qwen3-14b": 4,
    "pixtral-12b": 4,
    "mixtral-8x7b": 8,
    "zamba2-7b": 8,
    "olmoe-1b-7b": 2,
    "whisper-small": 2,
}


def _fix_divisibility(shape, sharding):
    """Drop partitioning on dims the sharding doesn't divide evenly
    (explicit in_shardings require exact divisibility, unlike internal
    GSPMD constraints which pad)."""
    mesh = sharding.mesh
    ax_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = list(sharding.spec) + [None] * (len(shape) - len(sharding.spec))
    changed = False
    for i, (dim, entry) in enumerate(zip(shape, spec)):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        n = 1
        for a in axes:
            n *= ax_size[a]
        if dim % n != 0:
            spec[i] = None
            changed = True
    if not changed:
        return sharding
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec(*spec))


def _attach(specs, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=_fix_divisibility(s.shape, sh)),
        specs, shardings)


def _lower_and_compile(cfg, shape, mesh, rules, model, optimizer, *,
                       dp_mode: str, donate: bool, compress_pod: bool = False):
    if shape.kind == "train":
        tcfg = train_rt.TrainStepConfig(
            dp_mode=dp_mode, microbatches=shape.microbatches, remat=True,
            compress_pod=compress_pod)
        step, state_sh = train_rt.make_train_step(
            model, optimizer, shape, mesh=mesh, rules=rules, tcfg=tcfg)
        state_specs = jax.eval_shape(
            lambda: train_rt.init_state(model, optimizer,
                                        jax.random.PRNGKey(0), tcfg))
        state_specs = _attach(state_specs, state_sh)
        b_specs = input_specs(cfg, shape)
        b_specs = _attach(b_specs, train_rt.batch_shardings(mesh, rules, b_specs))
        fn = jax.jit(step, donate_argnums=(0,) if donate else ())
        return fn.lower(state_specs, b_specs)
    if shape.kind == "prefill":
        pf = serve_rt.make_prefill_step(model)
        cache_specs = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     dtype=jnp.bfloat16))
        cache_specs = _attach(cache_specs,
                              tree_shardings(mesh, rules, model.cache_axes()))
        p_specs = _attach(jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))),
                          tree_shardings(mesh, rules, model.param_axes()))
        b_specs = input_specs(cfg, shape)
        b_specs = _attach(b_specs, train_rt.batch_shardings(mesh, rules, b_specs))
        fn = jax.jit(pf, donate_argnums=(2,) if donate else ())
        return fn.lower(p_specs, b_specs, cache_specs)
    dec = serve_rt.make_decode_step(model)
    carry_specs = serve_rt.decode_carry_specs(model, shape)
    carry_specs = _attach(carry_specs,
                          serve_rt.decode_carry_shardings(model, mesh, rules, shape))
    p_specs = _attach(jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))),
                      tree_shardings(mesh, rules, model.param_axes()))
    fn = jax.jit(dec, donate_argnums=(1,) if donate else ())
    return fn.lower(p_specs, carry_specs)


def _measure(cfg, shape, mesh, rules, model, optimizer, pod_size, *,
             dp_mode, donate, mode, compress_pod=False):
    """Compile under one unroll mode; return (cost, coll_summary, mem, dt)."""
    t0 = time.time()
    with use_rules(rules, mesh), unroll_mode(mode):
        lowered = _lower_and_compile(cfg, shape, mesh, rules, model,
                                     optimizer, dp_mode=dp_mode, donate=donate,
                                     compress_pod=compress_pod)
        compiled = lowered.compile()
    dt = time.time() - t0
    cost = compat.cost_analysis(compiled)
    colls = H.parse_collectives(compiled.as_text(), pod_size=pod_size)
    csum = H.collective_summary(colls)
    mem = compiled.memory_analysis()
    return cost, csum, mem, dt


_COST_KEYS = ("flops", "bytes accessed", "transcendentals")


def _affine_combine(m1: Dict, m2: Dict, trips: int) -> Dict:
    """cost(k) = outside + k*body  →  outside + trips*body."""
    out = {}
    for k in set(m1) | set(m2):
        a, b = float(m1.get(k, 0.0)), float(m2.get(k, 0.0))
        body = b - a
        if k.endswith("_count") or k == "n_ops":
            out[k] = a + (trips - 1) * body
        else:
            out[k] = a + (trips - 1) * body
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               smoke: bool = False, dp_mode: str = "auto",
               fsdp: bool = True, donate: bool = True,
               mode: str = "extrapolate",
               cfg_patch: Optional[Dict] = None,
               rules_patch: Optional[Dict] = None,
               micro_override: Optional[int] = None,
               compress_pod: bool = False) -> Dict:
    """Lower+compile one cell; returns the result record.

    Cost-analysis fidelity (XLA counts while bodies once):
      mode="extrapolate" — compile at unroll=1 and unroll=2; per-layer
        cost = difference; total = outside + trips*body.  Exact for the
        layer-homogeneous scans used by every family (inner heterogenous
        scans are fully unrolled in both).
      mode="full" — fully unroll layer scans (validation path).
    Train cells lower ONE gradient microbatch (global_batch/microbatches)
    and scale flops/bytes/collectives by ``flops_scale``.
    """
    cfg = get_config(arch, smoke=smoke)
    if cfg_patch:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **cfg_patch)
    shape = SHAPES[shape_name]
    ok, reason = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "SKIP", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_data = ax.get("pod", 1) * ax.get("data", 1)
    pod_size = n_chips // ax.get("pod", 1)

    flops_scale = 1
    if shape.kind == "train":
        micro = TRAIN_MICROBATCHES.get(arch, 1) if not smoke else 1
        if micro_override:
            micro = micro_override
        # per-microbatch batch must still cover the data shards
        micro = max(1, min(micro, shape.global_batch // n_data))
        flops_scale = micro
        shape = ShapeConfig(shape.name, shape.kind, shape.seq_len,
                            max(1, shape.global_batch // micro), microbatches=1)

    if dp_mode == "hierarchical":
        from repro.sharding.profiles import hierarchical_unsafe
        reason = hierarchical_unsafe(cfg)
        if reason:
            return {"arch": arch, "shape": shape_name,
                    "mesh": "multi" if multi_pod else "single",
                    "status": "SKIP", "reason": reason}
    rules = make_rules(cfg, shape, mesh, fsdp=fsdp, dp_mode=dp_mode)
    if rules_patch:
        rules = rules.override(**rules_patch)
    model = build_model(cfg, moe_groups=n_data)
    optimizer = AdamW()
    trips = layer_scan_trips(cfg)

    if mode == "full":
        cost, csum, mem, dt1 = _measure(cfg, shape, mesh, rules, model,
                                        optimizer, pod_size, dp_mode=dp_mode,
                                        donate=donate, mode="full",
                                        compress_pod=compress_pod)
        dt2 = 0.0
    else:
        def pair(ka, kb):
            ca, sa, mem, dta = _measure(cfg, shape, mesh, rules, model,
                                        optimizer, pod_size, dp_mode=dp_mode,
                                        donate=donate, mode=ka,
                                        compress_pod=compress_pod)
            cb, sb, _, dtb = _measure(cfg, shape, mesh, rules, model,
                                      optimizer, pod_size, dp_mode=dp_mode,
                                      donate=donate, mode=kb,
                                      compress_pod=compress_pod)
            # cost(k) = outside + k*body; solve from (ka, kb)
            def fit(ma, mb):
                out = {}
                for key in set(ma) | set(mb):
                    a, b = float(ma.get(key, 0.0)), float(mb.get(key, 0.0))
                    body = (b - a) / (kb - ka)
                    out[key] = a + (trips - ka) * body
                return out
            return fit(ca, cb), fit(sa, sb), mem, dta + dtb

        cost, csum, mem, dtp = pair(1, 2)
        dt1, dt2 = dtp, 0.0
        bad = (cost.get("flops", 0) <= 0 or cost.get("bytes accessed", 0) < 0
               or csum.get("total_moved_bytes", 0) < 0)
        if bad:
            # cross-body CSE broke the k=1->2 affine fit (the partitioner
            # hoists shared subexpressions only once bodies repeat); the
            # (2,3) pair is affine again.
            cost, csum, mem, dt2 = pair(2, 3)

    # MODEL_FLOPS: 6·N·D for train (N active for MoE), 2·N·D forward-only
    tokens = (flops_scale * shape.global_batch
              * (shape.seq_len if shape.kind != "decode" else 1))
    n_active = cfg.active_param_count()
    model_flops = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens

    cost_scaled = {k: (v * flops_scale if k in _COST_KEYS else v)
                   for k, v in cost.items()}
    csum_scaled = {k: v * flops_scale for k, v in csum.items()}
    roof = H.roofline_terms(cost_scaled, csum_scaled, n_chips, model_flops)
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "OK", "n_chips": n_chips,
        "dp_mode": dp_mode, "fsdp": fsdp, "mode": mode,
        "flops_scale": flops_scale, "layer_trips": trips,
        "n_params": cfg.param_count(),
        "n_active_params": cfg.active_param_count(),
        "microbatches": flops_scale if shape.kind == "train" else 0,
        "compile_s": round(dt1 + dt2, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost": {k: v for k, v in cost_scaled.items() if k in _COST_KEYS},
        "collectives": csum_scaled,
        "roofline": roof,
        "model_flops_total": model_flops,
    }

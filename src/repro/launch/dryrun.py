import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, build the production mesh
(16x16 single-pod / 2x16x16 multi-pod), lower + compile the appropriate
step (train_step / prefill_step / decode_step) from ShapeDtypeStruct
stand-ins (no allocation), and record memory_analysis / cost_analysis /
collective traffic to ``artifacts/dryrun/*.json`` — §Roofline reads from
these artifacts.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
"""

import argparse
import json
import sys
import traceback
from pathlib import Path

import jax

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")

from repro.configs import ARCHS
from repro.launch.dryrun_cell import lower_cell
from repro.obs.console import emit
from repro.models.config import SHAPES


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="single arch (default: all)")
    p.add_argument("--shape", default=None, help="single shape (default: all)")
    p.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    p.add_argument("--smoke", action="store_true", help="use reduced configs")
    p.add_argument("--dp-mode", default="auto", choices=["auto", "hierarchical"])
    p.add_argument("--no-fsdp", action="store_true")
    p.add_argument("--mode", default="extrapolate", choices=["extrapolate", "full"])
    p.add_argument("--out", default="artifacts/dryrun")
    p.add_argument("--tag", default="")
    p.add_argument("--skip-existing", action="store_true")
    args = p.parse_args(argv)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                mesh_name = "multi" if multi_pod else "single"
                tag = f"-{args.tag}" if args.tag else ""
                name = f"{arch}__{shape_name}__{mesh_name}{tag}"
                fp = outdir / f"{name}.json"
                if args.skip_existing and fp.exists():
                    rec = json.loads(fp.read_text())
                    if rec.get("status") in ("OK", "SKIP"):
                        n_ok += rec["status"] == "OK"
                        n_skip += rec["status"] == "SKIP"
                        emit(f"[keep] {name}")
                        continue
                try:
                    rec = lower_cell(arch, shape_name, multi_pod,
                                     smoke=args.smoke, dp_mode=args.dp_mode,
                                     fsdp=not args.no_fsdp, mode=args.mode)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                fp.write_text(json.dumps(rec, indent=2))
                status = rec["status"]
                n_ok += status == "OK"
                n_skip += status == "SKIP"
                n_fail += status == "FAIL"
                line = f"[{status:4s}] {name}"
                if status == "OK":
                    r = rec["roofline"]
                    line += (f"  compile={rec['compile_s']:.1f}s"
                             f"  flops={r['hlo_flops']:.3g}"
                             f"  coll={r['collective_bytes']:.3g}B"
                             f"  dom={r['dominant']}")
                elif status == "FAIL":
                    line += "  " + rec["error"][:140]
                emit(line)
    emit(f"\ndry-run: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())

"""Serving driver over the ``repro.serve`` engine.

Request-level modes (continuous batching + budgeted KV tiering):

    # synthetic request trace through the engine
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 16 --max-new 16 --slots 4

    # trace file (JSONL: prompt_tokens / max_new_tokens / arrival_time)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --trace /path/to/trace.jsonl --tier2-kv-gb 1

    # lease-backed: the pool grants the tier-2 KV budget
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 16 --pool scalepool --pool-accels 4 --tier2-kv-gb 1

    # multi-tenant: N engines fair-sharing ONE physical page pool
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 16 --tenants 2 --tier1-pages 12 --tier2-kv-gb 1

    # disaggregated: prefill tier + decode tier, KV streamed over the
    # routed fabric (direct pod-to-pod or staged through tier-2 memory)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 16 --disagg --disagg-staging tier2 --min-ready-pages 1

Legacy fixed-batch mode (pre-engine path, kept for encdec archs):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt 64 --generate 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.compat import mesh_context
from repro.core.tiering import KVBudget
from repro.launch.mesh import make_smoke_mesh
from repro.models.api import build_model
from repro.models.config import ShapeConfig
from repro.obs import Tracer, write_chrome_trace
from repro.obs.console import emit_json, warn
from repro.runtime import serve as serve_rt
from repro.sharding.partition import use_rules
from repro.sharding.profiles import make_rules


def _flush_trace(tracer, transports, path: str) -> dict:
    """Drain every transport's in-flight transfers (their spans land at
    completion) and write the Perfetto-loadable trace file."""
    for tx in {id(t): t for t in transports if t is not None}.values():
        tx.quiesce()
    write_chrome_trace(tracer, path)
    return {"path": path, "events": len(tracer),
            "dropped": tracer.dropped}


def _engine_mode(args, cfg, model) -> int:
    from repro.serve import (Engine, EngineConfig, latency_summary,
                             load_trace, run_trace, synthetic_trace)

    ecfg = EngineConfig(max_slots=args.slots, max_seq=args.max_seq,
                        page_size=args.page_size)
    tracer = Tracer(args.trace_capacity) if args.trace_out else None
    budget = None
    if args.tier1_pages or args.tier2_kv_gb:
        budget = KVBudget(
            tier1_pages=args.tier1_pages or None,
            tier2_bytes=args.tier2_kv_gb * 1e9,
            page_size=args.page_size)

    if args.tenants > 1:
        return _multitenant_mode(args, cfg, model, ecfg, tracer)

    if args.pool != "none":
        from repro.pool import smoke_pool
        pool = smoke_pool(args.pool)
        lease = pool.lease("cli-serve", args.pool_accels,
                           tier2_gb=max(args.pool_tier2_gb, args.tier2_kv_gb),
                           kv_gb=args.tier2_kv_gb,
                           model_parallel=args.pool_model_parallel)
        engine = Engine.from_lease(model, lease, ecfg, budget=budget,
                                   tracer=tracer)
    else:
        engine = Engine.local(model, ecfg, budget=budget, tracer=tracer)

    if args.trace:
        trace = load_trace(args.trace, vocab=cfg.vocab)
    else:
        trace = synthetic_trace(
            args.requests, mean_interarrival_s=args.interarrival,
            prompt_lens=tuple(int(x) for x in args.prompt_lens.split(",")),
            max_new_tokens=args.max_new, vocab=cfg.vocab, seed=args.seed)

    t0 = time.time()
    handles = run_trace(engine, trace)
    wall = time.time() - t0
    stats = engine.stats()
    out = {
        "arch": cfg.name, "mode": "engine",
        "lease": args.pool if args.pool != "none" else None,
        "requests": len(handles),
        "latency": latency_summary(handles),
        "stats": stats,
        "wall_s": round(wall, 2),
        "sample_tokens": handles[0].tokens[:8] if handles else [],
    }
    if tracer is not None:
        out["trace_out"] = _flush_trace(tracer, [engine.transport],
                                        args.trace_out)
    emit_json(out)
    return 0 if stats["failed_oom"] == 0 else 1


def _disagg_mode(args, cfg, model) -> int:
    """--disagg: prefill tier + decode tier on separate pods of one
    routed fabric, KV pages streamed between them (repro.disagg)."""
    from repro.core import fabric as fb
    from repro.disagg import DisaggCluster, DisaggConfig, PrefillWorker
    from repro.fabric import Topology, Transport
    from repro.serve import (Engine, EngineConfig, latency_summary,
                             load_trace, synthetic_trace)

    ecfg = EngineConfig(max_slots=args.slots, max_seq=args.max_seq,
                        page_size=args.page_size)
    tracer = Tracer(args.trace_capacity) if args.trace_out else None
    budget = None
    if args.tier1_pages or args.tier2_kv_gb:
        budget = KVBudget(
            tier1_pages=args.tier1_pages or None,
            tier2_bytes=args.tier2_kv_gb * 1e9,
            page_size=args.page_size)

    params = model.init(jax.random.PRNGKey(0))
    n_pre, n_dec = args.prefill_pods, args.decode_pods
    workers = [PrefillWorker(Engine.local(model, ecfg, params=params,
                                          tracer=tracer), name=f"p{i}")
               for i in range(n_pre)]
    dengines = [Engine.local(model, ecfg, params=params, budget=budget,
                             tracer=tracer, tenant=f"d{k}")
                for k in range(n_dec)]

    # a two-tier estate graph: every pod hangs off one leaf switch, the
    # staging memory node too; capacities default to ~50 page-transfers
    # per modeled second so handoffs are visible but not dominant
    pb = dengines[0].kv.page_bytes
    bw = args.kv_gbps * 1e9 if args.kv_gbps > 0 else 50.0 * pb
    lat = fb.tier2_memory_fabric(8).latency()
    topo = Topology("disagg-cli")
    topo.add_node("leaf", "switch")
    topo.add_node("mem:0", "memory")
    topo.connect("mem:0", "leaf", fb.CXL_CAPACITY, capacity=2.0 * bw,
                 latency=lat / 4)
    for i in range(n_pre + n_dec):
        topo.add_node(f"pod:{i}", "pod")
        topo.connect(f"pod:{i}", "leaf", fb.CXL3, capacity=bw,
                     latency=lat / 4)
    tx = Transport(topo, tracer=tracer)
    kw = dict(route=topo.route("pod:0", f"pod:{n_pre}"))
    if args.disagg_staging == "tier2":
        kw["stage_in"] = topo.route("pod:0", "mem:0")
        kw["stage_out"] = topo.route("mem:0", f"pod:{n_pre}")
    cluster = DisaggCluster(
        workers, dengines, transport=tx, tenant="cli",
        config=DisaggConfig(
            staging=args.disagg_staging,
            min_ready_pages=args.min_ready_pages or None,
            max_transit_s=args.max_transit_s or None), **kw)

    if args.trace:
        trace = load_trace(args.trace, vocab=cfg.vocab)
    else:
        trace = synthetic_trace(
            args.requests, mean_interarrival_s=args.interarrival,
            prompt_lens=tuple(int(x) for x in args.prompt_lens.split(",")),
            max_new_tokens=args.max_new, vocab=cfg.vocab, seed=args.seed)

    t0 = time.time()
    handles = cluster.run(trace)
    wall = time.time() - t0
    failed = sum(e.stats()["failed_oom"] for e in dengines)
    transits = sorted(h.kv_transit_s for h in handles)
    out = {
        "arch": cfg.name, "mode": "disagg",
        "staging": args.disagg_staging,
        "prefill_pods": n_pre, "decode_pods": n_dec,
        "requests": len(handles),
        "handoffs": cluster.handoffs, "colocated": cluster.colocated,
        "latency": latency_summary(handles),
        "kv_transit_s": {
            "mean": sum(transits) / max(1, len(transits)),
            "max": transits[-1] if transits else 0.0,
        },
        "wall_s": round(wall, 2),
        "sample_tokens": handles[0].tokens[:8] if handles else [],
    }
    if tracer is not None:
        out["trace_out"] = _flush_trace(
            tracer, [tx] + [e.transport for e in dengines]
            + [w.engine.transport for w in workers], args.trace_out)
    emit_json(out)
    return 0 if failed == 0 else 1


def _multitenant_mode(args, cfg, model, ecfg, tracer=None) -> int:
    """--tenants N: N engines over ONE shared page pool (PoolArbiter),
    traffic (synthetic or --trace JSONL) split round-robin across
    tenants."""
    from repro.serve import (Engine, PoolArbiter, latency_summary,
                             load_trace, run_multi_trace, synthetic_trace)

    if args.pool != "none" and args.tier2_kv_gb <= 0:
        warn("--tenants with --pool shares one KV grant across the "
             "tenants — pass --tier2-kv-gb > 0 so the lease has kv "
             "bytes to share")
        return 2

    names = [f"t{i}" for i in range(args.tenants)]
    tier1 = args.tier1_pages or args.tenants * args.slots * ecfg.pages_per_slot
    arb = PoolArbiter(tier1, page_size=args.page_size, tracer=tracer)
    per_tenant = KVBudget(tier2_bytes=args.tier2_kv_gb * 1e9 / args.tenants,
                          page_size=args.page_size)
    if args.pool != "none":
        from repro.pool import smoke_pool
        pool = smoke_pool(args.pool)
        lease = pool.lease("cli-serve", args.pool_accels,
                           tier2_gb=max(args.pool_tier2_gb, args.tier2_kv_gb),
                           kv_gb=args.tier2_kv_gb,
                           model_parallel=args.pool_model_parallel,
                           tenants=tuple(names))
        engines = {n: Engine.from_lease(model, lease, ecfg,
                                        arbiter=arb, tenant=n,
                                        tracer=tracer)
                   for n in names}
    else:
        engines = {n: Engine.local(model, ecfg, budget=per_tenant,
                                   arbiter=arb, tenant=n, tracer=tracer)
                   for n in names}

    if args.trace:
        trace = load_trace(args.trace, vocab=cfg.vocab)
    else:
        trace = synthetic_trace(
            args.requests, mean_interarrival_s=args.interarrival,
            prompt_lens=tuple(int(x) for x in args.prompt_lens.split(",")),
            max_new_tokens=args.max_new, vocab=cfg.vocab, seed=args.seed)
    split = {n: [r for j, r in enumerate(trace)
                 if j % args.tenants == i]
             for i, n in enumerate(names)}

    t0 = time.time()
    results = run_multi_trace([(engines[n], split[n]) for n in names])
    wall = time.time() - t0
    out = {"arch": cfg.name, "mode": "multitenant",
           "tenants": args.tenants, "tier1_pages": tier1,
           "wall_s": round(wall, 2), "arbiter": arb.stats(), "per_tenant": {}}
    failed = 0
    for n, handles in zip(names, results):
        st = engines[n].stats()
        failed += st["failed_oom"]
        out["per_tenant"][n] = {
            "requests": len(handles),
            "latency": latency_summary(handles),
            "swaps": st["preempt_swaps"],
            "recomputes": st["preempt_recomputes"],
            "tput_busy_tok_s": st["throughput_busy_tok_s"],
        }
    if tracer is not None:
        out["trace_out"] = _flush_trace(
            tracer, [e.transport for e in engines.values()],
            args.trace_out)
    emit_json(out)
    return 0 if failed == 0 else 1


def _legacy_batch_mode(args, cfg, model) -> int:
    max_seq = args.prompt + args.generate
    shape = ShapeConfig("cli", "decode", max_seq, args.batch)
    mesh = make_smoke_mesh()
    rules = make_rules(cfg, shape, mesh, fsdp=False)

    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    prompts = jax.random.randint(rng, (args.batch, args.prompt), 1, cfg.vocab)

    decode_fn = jax.jit(serve_rt.make_decode_step(model),
                        donate_argnums=(1,))

    with use_rules(rules, mesh), mesh_context(mesh):
        cache = model.init_cache(args.batch, max_seq, dtype=jnp.float32)
        t0 = time.time()
        if cfg.family == "encdec":
            frames = jax.random.normal(rng, (args.batch, cfg.enc_seq,
                                             cfg.d_model), jnp.bfloat16)
            logits, cache, enc = model.prefill(
                params, {"frame_embeds": frames, "tokens": prompts}, cache)
        else:
            logits, cache = model.prefill(params, {"tokens": prompts}, cache)
            enc = None
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        carry = {"tokens": jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32),
                 "cache": cache, "index": jnp.int32(args.prompt)}
        if enc is not None:
            carry["enc_states"] = enc
        generated = [np.asarray(carry["tokens"])]
        t0 = time.time()
        for _ in range(args.generate - 1):
            logits, carry = decode_fn(params, carry)
            generated.append(np.asarray(carry["tokens"]))
        jax.block_until_ready(carry["tokens"])
        t_decode = time.time() - t0

    toks = np.concatenate(generated, axis=1)
    tokens_per_s = args.batch * (args.generate - 1) / max(t_decode, 1e-9)
    emit_json({
        "arch": cfg.name, "mode": "batch",
        "batch": args.batch, "prompt": args.prompt,
        "generated": toks.shape[1],
        "prefill_s": round(t_prefill, 3),
        "decode_tok_per_s": round(tokens_per_s, 1),
        "sample_tokens": toks[0, :8].tolist(),
    })
    return 0


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen1.5-0.5b")
    p.add_argument("--smoke", action="store_true")
    # engine (request-level) mode
    p.add_argument("--requests", type=int, default=0,
                   help="serve N synthetic requests through the engine")
    p.add_argument("--trace", default=None,
                   help="JSONL request trace driven through the engine")
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=256)
    p.add_argument("--page-size", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--prompt-lens", default="16,32,64")
    p.add_argument("--interarrival", type=float, default=0.05)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--tier1-pages", type=int, default=0,
                   help="tier-1 KV page quota (0 = full slot capacity)")
    p.add_argument("--tier2-kv-gb", type=float, default=0.0,
                   help="tier-2 KV byte budget (spill target)")
    p.add_argument("--tenants", type=int, default=1,
                   help="N>1: N tenant engines over ONE shared page pool "
                        "(PoolArbiter fair shares), traffic split "
                        "round-robin")
    p.add_argument("--disagg", action="store_true",
                   help="disaggregated serving: prefill tier + decode "
                        "tier on separate pods, KV pages streamed over "
                        "the routed fabric (repro.disagg)")
    p.add_argument("--disagg-staging", default="direct",
                   choices=["direct", "tier2"],
                   help="handoff path: direct pod-to-pod, or staged "
                        "through a tier-2 memory node (two priced legs)")
    p.add_argument("--prefill-pods", type=int, default=1)
    p.add_argument("--decode-pods", type=int, default=1)
    p.add_argument("--min-ready-pages", type=int, default=0,
                   help="admit a handed-off request once this many KV "
                        "pages landed (0 = wait for all)")
    p.add_argument("--max-transit-s", type=float, default=0.0,
                   help="route a request colocated when its predicted "
                        "KV transit exceeds this (0 = never)")
    p.add_argument("--kv-gbps", type=float, default=0.0,
                   help="fabric pod-uplink capacity for KV handoffs "
                        "(0 = auto-scale to ~50 pages/s)")
    p.add_argument("--pool", default="none",
                   choices=["none", "scalepool", "baseline"])
    p.add_argument("--pool-accels", type=int, default=4)
    p.add_argument("--pool-tier2-gb", type=float, default=0.0)
    p.add_argument("--pool-model-parallel", type=int, default=1)
    p.add_argument("--trace-out", default=None,
                   help="write a Chrome/Perfetto trace_event JSON of the "
                        "run's modeled timeline (open in ui.perfetto.dev)")
    p.add_argument("--trace-capacity", type=int, default=1 << 16,
                   help="flight-recorder ring size (events); oldest "
                        "events drop beyond this")
    # legacy fixed-batch mode
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt", type=int, default=64)
    p.add_argument("--generate", type=int, default=32)
    args = p.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    if args.requests or args.trace:
        if not model.supports_paged_kv:
            warn(f"the request-level engine serves paged-KV families "
                 f"(dense/moe); {cfg.family!r} is not supported yet — "
                 f"use the fixed-batch mode (--batch/--prompt/"
                 f"--generate) instead")
            return 2
        if args.disagg:
            return _disagg_mode(args, cfg, model)
        return _engine_mode(args, cfg, model)
    return _legacy_batch_mode(args, cfg, model)


if __name__ == "__main__":
    raise SystemExit(main())

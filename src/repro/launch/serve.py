"""Batched serving driver: prefill + decode over a sharded KV cache with
optional tier-2 page spilling.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt 64 --generate 32
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.compat import mesh_context
from repro.launch.mesh import make_smoke_mesh
from repro.models.api import build_model
from repro.models.config import ShapeConfig
from repro.runtime import serve as serve_rt
from repro.sharding.partition import use_rules
from repro.sharding.profiles import make_rules


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen1.5-0.5b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt", type=int, default=64)
    p.add_argument("--generate", type=int, default=32)
    args = p.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    max_seq = args.prompt + args.generate
    shape = ShapeConfig("cli", "decode", max_seq, args.batch)
    mesh = make_smoke_mesh()
    rules = make_rules(cfg, shape, mesh, fsdp=False)

    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    prompts = jax.random.randint(rng, (args.batch, args.prompt), 1, cfg.vocab)

    decode_fn = jax.jit(serve_rt.make_decode_step(model),
                        donate_argnums=(1,))

    with use_rules(rules, mesh), mesh_context(mesh):
        cache = model.init_cache(args.batch, max_seq, dtype=jnp.float32)
        t0 = time.time()
        if cfg.family == "encdec":
            frames = jax.random.normal(rng, (args.batch, cfg.enc_seq,
                                             cfg.d_model), jnp.bfloat16)
            logits, cache, enc = model.prefill(
                params, {"frame_embeds": frames, "tokens": prompts}, cache)
        else:
            logits, cache = model.prefill(params, {"tokens": prompts}, cache)
            enc = None
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        carry = {"tokens": jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32),
                 "cache": cache, "index": jnp.int32(args.prompt)}
        if enc is not None:
            carry["enc_states"] = enc
        generated = [np.asarray(carry["tokens"])]
        t0 = time.time()
        for _ in range(args.generate - 1):
            logits, carry = decode_fn(params, carry)
            generated.append(np.asarray(carry["tokens"]))
        jax.block_until_ready(carry["tokens"])
        t_decode = time.time() - t0

    toks = np.concatenate(generated, axis=1)
    tokens_per_s = args.batch * (args.generate - 1) / max(t_decode, 1e-9)
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch, "prompt": args.prompt,
        "generated": toks.shape[1],
        "prefill_s": round(t_prefill, 3),
        "decode_tok_per_s": round(tokens_per_s, 1),
        "sample_tokens": toks[0, :8].tolist(),
    }, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

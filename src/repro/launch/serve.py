"""Serving driver over the ``repro.serve`` engine.

Request-level modes (continuous batching + budgeted KV tiering):

    # synthetic request trace through the engine
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 16 --max-new 16 --slots 4

    # trace file (JSONL: prompt_tokens / max_new_tokens / arrival_time)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --trace /path/to/trace.jsonl --tier2-kv-gb 1

    # lease-backed: the pool grants the tier-2 KV budget
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 16 --pool scalepool --pool-accels 4 --tier2-kv-gb 1

    # multi-tenant: N engines fair-sharing ONE physical page pool
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 16 --tenants 2 --tier1-pages 12 --tier2-kv-gb 1

Legacy fixed-batch mode (pre-engine path, kept for encdec archs):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt 64 --generate 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.compat import mesh_context
from repro.core.tiering import KVBudget
from repro.launch.mesh import make_smoke_mesh
from repro.models.api import build_model
from repro.models.config import ShapeConfig
from repro.obs import Tracer, write_chrome_trace
from repro.obs.console import emit_json, warn
from repro.runtime import serve as serve_rt
from repro.sharding.partition import use_rules
from repro.sharding.profiles import make_rules


def _flush_trace(tracer, transports, path: str) -> dict:
    """Drain every transport's in-flight transfers (their spans land at
    completion) and write the Perfetto-loadable trace file."""
    for tx in {id(t): t for t in transports if t is not None}.values():
        tx.quiesce()
    write_chrome_trace(tracer, path)
    return {"path": path, "events": len(tracer),
            "dropped": tracer.dropped}


def _engine_mode(args, cfg, model) -> int:
    from repro.serve import (Engine, EngineConfig, latency_summary,
                             load_trace, run_trace, synthetic_trace)

    ecfg = EngineConfig(max_slots=args.slots, max_seq=args.max_seq,
                        page_size=args.page_size)
    tracer = Tracer(args.trace_capacity) if args.trace_out else None
    budget = None
    if args.tier1_pages or args.tier2_kv_gb:
        budget = KVBudget(
            tier1_pages=args.tier1_pages or None,
            tier2_bytes=args.tier2_kv_gb * 1e9,
            page_size=args.page_size)

    if args.tenants > 1:
        return _multitenant_mode(args, cfg, model, ecfg, tracer)

    if args.pool != "none":
        from repro.pool import smoke_pool
        pool = smoke_pool(args.pool)
        lease = pool.lease("cli-serve", args.pool_accels,
                           tier2_gb=max(args.pool_tier2_gb, args.tier2_kv_gb),
                           kv_gb=args.tier2_kv_gb,
                           model_parallel=args.pool_model_parallel)
        engine = Engine.from_lease(model, lease, ecfg, budget=budget,
                                   tracer=tracer)
    else:
        engine = Engine.local(model, ecfg, budget=budget, tracer=tracer)

    if args.trace:
        trace = load_trace(args.trace, vocab=cfg.vocab)
    else:
        trace = synthetic_trace(
            args.requests, mean_interarrival_s=args.interarrival,
            prompt_lens=tuple(int(x) for x in args.prompt_lens.split(",")),
            max_new_tokens=args.max_new, vocab=cfg.vocab, seed=args.seed)

    t0 = time.time()
    handles = run_trace(engine, trace)
    wall = time.time() - t0
    stats = engine.stats()
    out = {
        "arch": cfg.name, "mode": "engine",
        "lease": args.pool if args.pool != "none" else None,
        "requests": len(handles),
        "latency": latency_summary(handles),
        "stats": stats,
        "wall_s": round(wall, 2),
        "sample_tokens": handles[0].tokens[:8] if handles else [],
    }
    if tracer is not None:
        out["trace_out"] = _flush_trace(tracer, [engine.transport],
                                        args.trace_out)
    emit_json(out)
    return 0 if stats["failed_oom"] == 0 else 1


def _multitenant_mode(args, cfg, model, ecfg, tracer=None) -> int:
    """--tenants N: N engines over ONE shared page pool (PoolArbiter),
    traffic (synthetic or --trace JSONL) split round-robin across
    tenants."""
    from repro.serve import (Engine, PoolArbiter, latency_summary,
                             load_trace, run_multi_trace, synthetic_trace)

    if args.pool != "none" and args.tier2_kv_gb <= 0:
        warn("--tenants with --pool shares one KV grant across the "
             "tenants — pass --tier2-kv-gb > 0 so the lease has kv "
             "bytes to share")
        return 2

    names = [f"t{i}" for i in range(args.tenants)]
    tier1 = args.tier1_pages or args.tenants * args.slots * ecfg.pages_per_slot
    arb = PoolArbiter(tier1, page_size=args.page_size, tracer=tracer)
    per_tenant = KVBudget(tier2_bytes=args.tier2_kv_gb * 1e9 / args.tenants,
                          page_size=args.page_size)
    if args.pool != "none":
        from repro.pool import smoke_pool
        pool = smoke_pool(args.pool)
        lease = pool.lease("cli-serve", args.pool_accels,
                           tier2_gb=max(args.pool_tier2_gb, args.tier2_kv_gb),
                           kv_gb=args.tier2_kv_gb,
                           model_parallel=args.pool_model_parallel,
                           tenants=tuple(names))
        engines = {n: Engine.from_lease(model, lease, ecfg,
                                        arbiter=arb, tenant=n,
                                        tracer=tracer)
                   for n in names}
    else:
        engines = {n: Engine.local(model, ecfg, budget=per_tenant,
                                   arbiter=arb, tenant=n, tracer=tracer)
                   for n in names}

    if args.trace:
        trace = load_trace(args.trace, vocab=cfg.vocab)
    else:
        trace = synthetic_trace(
            args.requests, mean_interarrival_s=args.interarrival,
            prompt_lens=tuple(int(x) for x in args.prompt_lens.split(",")),
            max_new_tokens=args.max_new, vocab=cfg.vocab, seed=args.seed)
    split = {n: [r for j, r in enumerate(trace)
                 if j % args.tenants == i]
             for i, n in enumerate(names)}

    t0 = time.time()
    results = run_multi_trace([(engines[n], split[n]) for n in names])
    wall = time.time() - t0
    out = {"arch": cfg.name, "mode": "multitenant",
           "tenants": args.tenants, "tier1_pages": tier1,
           "wall_s": round(wall, 2), "arbiter": arb.stats(), "per_tenant": {}}
    failed = 0
    for n, handles in zip(names, results):
        st = engines[n].stats()
        failed += st["failed_oom"]
        out["per_tenant"][n] = {
            "requests": len(handles),
            "latency": latency_summary(handles),
            "swaps": st["preempt_swaps"],
            "recomputes": st["preempt_recomputes"],
            "tput_busy_tok_s": st["throughput_busy_tok_s"],
        }
    if tracer is not None:
        out["trace_out"] = _flush_trace(
            tracer, [e.transport for e in engines.values()],
            args.trace_out)
    emit_json(out)
    return 0 if failed == 0 else 1


def _legacy_batch_mode(args, cfg, model) -> int:
    max_seq = args.prompt + args.generate
    shape = ShapeConfig("cli", "decode", max_seq, args.batch)
    mesh = make_smoke_mesh()
    rules = make_rules(cfg, shape, mesh, fsdp=False)

    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    prompts = jax.random.randint(rng, (args.batch, args.prompt), 1, cfg.vocab)

    decode_fn = jax.jit(serve_rt.make_decode_step(model),
                        donate_argnums=(1,))

    with use_rules(rules, mesh), mesh_context(mesh):
        cache = model.init_cache(args.batch, max_seq, dtype=jnp.float32)
        t0 = time.time()
        if cfg.family == "encdec":
            frames = jax.random.normal(rng, (args.batch, cfg.enc_seq,
                                             cfg.d_model), jnp.bfloat16)
            logits, cache, enc = model.prefill(
                params, {"frame_embeds": frames, "tokens": prompts}, cache)
        else:
            logits, cache = model.prefill(params, {"tokens": prompts}, cache)
            enc = None
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        carry = {"tokens": jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32),
                 "cache": cache, "index": jnp.int32(args.prompt)}
        if enc is not None:
            carry["enc_states"] = enc
        generated = [np.asarray(carry["tokens"])]
        t0 = time.time()
        for _ in range(args.generate - 1):
            logits, carry = decode_fn(params, carry)
            generated.append(np.asarray(carry["tokens"]))
        jax.block_until_ready(carry["tokens"])
        t_decode = time.time() - t0

    toks = np.concatenate(generated, axis=1)
    tokens_per_s = args.batch * (args.generate - 1) / max(t_decode, 1e-9)
    emit_json({
        "arch": cfg.name, "mode": "batch",
        "batch": args.batch, "prompt": args.prompt,
        "generated": toks.shape[1],
        "prefill_s": round(t_prefill, 3),
        "decode_tok_per_s": round(tokens_per_s, 1),
        "sample_tokens": toks[0, :8].tolist(),
    })
    return 0


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen1.5-0.5b")
    p.add_argument("--smoke", action="store_true")
    # engine (request-level) mode
    p.add_argument("--requests", type=int, default=0,
                   help="serve N synthetic requests through the engine")
    p.add_argument("--trace", default=None,
                   help="JSONL request trace driven through the engine")
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=256)
    p.add_argument("--page-size", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--prompt-lens", default="16,32,64")
    p.add_argument("--interarrival", type=float, default=0.05)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--tier1-pages", type=int, default=0,
                   help="tier-1 KV page quota (0 = full slot capacity)")
    p.add_argument("--tier2-kv-gb", type=float, default=0.0,
                   help="tier-2 KV byte budget (spill target)")
    p.add_argument("--tenants", type=int, default=1,
                   help="N>1: N tenant engines over ONE shared page pool "
                        "(PoolArbiter fair shares), traffic split "
                        "round-robin")
    p.add_argument("--pool", default="none",
                   choices=["none", "scalepool", "baseline"])
    p.add_argument("--pool-accels", type=int, default=4)
    p.add_argument("--pool-tier2-gb", type=float, default=0.0)
    p.add_argument("--pool-model-parallel", type=int, default=1)
    p.add_argument("--trace-out", default=None,
                   help="write a Chrome/Perfetto trace_event JSON of the "
                        "run's modeled timeline (open in ui.perfetto.dev)")
    p.add_argument("--trace-capacity", type=int, default=1 << 16,
                   help="flight-recorder ring size (events); oldest "
                        "events drop beyond this")
    # legacy fixed-batch mode
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt", type=int, default=64)
    p.add_argument("--generate", type=int, default=32)
    args = p.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    if args.requests or args.trace:
        if not model.supports_paged_kv:
            warn(f"the request-level engine serves paged-KV families "
                 f"(dense/moe); {cfg.family!r} is not supported yet — "
                 f"use the fixed-batch mode (--batch/--prompt/"
                 f"--generate) instead")
            return 2
        return _engine_mode(args, cfg, model)
    return _legacy_batch_mode(args, cfg, model)


if __name__ == "__main__":
    raise SystemExit(main())

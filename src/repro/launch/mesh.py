"""Production mesh builders.

ScalePool mapping (DESIGN.md §2): the inner axes ("data", "model") are
one accelerator cluster's XLink domain (a 256-chip pod); the outer
"pod" axis is the inter-cluster CXL fabric.  Functions, not module
constants — importing this module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(n_devices: int | None = None):
    """Small mesh for in-process tests (requires forced host devices)."""
    n = n_devices or len(jax.devices())
    if n >= 8:
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    if n >= 4:
        return jax.make_mesh((2, 2), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))

"""Fault tolerance & straggler mitigation for 1000+ node operation.

What actually fails at scale and what this module does about it:

* **Chip/host failure mid-step** → the step raises; ``FaultTolerantLoop``
  catches, restores the last committed checkpoint (written every
  ``ckpt_every`` steps, asynchronously), rebuilds the mesh from the
  surviving device set via ``repro.ckpt.elastic.resize_plan``, and
  resumes from the exact data-pipeline state (the pipeline is a pure
  function of (seed, step)).
* **Stragglers** → synchronous SPMD steps run at the speed of the
  slowest participant.  ``StragglerMonitor`` keeps an EWMA of step time;
  when a step exceeds ``threshold``× the EWMA it records the event and
  (at the cluster level) the policy recommendation is eviction +
  elastic resize — the hierarchical ScalePool schedule also CONTAINS a
  slow pod: only the inter-pod phase (1/|data| of bytes) waits on it.
* **Transient errors** (preemption notices, DMA timeouts) → bounded
  retry with backoff before escalating to restore.

The single-process test environment exercises all of this with injected
failures (tests/test_ft.py); the interfaces take a mesh + process index
so the same loop runs under multi-host jax.distributed.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker with a slowdown threshold."""

    alpha: float = 0.1
    threshold: float = 2.0
    ewma: Optional[float] = None
    events: List[Dict[str, float]] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler event."""
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = dt > self.threshold * self.ewma
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "ewma": self.ewma})
        # stragglers don't poison the EWMA
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * min(
            dt, self.threshold * self.ewma)
        return is_straggler

    def recommendation(self) -> str:
        if len(self.events) >= 3:
            return "evict-and-resize"
        if self.events:
            return "monitor"
        return "healthy"


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 2
    backoff_s: float = 0.5

    def run(self, fn: Callable[[], Any]) -> Any:
        last: Optional[BaseException] = None
        for attempt in range(self.max_retries + 1):
            try:
                return fn()
            except Exception as e:  # noqa: BLE001
                last = e
                if attempt < self.max_retries:
                    time.sleep(self.backoff_s * (2 ** attempt))
        raise last  # type: ignore[misc]


class FaultTolerantLoop:
    """Checkpointed training loop with failure injection hooks.

    train_step: (state, batch) -> (state, metrics)
    save_fn:    (state, step) -> None       (async checkpoint)
    restore_fn: () -> (state, step)         (last committed checkpoint)
    """

    def __init__(self, train_step, save_fn, restore_fn, pipeline, *,
                 ckpt_every: int = 50,
                 retry: RetryPolicy = RetryPolicy(),
                 monitor: Optional[StragglerMonitor] = None,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.train_step = train_step
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.pipeline = pipeline
        self.ckpt_every = ckpt_every
        self.retry = retry
        self.monitor = monitor or StragglerMonitor()
        self.failure_hook = failure_hook
        self.restarts = 0
        self.history: List[Dict[str, float]] = []

    def run(self, state, n_steps: int):
        step = 0
        while step < n_steps:
            def attempt():
                if self.failure_hook is not None:
                    self.failure_hook(step)  # may raise (injected failure)
                batch = self.pipeline.peek_step(step)
                t0 = time.time()
                new_state, metrics = self.train_step(state, batch)
                dt = time.time() - t0
                return new_state, metrics, dt

            try:
                state, metrics, dt = self.retry.run(attempt)
            except Exception:
                # unrecoverable step: restore + rewind
                state, ckpt_step = self.restore_fn()
                self.pipeline.state.step = ckpt_step
                step = ckpt_step
                self.restarts += 1
                continue

            self.monitor.observe(step, dt)
            self.history.append({"step": step, **{
                k: float(np.asarray(v)) for k, v in metrics.items()}})
            step += 1
            self.pipeline.state.step = step
            if step % self.ckpt_every == 0:
                self.save_fn(state, step)
        return state

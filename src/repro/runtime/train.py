"""Training-step factory: microbatched grad accumulation, remat, FSDP/TP
via GSPMD shardings, and the ScalePool hierarchical cross-pod gradient
phase (shard_map manual over ``pod``, GSPMD auto inside the pod).

Modes:
  dp_mode="auto"         — one GSPMD program over all mesh axes (the flat
                           baseline for §Perf comparisons).
  dp_mode="hierarchical" — the pod axis is manual: per-pod grads are
                           computed by GSPMD on the intra-pod (XLink)
                           axes, then explicitly reduced across pods
                           (the CXL fabric phase), optionally with int8
                           error-feedback compression.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import hierarchy
from repro.core.compat import shard_map as _shard_map
from repro.models.api import Model, input_specs
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import AdamW, AdamWState
from repro.sharding.partition import Rules, tree_shardings, use_rules


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    residuals: Any     # int8-compression error feedback (or empty dict)


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    dp_mode: str = "auto"              # auto | hierarchical
    compress_pod: bool = False         # int8 EF on the cross-pod phase
    microbatches: int = 1
    remat: bool = True


def _accumulated_grads(model: Model, params, batch, tcfg: TrainStepConfig):
    """loss, grads averaged over the (local) batch, with optional
    gradient-accumulation microbatching."""

    def loss_fn(p, mb):
        return model.loss(p, mb, remat=tcfg.remat)

    if tcfg.microbatches <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    mbs = jax.tree.map(
        lambda x: x.reshape((tcfg.microbatches, x.shape[0] // tcfg.microbatches)
                            + x.shape[1:]), batch)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        lsum, gsum = carry
        l, g = jax.value_and_grad(loss_fn)(params, mb)
        gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
        return (lsum + l, gsum), None

    (lsum, gsum), _ = lax.scan(body, (jnp.float32(0.0), zeros), mbs)
    inv = 1.0 / tcfg.microbatches
    return lsum * inv, jax.tree.map(lambda g: g * inv, gsum)


def make_train_step(model: Model, optimizer: AdamW, shape: ShapeConfig, *,
                    mesh: Optional[Mesh] = None,
                    rules: Optional[Rules] = None,
                    tcfg: TrainStepConfig = TrainStepConfig()):
    """Returns (train_step, state_shardings, batch_shardings) — the step is
    NOT jitted; callers jit (or AOT-lower) with the returned shardings."""

    def core_step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        loss, grads = _accumulated_grads(model, state.params, batch, tcfg)
        residuals = state.residuals
        if tcfg.dp_mode == "hierarchical":
            grads, new_res = hierarchy.reduce_gradients_hierarchically(
                grads, inter_axis="pod", compress=tcfg.compress_pod,
                residuals=residuals.get("g") if tcfg.compress_pod else None)
            loss = jax.lax.pmean(loss, "pod")
            if tcfg.compress_pod:
                residuals = {"g": new_res}
        new_params, new_opt, gnorm = optimizer.update(grads, state.opt,
                                                      state.params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": new_opt.step.astype(jnp.float32)}
        return TrainState(new_params, new_opt, residuals), metrics

    if tcfg.dp_mode == "hierarchical":
        if mesh is None or "pod" not in mesh.axis_names:
            raise ValueError("hierarchical dp_mode needs a mesh with a 'pod' axis")

        # inside the manual-pod body, sharding constraints may only touch
        # the auto axes — strip 'pod' from the rule table
        inner_rules = rules.strip_axis("pod") if rules is not None else None

        def step(state, batch):
            def inner(state, batch):
                with use_rules(inner_rules, mesh):
                    new_state, metrics = core_step(state, batch)
                metrics = {k: v[None] for k, v in metrics.items()}
                return new_state, metrics

            out_state_spec = jax.tree.map(lambda _: P(), state,
                                          is_leaf=lambda x: x is None)
            f = _shard_map(
                inner, mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P(), state,
                                       is_leaf=lambda x: x is None),
                          jax.tree.map(lambda _: P("pod"), batch)),
                out_specs=(out_state_spec,
                           {"loss": P("pod"), "grad_norm": P("pod"),
                            "step": P("pod")}),
                check=False, manual_axes={"pod"})
            new_state, metrics = f(state, batch)
            metrics = {k: v[0] for k, v in metrics.items()}
            return new_state, metrics
    else:
        def step(state, batch):
            return core_step(state, batch)

    # ---- sharding pytrees for jit in_shardings / AOT lowering ----
    shardings = None
    if mesh is not None and rules is not None:
        p_ax = model.param_axes()
        state_ax = TrainState(
            params=p_ax,
            opt=optimizer.state_axes(p_ax),
            residuals={"g": p_ax} if tcfg.compress_pod else {},
        )
        state_sh = tree_shardings(mesh, rules, state_ax)
        shardings = state_sh
    return step, shardings


def init_state(model: Model, optimizer: AdamW, rng,
               tcfg: TrainStepConfig = TrainStepConfig()) -> TrainState:
    params = model.init(rng)
    opt = optimizer.init(params)
    residuals = {}
    if tcfg.compress_pod:
        residuals = {"g": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}
    return TrainState(params, opt, residuals)


def batch_shardings(mesh: Mesh, rules: Rules, specs: Dict[str, jax.ShapeDtypeStruct]):
    """Shardings for the input batch: leading dim over the batch axes."""
    out = {}
    for k, v in specs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, rules.spec(*axes))
    return out

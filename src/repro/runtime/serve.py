"""Serving-step factories: prefill and one-token decode over a sharded
KV/state cache.  These are the functions the decode_* / long_* dry-run
cells lower (``serve_step``, not ``train_step``, per the assignment).

.. deprecated::
    The request-level serving API now lives in ``repro.serve``: build an
    ``Engine`` (``Engine.from_lease`` / ``Engine.local``), ``submit``
    ``Request`` objects, and drive ``engine.step()`` — continuous
    batching, slot recycling, and lease-budgeted paged-KV tiering
    (``KVBudget``) are handled there.  The step factories below remain
    as the engine's building blocks and for the dry-run lowering cells;
    ``make_lease_session`` remains for encdec models and single-batch
    deployments but new code should prefer the engine."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.tiering import TieringPolicy
from repro.models.api import Model
from repro.models.config import ModelConfig, ShapeConfig
from repro.sharding.partition import Rules, tree_shardings
from repro.sharding.profiles import make_rules


def make_prefill_step(model: Model):
    """prefill_step(params, batch, cache) -> (next_token_logits, cache)."""

    def prefill_step(params, batch, cache):
        out = model.prefill(params, batch, cache)
        return out  # (logits, cache[, enc_states])

    return prefill_step


def make_decode_step(model: Model):
    """decode_step(params, carry) -> (logits, new_carry).

    carry = {tokens (B,1), cache, index ()} (+ enc_states for enc-dec).
    Greedy-samples the next token into the carry so the step is
    self-contained for a generation loop.
    """
    cfg = model.cfg

    def decode_step(params, carry):
        tokens, cache, index = carry["tokens"], carry["cache"], carry["index"]
        if cfg.family == "encdec":
            logits, new_cache = model.decode(params, tokens, cache, index,
                                             carry["enc_states"])
        else:
            logits, new_cache = model.decode(params, tokens, cache, index)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        new_carry = dict(carry)
        new_carry.update(tokens=next_tok[:, None], cache=new_cache,
                         index=index + 1)
        return logits, new_carry

    return decode_step


def decode_carry_specs(model: Model, shape: ShapeConfig,
                       cache_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStructs for the decode carry (no allocation)."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: model.init_cache(B, S, dtype=cache_dtype))
    carry = {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "cache": cache,
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.family == "encdec":
        carry["enc_states"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return carry


@dataclasses.dataclass(frozen=True)
class LeaseServeSession:
    """Everything a serving worker needs from its pool lease.

    .. deprecated:: superseded by ``repro.serve.Engine.from_lease`` for
       request-level serving; kept for encdec and fixed-batch loops."""

    mesh: Mesh
    rules: Rules
    policy: TieringPolicy
    prefill_step: Any          # jitted
    decode_step: Any           # jitted

    @property
    def kv_spill(self) -> bool:
        return self.policy.kv_spill


def make_lease_session(model: Model, shape: ShapeConfig,
                       lease) -> LeaseServeSession:
    """Bind a ``repro.pool.Lease`` to a runnable serving session.

    The lease's allocation determines the mesh shape (pod span → mesh
    axes) and its tier-2 reservation determines the KV spill policy —
    serving capacity and KV paging are composed by the orchestrator, not
    hard-coded per deployment.  The returned steps run scoped to the
    lease's mesh/rules so GSPMD honors the leased model parallelism.
    """
    from repro.core.compat import mesh_context
    from repro.sharding.partition import use_rules

    mesh, policy = lease.materialize()
    rules = make_rules(model.cfg, shape, mesh, fsdp=False)

    def scoped(fn, donate=()):
        jitted = jax.jit(fn, donate_argnums=donate)

        def call(*args):
            with use_rules(rules, mesh), mesh_context(mesh):
                return jitted(*args)
        return call

    return LeaseServeSession(
        mesh=mesh, rules=rules, policy=policy,
        prefill_step=scoped(make_prefill_step(model)),
        # donate the decode carry (the KV cache dominates it) so the
        # token loop updates in place instead of copying the cache
        decode_step=scoped(make_decode_step(model), donate=(1,)))


def decode_carry_shardings(model: Model, mesh: Mesh, rules: Rules,
                           shape: ShapeConfig) -> Dict[str, Any]:
    cfg = model.cfg
    cache_ax = model.cache_axes()
    out = {
        "tokens": NamedSharding(mesh, rules.spec("batch", None)),
        "cache": tree_shardings(mesh, rules, cache_ax),
        "index": NamedSharding(mesh, rules.spec()),
    }
    if cfg.family == "encdec":
        out["enc_states"] = NamedSharding(
            mesh, rules.spec("batch", None, "embed"))
    return out

"""Elastic re-sharding: restore a checkpoint onto a DIFFERENT mesh.

ScalePool's composable disaggregation means the compute pool can grow or
shrink independently of storage; a training job restarted on 384 chips
must consume a checkpoint written on 512.  The manifest stores global
shapes + shard slices, so re-assembly is mesh-agnostic: we rebuild the
full logical array from shard files and re-slice it for the new mesh's
shardings.  (At 1000+ nodes one would stream slices instead of
materializing; the interface is the same.)
"""

from __future__ import annotations

from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.ckpt import checkpoint as C
from repro.sharding.partition import Rules, tree_shardings


def replan(ckpt_dir, target_tree, new_mesh: Mesh, rules: Rules,
           axes_tree) -> Any:
    """Restore ``ckpt_dir`` re-sharded for ``new_mesh``.

    axes_tree: logical-axes pytree matching target_tree (from
    model.param_axes() / optimizer.state_axes()).
    """
    shardings = tree_shardings(new_mesh, rules, axes_tree)
    tree, extra = C.restore(ckpt_dir, target_tree, shardings=shardings)
    return tree, extra


def resize_plan(old_devices: int, new_devices: int, *,
                model_parallel: int = 16) -> Dict[str, int]:
    """Derive a (pods, data, model) decomposition for an elastic resize.

    Keeps model parallelism fixed (sharding layouts stay valid) and
    absorbs the change in the data-parallel/pod dimensions — the paper's
    composability axis.  Raises if the new size can't host the model."""
    if new_devices % model_parallel:
        raise ValueError(f"{new_devices} devices cannot host "
                         f"{model_parallel}-way model parallelism")
    data_total = new_devices // model_parallel
    pods = max(1, data_total // 16)
    while data_total % pods:
        pods -= 1
    return {"pods": pods, "data": data_total // pods, "model": model_parallel}

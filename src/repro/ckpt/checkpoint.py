"""Checkpoint/restart without external dependencies (deliverable:
fault tolerance at 1000+ node scale).

Design for multi-host:
  * each process writes ONLY its addressable shards
    (``arr.addressable_shards``), named by (leaf-path, shard-index);
  * a manifest (JSON) records the tree structure, global shapes, dtypes,
    sharding specs, per-file checksums, step, and pipeline state;
  * commit is atomic: write to ``<dir>.tmp``, fsync, rename;
  * restore validates checksums and re-assembles global arrays via
    ``jax.make_array_from_single_device_arrays`` (or re-shards through
    ``repro.ckpt.elastic`` when the mesh changed);
  * async save: a snapshot is taken (device→host copy) synchronously,
    serialization happens on a background thread (training continues).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                        for p in path)
        out.append((name, leaf))
    return out


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


def save(ckpt_dir: str | os.PathLike, tree, *, step: int,
         extra: Optional[Dict[str, Any]] = None, process_index: int = 0,
         asynchronous: bool = False) -> "SaveHandle":
    """Save a pytree of (possibly sharded) arrays.  Returns a handle;
    ``handle.wait()`` blocks until the checkpoint is committed."""
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir.with_name(ckpt_dir.name + f".tmp{process_index}")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    # snapshot: device -> host, synchronously (training may then continue)
    snapshot: List[Tuple[str, List[Tuple[int, np.ndarray]], Any]] = []
    for name, leaf in _leaf_paths(tree):
        shards = []
        if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
            for sh in leaf.addressable_shards:
                if sh.replica_id == 0:
                    shards.append((sh.index, np.asarray(sh.data)))
            # replicated arrays: process 0 writes one copy
            if not shards and process_index == 0:
                shards.append((None, np.asarray(leaf)))
        else:
            shards.append((None, np.asarray(leaf)))
        snapshot.append((name, shards, leaf))

    def commit():
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for name, shards, leaf in snapshot:
            entries = []
            safe = name.replace("/", "__")
            for i, (index, arr) in enumerate(shards):
                fn = f"{safe}.p{process_index}.s{i}.npy"
                np.save(tmp / fn, arr)
                entries.append({
                    "file": fn,
                    "index": _index_to_json(index),
                    "checksum": _checksum(arr),
                    "shape": list(arr.shape),
                })
            manifest["leaves"][name] = {
                "global_shape": list(getattr(leaf, "shape", np.shape(leaf))),
                "dtype": str(getattr(leaf, "dtype", np.asarray(leaf).dtype)),
                "shards": entries,
            }
        with open(tmp / f"manifest.p{process_index}.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        # atomic publish
        if ckpt_dir.exists():
            shutil.rmtree(ckpt_dir)
        os.replace(tmp, ckpt_dir)

    handle = SaveHandle()
    if asynchronous:
        t = threading.Thread(target=lambda: handle._run(commit), daemon=True)
        t.start()
        handle._thread = t
    else:
        handle._run(commit)
    return handle


class SaveHandle:
    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None
        self.done = False

    def _run(self, fn):
        try:
            fn()
        except BaseException as e:  # noqa: BLE001
            self.error = e
        finally:
            self.done = True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
        if self.error is not None:
            raise self.error
        return self


def _index_to_json(index) -> Optional[List[List[Optional[int]]]]:
    if index is None:
        return None
    out = []
    for sl in index:
        out.append([sl.start, sl.stop, sl.step])
    return out


def _json_to_index(j) -> Optional[Tuple[slice, ...]]:
    if j is None:
        return None
    return tuple(slice(a, b, c) for a, b, c in j)


def load_manifest(ckpt_dir: str | os.PathLike, process_index: int = 0) -> Dict:
    with open(Path(ckpt_dir) / f"manifest.p{process_index}.json") as f:
        return json.load(f)


def restore(ckpt_dir: str | os.PathLike, target_tree, *,
            shardings=None, process_index: int = 0,
            validate: bool = True) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target_tree`` (values ignored).

    ``shardings``: optional pytree of NamedShardings — when given, leaves
    are assembled as global arrays on that sharding (re-sharding across a
    DIFFERENT mesh goes through repro.ckpt.elastic.replan, which reads the
    manifest directly)."""
    ckpt_dir = Path(ckpt_dir)
    manifest = load_manifest(ckpt_dir, process_index)
    names = dict(_leaf_paths(target_tree))
    sh_map = dict(_leaf_paths(shardings)) if shardings is not None else {}

    restored: Dict[str, Any] = {}
    for name, meta in manifest["leaves"].items():
        full = np.zeros(meta["global_shape"], dtype=np.dtype(
            meta["dtype"].replace("bfloat16", "float32")))
        for e in meta["shards"]:
            arr = np.load(ckpt_dir / e["file"])
            if validate and _checksum(arr) != e["checksum"]:
                raise IOError(f"checksum mismatch in {e['file']}")
            idx = _json_to_index(e["index"])
            if idx is None:
                full = arr
            else:
                full[idx] = arr
        dtype = meta["dtype"]
        leaf_t = names.get(name)
        target_dtype = getattr(leaf_t, "dtype", None) or dtype
        out = jnp.asarray(full).astype(target_dtype)
        if name in sh_map:
            out = jax.device_put(out, sh_map[name])
        restored[name] = out

    # rebuild the tree in target order
    flat = []
    for name, _ in _leaf_paths(target_tree):
        if name not in restored:
            raise KeyError(f"checkpoint missing leaf {name}")
        flat.append(restored[name])
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), flat)
    return tree, {"step": manifest["step"], **manifest.get("extra", {})}

"""Structural A/B differ over two ``obs.Tracer`` event streams.

Two runs that claim bit-exactness must produce *identical* event
streams: same tracks, same events per track in the same order, same
modeled clocks, same args.  This module aligns two streams track by
track and reports the **first divergent event per track** — the blame
pointer ``repro.analysis.racecheck`` uses to localize an
order-dependence, and the thing a human wants first when an A/B
regression run stops matching.

Alignment model: events are grouped by ``track`` in emission order
(emission order per track is deterministic in a correct run — that is
the claim under test), then compared positionally.  Cross-track
emission *interleaving* is deliberately NOT compared: two streams with
identical per-track timelines are the same recording even if a
refactor moved an emission site a few lines.  The clock-delta and
by-label byte-delta summaries quantify *how far apart* two non-
identical runs drifted, which turns "the traces differ" into "tenant
b's clock ends 0.41s later and the spine trunk carried 1.2MB more of
``train:job0``".

Entry points mirror the sanitizer's: in-memory events, live tracers,
exported Chrome trace docs, or files (Perfetto JSON and the
``obs.JsonlSink`` streaming format) — ``scripts/trace_diff.py`` is the
CLI.  Stdlib-only; importing must stay cheap.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs.trace import Event

__all__ = [
    "EventDelta", "TraceDiff", "diff_events", "diff_tracers",
    "diff_trace_docs", "diff_trace_files", "load_events",
]

# Event tuple fields compared, in report order
_FIELDS = ("ph", "cat", "name", "ts", "dur", "args")


@dataclasses.dataclass(frozen=True)
class EventDelta:
    """First divergence on one track: positional index, the two events
    (either may be None when one stream's track is a prefix of the
    other's), and which fields differ."""

    track: str
    index: int
    a: Optional[Event]
    b: Optional[Event]
    fields: Tuple[str, ...]

    @property
    def ts(self) -> float:
        """Modeled time of the divergence (earliest side present)."""
        cands = [e.ts for e in (self.a, self.b) if e is not None]
        return min(cands) if cands else 0.0

    def format(self) -> str:
        if self.a is None:
            return (f"track {self.track!r} event #{self.index}: only in "
                    f"B — {_fmt_event(self.b)}")
        if self.b is None:
            return (f"track {self.track!r} event #{self.index}: only in "
                    f"A — {_fmt_event(self.a)}")
        parts = []
        for f in self.fields:
            va, vb = getattr(self.a, f), getattr(self.b, f)
            if f == "args":
                ks = sorted(set(va) | set(vb),
                            key=lambda k: (str(type(k)), str(k)))
                inner = [f"{k}: {va.get(k)!r} != {vb.get(k)!r}"
                         for k in ks if va.get(k) != vb.get(k)]
                parts.append(f"args{{{', '.join(inner)}}}")
            else:
                parts.append(f"{f}: {va!r} != {vb!r}")
        return (f"track {self.track!r} event #{self.index} "
                f"({_fmt_event(self.a)}): {'; '.join(parts)}")


def _fmt_event(ev: Optional[Event]) -> str:
    if ev is None:
        return "<absent>"
    return f"{ev.ph} {ev.name!r} @ {ev.ts:.9f}s"


@dataclasses.dataclass
class TraceDiff:
    """Outcome of one A/B pass.  ``identical`` is the bit-exactness
    verdict; everything else is blame and drift quantification."""

    identical: bool
    events_a: int
    events_b: int
    only_a: List[str]                   # tracks present only in A
    only_b: List[str]
    divergences: List[EventDelta]       # first divergence per track
    clock_delta: Dict[str, float]       # per-track last-event-end B - A
    label_bytes_delta: Dict[str, float]  # per-label link bytes B - A

    def first(self) -> Optional[EventDelta]:
        """The earliest divergence on the modeled clock (ties to track
        name) — racecheck's blame pointer."""
        if not self.divergences:
            return None
        return min(self.divergences, key=lambda d: (d.ts, d.track))

    def format(self) -> str:
        if self.identical:
            return (f"traces identical: {self.events_a} events, "
                    f"bit-exact per track")
        lines = [f"traces DIFFER: {self.events_a} events (A) vs "
                 f"{self.events_b} (B)"]
        for t in self.only_a:
            lines.append(f"  track only in A: {t!r}")
        for t in self.only_b:
            lines.append(f"  track only in B: {t!r}")
        first = self.first()
        for d in sorted(self.divergences, key=lambda d: (d.ts, d.track)):
            tag = "  FIRST " if d is first else "  "
            lines.append(tag + d.format())
        drift = {t: dv for t, dv in sorted(self.clock_delta.items())
                 if dv != 0.0}
        if drift:
            lines.append("  clock drift (B - A): " + ", ".join(
                f"{t}={dv:+.9f}s" for t, dv in drift.items()))
        bdrift = {l: dv for l, dv in
                  sorted(self.label_bytes_delta.items()) if dv != 0.0}
        if bdrift:
            lines.append("  link bytes by label (B - A): " + ", ".join(
                f"{l}={dv:+.0f}B" for l, dv in bdrift.items()))
        return "\n".join(lines)

    def to_doc(self) -> Dict[str, Any]:
        first = self.first()
        return {
            "identical": self.identical,
            "events_a": self.events_a,
            "events_b": self.events_b,
            "only_a": list(self.only_a),
            "only_b": list(self.only_b),
            "first_divergence": None if first is None else {
                "track": first.track, "index": first.index,
                "ts": first.ts, "fields": list(first.fields),
            },
            "divergences": [
                {"track": d.track, "index": d.index, "ts": d.ts,
                 "fields": list(d.fields)}
                for d in self.divergences],
            "clock_delta": dict(self.clock_delta),
            "label_bytes_delta": dict(self.label_bytes_delta),
        }


def _by_track(events: Iterable[Event]) -> Dict[str, List[Event]]:
    out: Dict[str, List[Event]] = {}
    for ev in events:
        out.setdefault(ev.track, []).append(ev)
    return out


def _first_delta(track: str, a: List[Event],
                 b: List[Event]) -> Optional[EventDelta]:
    n = min(len(a), len(b))
    for i in range(n):
        if tuple(a[i]) == tuple(b[i]):
            continue
        fields = tuple(f for f in _FIELDS
                       if getattr(a[i], f) != getattr(b[i], f))
        return EventDelta(track, i, a[i], b[i], fields or ("args",))
    if len(a) != len(b):
        ea = a[n] if n < len(a) else None
        eb = b[n] if n < len(b) else None
        return EventDelta(track, n, ea, eb, ())
    return None


def _label_bytes(events: Sequence[Event]) -> Dict[str, float]:
    """Per-label payload bytes over link-occupancy spans (tracks
    ``link:*``) — the by-label drift summary's raw material."""
    out: Dict[str, float] = {}
    for ev in events:
        if ev.track.startswith("link:") and "label" in ev.args:
            lab = ev.args["label"]
            out[lab] = out.get(lab, 0.0) + float(ev.args.get("bytes", 0.0))
    return out


def diff_events(events_a: Iterable[Event],
                events_b: Iterable[Event]) -> TraceDiff:
    """Structural diff of two event streams (see module docstring for
    the alignment model)."""
    ea, eb = list(events_a), list(events_b)
    ta, tb = _by_track(ea), _by_track(eb)
    only_a = sorted(set(ta) - set(tb))
    only_b = sorted(set(tb) - set(ta))
    divergences: List[EventDelta] = []
    clock_delta: Dict[str, float] = {}
    for track in sorted(set(ta) & set(tb)):
        d = _first_delta(track, ta[track], tb[track])
        if d is not None:
            divergences.append(d)
        end_a = max((e.ts + e.dur for e in ta[track]), default=0.0)
        end_b = max((e.ts + e.dur for e in tb[track]), default=0.0)
        clock_delta[track] = end_b - end_a
    la, lb = _label_bytes(ea), _label_bytes(eb)
    label_delta = {lab: lb.get(lab, 0.0) - la.get(lab, 0.0)
                   for lab in sorted(set(la) | set(lb))}
    identical = not (only_a or only_b or divergences)
    return TraceDiff(
        identical=identical, events_a=len(ea), events_b=len(eb),
        only_a=only_a, only_b=only_b, divergences=divergences,
        clock_delta=clock_delta, label_bytes_delta=label_delta)


def diff_tracers(a, b) -> TraceDiff:
    return diff_events(a.events(), b.events())


def diff_trace_docs(doc_a: Dict[str, Any],
                    doc_b: Dict[str, Any]) -> TraceDiff:
    # deferred import: sanitizer owns the Chrome-doc reconstruction
    from repro.analysis.sanitizer import events_from_trace_doc
    ea, _ = events_from_trace_doc(doc_a)
    eb, _ = events_from_trace_doc(doc_b)
    return diff_events(ea, eb)


def load_events(path: str) -> List[Event]:
    """Events from a trace file: Perfetto/Chrome JSON export (one
    ``traceEvents`` document) or an ``obs.JsonlSink`` stream (one
    event per line, modeled seconds, lossless)."""
    if path.endswith(".jsonl"):
        from repro.obs.trace import events_from_jsonl
        return events_from_jsonl(path)
    with open(path) as f:
        doc = json.load(f)
    from repro.analysis.sanitizer import events_from_trace_doc
    events, _ = events_from_trace_doc(doc)
    return events


def diff_trace_files(path_a: str, path_b: str) -> TraceDiff:
    return diff_events(load_events(path_a), load_events(path_b))

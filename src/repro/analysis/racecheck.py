"""Schedule-perturbation determinism harness ("racecheck").

The repro's bit-exactness claims rest on every tie in the simulators
being broken by a *spec'd total order* — FIFO by submit sequence,
serve-before-train on equal clocks, victim = max over-use then min
name — and never by an incidental enumeration order (dict insertion,
heap pop sequence, list construction).  Incidental orders are
deterministic *today*, which is exactly what makes them dangerous: a
refactor that changes one produces a run that is still reproducible,
just silently different.

This harness makes the distinction testable.  Decision sites in the
scheduler, arbiter, transport, and interleave drivers route their
candidate enumerations through :mod:`repro.analysis.tiebreak`; under
``tiebreak.perturb(seed)`` those enumerations are shuffled before the
spec'd total order is applied.  ``racecheck`` runs one scenario K+1
times — once unperturbed (the baseline) and once per seed — and
asserts the **outcome mapping** (tokens, modeled clocks, metrics
snapshots: whatever the scenario returns) and the **trace event
stream** are bit-identical every time.  On divergence it reports the
differing outcome paths and bisects the traces to the first divergent
event per track (via :mod:`repro.analysis.tracediff`), so the blame
is "track ``pool:sched``, event #41, ``admit`` of job ``b`` instead
of ``a`` at t=12.5" rather than "the numbers changed".

A scenario is a ``Callable[[Tracer], Mapping]``: build *fresh* state
(topology, engines, jobs — never reuse objects across calls), run to
completion against the supplied tracer, return the outcome mapping.
Floats are compared with exact ``==`` — close is not deterministic.

Stdlib-only; scenarios themselves may of course be as heavy as they
like.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Mapping, Optional, Sequence, Tuple

from repro.analysis import tiebreak
from repro.analysis.tracediff import TraceDiff, diff_events
from repro.obs.trace import Event, Tracer

__all__ = ["RaceDivergence", "RaceReport", "SeedResult", "racecheck"]

Scenario = Callable[[Tracer], Mapping[str, Any]]

# cap on reported outcome-path diffs per seed; divergence is usually
# one root cause fanned out over many keys, and the trace blame is the
# useful pointer anyway
_MAX_DIFFS = 20


def _is_nan(x: Any) -> bool:
    return isinstance(x, float) and x != x  # repro: allow(no-float-equality) NaN self-inequality IS the NaN test


def _compare(path: str, a: Any, b: Any, out: List[str]) -> None:
    """Recursive bit-exact comparison; appends ``path: a != b`` lines."""
    if len(out) >= _MAX_DIFFS:
        return
    if isinstance(a, Mapping) and isinstance(b, Mapping):
        ka = sorted(a, key=str)
        kb = sorted(b, key=str)
        if ka != kb:
            out.append(f"{path}: key sets differ "
                       f"({sorted(set(map(str, a)) ^ set(map(str, b)))})")
            return
        for k in ka:
            _compare(f"{path}.{k}" if path else str(k), a[k], b[k], out)
        return
    if (isinstance(a, (list, tuple)) and isinstance(b, (list, tuple))
            and not isinstance(a, str)):
        if len(a) != len(b):
            out.append(f"{path}: length {len(a)} != {len(b)}")
            return
        for i, (xa, xb) in enumerate(zip(a, b)):
            _compare(f"{path}[{i}]", xa, xb, out)
        return
    if _is_nan(a) and _is_nan(b):
        return
    if a != b:
        out.append(f"{path}: {a!r} != {b!r}")


@dataclasses.dataclass(frozen=True)
class SeedResult:
    """One perturbed run vs the baseline."""

    seed: int
    outcome_diffs: Tuple[str, ...]
    trace_diff: TraceDiff

    @property
    def ok(self) -> bool:
        return not self.outcome_diffs and self.trace_diff.identical

    def format(self) -> str:
        if self.ok:
            return f"seed {self.seed}: bit-identical"
        lines = [f"seed {self.seed}: DIVERGED"]
        first = self.trace_diff.first()
        if first is not None:
            lines.append("  first divergent trace event: " + first.format())
        for d in self.outcome_diffs:
            lines.append("  outcome " + d)
        if not self.trace_diff.identical and first is None:
            lines.append("  " + self.trace_diff.format())
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class RaceReport:
    """Verdict of one racecheck: a baseline plus one result per seed."""

    label: str
    seeds: Tuple[int, ...]
    baseline_events: int
    results: Tuple[SeedResult, ...]

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.results)

    @property
    def divergent(self) -> List[SeedResult]:
        return [r for r in self.results if not r.ok]

    def format(self) -> str:
        head = (f"racecheck[{self.label}]: {len(self.seeds)} perturbation "
                f"seeds over {self.baseline_events} baseline events — "
                + ("OK (bit-identical)" if self.ok
                   else f"{len(self.divergent)} DIVERGED"))
        if self.ok:
            return head
        return "\n".join([head] + [r.format() for r in self.divergent])

    def check(self) -> "RaceReport":
        """Raise ``RaceDivergence`` unless every seed was bit-identical."""
        if not self.ok:
            raise RaceDivergence(self)
        return self


class RaceDivergence(AssertionError):
    """A perturbed schedule produced a different run — an incidental
    enumeration order is leaking into outcomes or trace emission."""

    def __init__(self, report: RaceReport):
        self.report = report
        super().__init__(report.format())


def _run(scenario: Scenario) -> Tuple[Mapping[str, Any], List[Event]]:
    tracer = Tracer(capacity=1 << 20)
    outcome = scenario(tracer)
    if not isinstance(outcome, Mapping):
        raise TypeError(
            f"racecheck scenario must return a Mapping outcome, got "
            f"{type(outcome).__name__}")
    if tracer.dropped:
        raise RuntimeError(
            f"racecheck tracer ring dropped {tracer.dropped} events; "
            f"the trace comparison would be blind to early divergence — "
            f"shrink the scenario")
    return outcome, tracer.events()


def racecheck(scenario: Scenario, *, seeds: Sequence[int] = (1, 2, 3, 4),
              label: str = "scenario",
              check: bool = False) -> RaceReport:
    """Run ``scenario`` unperturbed, then once per perturbation seed,
    and compare every run against the baseline bit-for-bit.

    ``seeds`` pick the shuffle streams for ``tiebreak.perturb``; more
    seeds explore more incidental orders at linear cost.  With
    ``check=True`` a divergence raises :class:`RaceDivergence` (whose
    message carries the full blame report) instead of returning.
    """
    if tiebreak.active():
        raise RuntimeError("racecheck cannot run inside tiebreak.perturb()")
    base_outcome, base_events = _run(scenario)
    results: List[SeedResult] = []
    for seed in seeds:
        with tiebreak.perturb(seed):
            outcome, events = _run(scenario)
        diffs: List[str] = []
        _compare("", base_outcome, outcome, diffs)
        results.append(SeedResult(
            seed=int(seed), outcome_diffs=tuple(diffs),
            trace_diff=diff_events(base_events, events)))
    report = RaceReport(label=label, seeds=tuple(int(s) for s in seeds),
                        baseline_events=len(base_events),
                        results=tuple(results))
    if check:
        report.check()
    return report

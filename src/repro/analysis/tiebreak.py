"""Seeded tie-break perturbation seam for the determinism race detector.

The estate's headline claims are *bit-exactness* claims on one modeled
clock, yet several decision paths enumerate collections whose order is
**incidental** — dict views, candidate lists, same-timestamp event
batches.  Python's insertion-ordered dicts make those enumerations
deterministic *today*, which is exactly the trap: a refactor that
changes insertion order silently changes results, and no test notices
because every run of the changed code agrees with itself.

This module is the seam ``repro.analysis.racecheck`` drives to prove
the enumerations don't matter.  Decision paths route incidental
enumerations through :func:`order` (or :func:`shuffled`):

* **inactive** (the default, and the only mode production code ever
  sees): ``order(items)`` returns ``list(items)`` unchanged — the
  exact enumeration the subsystem used before the seam existed, so
  instrumented code is bit-identical to pre-seam code;
* **active** (inside :func:`perturb`): the enumeration is permuted by
  a seeded ``random.Random``, so K differently-seeded runs exercise K
  different enumeration orders.  If outcomes and traces stay
  bit-identical across all of them, every decision downstream of the
  seam is a total-order reduction or a commutative accumulation — the
  dynamic proof of order-insensitivity.

The discipline the seam enforces (and the ``no-unordered-iteration``
lint checks statically): *perturb enumeration orders; canonicalize
before any order-sensitive effect*.  Spec'd tie-breaks (FIFO by
submission sequence, serve-before-train on equal clocks, victim = max
over-share then min name) are encoded as **total-order sort/selection
keys**, which permutation cannot disturb; they are never themselves
perturbed.

Stdlib-only; importing this module must stay cheap (it sits on the
import path of every modeled-time subsystem).
"""

from __future__ import annotations

import contextlib
import random
from typing import Iterable, Iterator, List, Optional, TypeVar

__all__ = ["TieBreaker", "active", "current", "order", "perturb"]

T = TypeVar("T")


class TieBreaker:
    """A seeded permutation source.  One instance = one perturbation
    schedule: calls consume the generator in program order, so a fixed
    seed replays the identical perturbation sequence (the harness can
    re-run a diverging seed to bisect)."""

    def __init__(self, seed: int):
        self.seed = int(seed)
        self._rng = random.Random(self.seed)

    def order(self, items: Iterable[T]) -> List[T]:
        out = list(items)
        if len(out) > 1:
            self._rng.shuffle(out)
        return out


# the active tiebreaker, installed by ``perturb`` — module-level so the
# subsystems need no new constructor arguments (the seam must not
# change any public API or any default behavior)
_ACTIVE: Optional[TieBreaker] = None


def active() -> bool:
    """True inside a ``perturb`` context."""
    return _ACTIVE is not None


def current() -> Optional[TieBreaker]:
    return _ACTIVE


def order(items: Iterable[T]) -> List[T]:
    """The seam: claims the enumeration order of ``items`` is
    incidental.  Identity (a plain ``list``) unless a perturbation is
    active, in which case the list is re-ordered by the seeded RNG.

    Call it ONLY where every downstream effect is order-insensitive —
    a total-order ``min``/``max``/``sorted`` key, an integer sum, a
    per-key independent write.  Float accumulations and trace
    emissions are NOT order-insensitive; sort first.
    """
    if _ACTIVE is None:
        return list(items)
    return _ACTIVE.order(items)


@contextlib.contextmanager
def perturb(seed: int) -> Iterator[TieBreaker]:
    """Install a seeded :class:`TieBreaker` for the duration of the
    context.  Re-entrant (the previous tiebreaker is restored), but the
    modeled-time subsystems are single-threaded by design so there is
    no cross-thread isolation — don't run perturbed scenarios
    concurrently."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = TieBreaker(seed)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev

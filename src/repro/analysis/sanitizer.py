"""Modeled-time causality sanitizer over ``repro.obs`` event streams.

The dynamic half of ``repro.analysis`` (the static half is
``repro.analysis.lints``): every claim the estate makes — solo-exact
transport pricing, conservation of link busy-seconds, fair-share
revocation charged to the victim — is ultimately a statement about the
event stream the flight recorder captures.  This module replays that
stream (live, through a ``Tracer`` hook, or offline from an exported
Perfetto JSON) and checks the causality and conservation invariants a
correct discrete-event simulation cannot violate.  It is the analog of
a race detector for a modeled clock: the instrumented run self-checks,
and CI rejects a PR whose traces stop conserving pages or bytes.

Rules (one violation names rule, track, and modeled timestamp):

``finite-clock``
    Every event's ``ts``/``dur`` is finite and ``dur >= 0`` — NaN/inf
    clocks mean a cost model divided by zero somewhere.
``track-monotone``
    Per track, event *end* times (``ts + dur``) never regress in
    emission order: each track is one actor's timeline, and an actor
    cannot complete an event before the one it already completed.
    Exempt: the ``pool:arbiter`` track (the arbiter stamps events at
    *victims'* clocks, which interleave), ``submit`` instants (future-
    dated to the request's arrival), and ``recompute_drop`` instants
    (stamped at the drop decision, which can precede the end of spill
    spans the same reclaim episode already emitted).
``span-serial``
    Compute spans (``cat="engine"``: prefill/decode) on an engine's
    main track never overlap — one engine executes one program at a
    time.  KV spill/fetch spans are excluded: revocation legitimately
    overlaps a victim's transfers.
``transfer-causality``
    Every fabric transfer span pairs with a ``begin_transfer`` instant
    carrying the same flow id; begin precedes the span's start and the
    payload bytes agree.  Begins without a span are in-flight tails
    (a note, not a violation — the exporter may run pre-``quiesce``).
``link-conservation``
    Per link-occupancy span: ``dur >= solo_s`` (contention only slows)
    and ``bytes <= capacity * dur`` (a link cannot carry more than
    line rate).  Per link at end of stream: the interval *union* of
    its spans times capacity covers the total bytes — concurrent
    flows fair-share one link, they do not multiply it.
``kv-conservation``
    Page accounting: at every engine step-end sample, free pages plus
    resident (hot) pages across the pool's tenants equals the pool
    size — no page is leaked or double-freed, across arbiter
    revocations included.  Cross-tenant mutations between a victim's
    samples (``revoke`` pages, arbiter-initiated ``recompute_drop``
    pages) are folded into the victim's last sample; an estimate
    driven below zero is a double-free.
``revocation-attribution``
    Swap seconds ``charge``d to a tenant never exceed the revocation
    costs recorded against it as victim — nobody is billed for
    traffic that was not priced.
``sched-gang-atomic``
    Pool-scheduler gang admission is all-or-nothing: every
    gang-tagged ``admit`` instant on ``pool:sched`` is covered by a
    same-timestamp ``gang_admit`` naming exactly that many members —
    an uncovered member is a split gang, the failure mode atomic
    admission exists to prevent.
``sched-accel-conservation``
    At every admission-round sample, ``free_accels`` plus
    ``busy_accels`` equals the pool total announced by ``sched_pool``:
    no accelerator leaked by a preemption rollback or double-granted
    by an elastic grow.
``sched-job-span``
    Per job, lifecycle events are causally ordered: ``submit`` <=
    ``hold`` <= ``admit`` <= ``run:*`` segment starts, ``finish`` >=
    the last admit; a job never admits twice without an intervening
    preempt/finish; ``finish``'s ``jct_s`` equals finish minus submit.
``sched-drf-share``
    Every ``drf_share:*`` sample lies in [0, 1] — a dominant share
    above 1 means DRF admitted past a resource's capacity.
``disagg-handoff``
    Disaggregated prefill->decode KV handoff (``disagg:req*`` tracks):
    every page the decode side uses was transferred before use — the
    ``handoff_use`` instant (fired at the request's first decode step)
    lands at or after every page's fabric completion time; the page
    set is complete (as many unique ``handoff_page`` instants as the
    ``handoff`` span announced) with byte agreement between the span
    total and the per-page payloads.  A handoff begun but never used
    is a note, not a violation (the request may have been dropped).

Offline mode reuses the ``link_report_from_trace`` reconstruction
idiom: thread-name metadata maps (pid, tid) back to tracks, µs back to
modeled seconds.  A truncated recording (``recorder_dropped > 0``)
skips the stateful pairing/accounting rules (their baselines may have
been dropped) and says so in the report's notes.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.obs.trace import (CAT_ENGINE, PH_COUNTER, PH_INSTANT, PH_SPAN,
                             Event, Tracer)

__all__ = [
    "RULES", "Sanitizer", "SanitizerReport", "TraceViolation", "attach",
    "events_from_trace_doc", "sanitize_events", "sanitize_tracer",
    "sanitize_trace_doc", "sanitize_trace_file",
]

RULES = ("finite-clock", "track-monotone", "span-serial",
         "transfer-causality", "link-conservation", "kv-conservation",
         "revocation-attribution", "sched-gang-atomic",
         "sched-accel-conservation", "sched-job-span", "sched-drf-share",
         "disagg-handoff")

_ARBITER_TRACK = "pool:arbiter"
_SCHED_TRACK = "pool:sched"
# float tolerance on modeled seconds: within-step costs accumulate in
# different association orders on different paths ((a+b)+c vs a+(b+c)),
# and the µs export round-trips through two more multiplies
_REL = 1e-9


def _tol(t: float) -> float:
    return 1e-9 + _REL * abs(t)


@dataclasses.dataclass(frozen=True)
class TraceViolation:
    rule: str
    track: str
    ts: float
    message: str

    def format(self) -> str:
        return (f"{self.rule}: track={self.track!r} "
                f"t={self.ts:.9f}s: {self.message}")


@dataclasses.dataclass
class SanitizerReport:
    """Outcome of one sanitizer pass: ``ok`` iff no rule fired;
    ``checks`` counts individual assertions per rule (a rule that
    checked nothing passed vacuously — the notes say why)."""

    violations: List[TraceViolation]
    events: int
    tracks: List[str]
    checks: Dict[str, int]
    notes: List[str]

    @property
    def ok(self) -> bool:
        return not self.violations

    def format(self) -> str:
        lines = [f"modeled-time sanitizer: "
                 f"{'PASS' if self.ok else 'FAIL'} — "
                 f"{self.events} events, {len(self.tracks)} tracks, "
                 f"{len(self.violations)} violation(s)"]
        lines.append("checks: " + ", ".join(
            f"{r}={n}" for r, n in self.checks.items()))
        for n in self.notes:
            lines.append(f"note: {n}")
        for v in self.violations:
            lines.append("  " + v.format())
        return "\n".join(lines)

    def to_doc(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "events": self.events,
            "tracks": list(self.tracks),
            "checks": dict(self.checks),
            "notes": list(self.notes),
            "violations": [dataclasses.asdict(v) for v in self.violations],
        }


class Sanitizer:
    """Streaming checker: ``feed`` it events in emission order (or let
    ``attach`` hook it onto a live ``Tracer``), then ``finish()`` for
    the report.  ``truncated=True`` (a ring that dropped events)
    disables the rules whose baselines may be gone."""

    def __init__(self, *, truncated: bool = False):
        self.truncated = truncated
        self.violations: List[TraceViolation] = []
        self.notes: List[str] = []
        self.checks: Dict[str, int] = {r: 0 for r in RULES}
        self._events = 0
        self._tracks: Dict[str, None] = {}
        self._last_end: Dict[str, float] = {}
        self._engine_span_end: Dict[str, float] = {}
        # transfer pairing: fid -> (begin ts, bytes)
        self._begun: Dict[Any, Tuple[float, float]] = {}
        self._paired = 0
        # per link track: coalesced-interval accumulator + byte totals
        self._link_iv: Dict[str, List[Tuple[float, float]]] = {}
        self._link_bytes: Dict[str, float] = {}
        self._link_cap: Dict[str, float] = {}
        # KV page accounting
        self._kv_enabled = not truncated
        self._pool_pages: Dict[str, float] = {}   # per-engine pool size
        self._pool_total: Optional[float] = None  # shared-arbiter pool
        self._pool_tracks: Dict[str, None] = {}   # tracks in shared pool
        self._hot: Dict[str, float] = {}
        self._free: Dict[str, float] = {}
        # revocation attribution (per tenant, cumulative seconds)
        self._revoked_s: Dict[str, float] = {}
        self._charged_s: Dict[str, float] = {}
        # disagg KV handoff state, per "disagg:req*" track:
        # [begin (ts, pages, bytes) | None, {page idx: ready_ts},
        #  page bytes total, used?]
        self._handoff: Dict[str, List[Any]] = {}
        # pool-scheduler lifecycle state (track "pool:sched")
        self._sched_total: Optional[float] = None   # sched_pool accels
        self._sched_free: Optional[float] = None    # last free_accels
        # gang -> [(admit ts, job)] awaiting a covering gang_admit
        self._gang_admits: Dict[str, List[Tuple[float, str]]] = {}
        self._job_submit: Dict[str, float] = {}
        self._job_admit: Dict[str, float] = {}      # last admit ts
        self._job_live: Dict[str, bool] = {}        # currently admitted
        self._tracer: Optional[Tracer] = None
        if truncated:
            self.notes.append(
                "recording truncated (ring dropped events): transfer "
                "pairing, KV accounting, and attribution checks skipped")

    # ---- plumbing --------------------------------------------------------
    def _fail(self, rule: str, track: str, ts: float, msg: str) -> None:
        self.violations.append(TraceViolation(rule, track, ts, msg))

    def detach(self) -> None:
        if self._tracer is not None:
            self._tracer.remove_hook(self.feed)
            self._tracer = None

    # ---- per-event checks ------------------------------------------------
    def feed(self, ev: Event) -> None:
        self._events += 1
        self._tracks.setdefault(ev.track)
        self.checks["finite-clock"] += 1
        if not (math.isfinite(ev.ts) and math.isfinite(ev.dur)) \
                or ev.dur < 0.0:
            self._fail("finite-clock", ev.track, ev.ts,
                       f"{ev.name!r}: ts={ev.ts!r} dur={ev.dur!r} "
                       f"(must be finite, dur >= 0)")
            return          # arithmetic below would just cascade
        self._check_monotone(ev)
        if ev.ph == PH_SPAN:
            self._check_spans(ev)
        if not self.truncated:
            self._feed_kv(ev)
            self._feed_attribution(ev)
            self._feed_disagg(ev)
        self._feed_sched(ev)

    def _check_monotone(self, ev: Event) -> None:
        if ev.track == _ARBITER_TRACK \
                or ev.name in ("submit", "recompute_drop"):
            return
        end = ev.ts + ev.dur
        last = self._last_end.get(ev.track)
        self.checks["track-monotone"] += 1
        if last is not None and end < last - _tol(last):
            self._fail("track-monotone", ev.track, ev.ts,
                       f"{ev.name!r} ends at {end:.9f}s, before the "
                       f"track's previous event end {last:.9f}s — the "
                       f"actor's clock ran backwards")
        self._last_end[ev.track] = max(last or end, end)

    def _check_spans(self, ev: Event) -> None:
        track = ev.track
        if ev.cat == CAT_ENGINE and track.startswith("engine") \
                and "/" not in track:
            prev = self._engine_span_end.get(track)
            self.checks["span-serial"] += 1
            if prev is not None and ev.ts < prev - _tol(prev):
                self._fail("span-serial", track, ev.ts,
                           f"compute span {ev.name!r} starts at "
                           f"{ev.ts:.9f}s, inside the previous compute "
                           f"span (ends {prev:.9f}s) — one engine, two "
                           f"concurrent programs")
            self._engine_span_end[track] = max(prev or 0.0,
                                               ev.ts + ev.dur)
        elif track == "fabric" and "fid" in ev.args:
            self._check_transfer(ev)
        elif track.startswith("link:"):
            self._check_link_span(ev)

    def _check_transfer(self, ev: Event) -> None:
        if self.truncated:
            return
        fid = ev.args["fid"]
        self.checks["transfer-causality"] += 1
        begun = self._begun.pop(fid, None)
        if begun is None:
            self._fail("transfer-causality", ev.track, ev.ts,
                       f"transfer span {ev.name!r} (fid={fid}) has no "
                       f"begin_transfer instant — a completion with no "
                       f"cause")
            return
        b_ts, b_bytes = begun
        self._paired += 1
        if b_ts > ev.ts + _tol(b_ts):
            self._fail("transfer-causality", ev.track, ev.ts,
                       f"fid={fid}: begin at {b_ts:.9f}s is after the "
                       f"transfer span's start {ev.ts:.9f}s")
        if abs(ev.args.get("bytes", b_bytes) - b_bytes) > 0.5:
            self._fail("transfer-causality", ev.track, ev.ts,
                       f"fid={fid}: begin announced {b_bytes} bytes, "
                       f"span carried {ev.args.get('bytes')}")

    def _check_link_span(self, ev: Event) -> None:
        cap = float(ev.args.get("capacity", 0.0))
        nbytes = float(ev.args.get("bytes", 0.0))
        solo = float(ev.args.get("solo_s", 0.0))
        self.checks["link-conservation"] += 1
        if ev.dur + _tol(ev.dur) < solo:
            self._fail("link-conservation", ev.track, ev.ts,
                       f"{ev.name!r}: dur {ev.dur:.9f}s < solo_s "
                       f"{solo:.9f}s — contention made a transfer "
                       f"FASTER than its uncontended time")
        if cap > 0.0 and nbytes > cap * ev.dur * (1.0 + 1e-6) + 0.5:
            self._fail("link-conservation", ev.track, ev.ts,
                       f"{ev.name!r}: {nbytes:.0f} bytes in "
                       f"{ev.dur:.9f}s exceeds line rate "
                       f"{cap:.3e} B/s x dur")
        if cap > 0.0:
            self._link_cap.setdefault(ev.track, cap)
        self._link_bytes[ev.track] = (self._link_bytes.get(ev.track, 0.0)
                                      + nbytes)
        self._merge_interval(ev.track, ev.ts, ev.ts + ev.dur)

    def _merge_interval(self, track: str, s: float, e: float) -> None:
        """Keep the union of span intervals per link as a coalesced
        sorted list (spans arrive roughly by completion, so merges are
        near the tail)."""
        iv = self._link_iv.setdefault(track, [])
        lo, hi = s, e
        keep: List[Tuple[float, float]] = []
        for a, b in iv:
            if b < lo or a > hi:
                keep.append((a, b))
            else:
                lo, hi = min(lo, a), max(hi, b)
        keep.append((lo, hi))
        keep.sort()
        self._link_iv[track] = keep

    # ---- transfer begins / KV / attribution (instants + counters) --------
    def _feed_kv(self, ev: Event) -> None:
        track = ev.track
        if ev.ph == PH_INSTANT:
            if track == "fabric" and ev.name == "begin_transfer":
                fid = ev.args.get("fid")
                if fid in self._begun:
                    self._fail("transfer-causality", track, ev.ts,
                               f"fid={fid}: second begin_transfer "
                               f"while the first is unresolved")
                self._begun[fid] = (ev.ts,
                                    float(ev.args.get("bytes", 0.0)))
            elif ev.name == "kv_pool" and track.startswith("engine"):
                self._pool_pages[track] = float(ev.args.get("pages", 0.0))
            elif ev.name == "pool_tenants" and track == _ARBITER_TRACK:
                self._pool_total = float(ev.args.get("pages", 0.0))
                for t in ev.args.get("tenants", ()):
                    self._pool_tracks.setdefault(f"engine:{t}")
            elif ev.name == "revoke" and track == _ARBITER_TRACK:
                self._adjust_hot(f"engine:{ev.args.get('victim')}",
                                 ev.args.get("pages"), ev)
            elif ev.name == "recompute_drop" and track.startswith("engine"):
                self._adjust_hot(track, ev.args.get("pages"), ev)
        elif ev.ph == PH_COUNTER and track.startswith("engine"):
            if ev.name == "free_pages":
                self._free[track] = float(ev.args.get("value", 0.0))
            elif ev.name == "hot_pages":
                self._hot[track] = float(ev.args.get("value", 0.0))
                self._check_kv_sample(ev)

    def _adjust_hot(self, track: str, pages, ev: Event) -> None:
        """Fold a cross-tenant page mutation into the victim's last
        residency sample.  ONLY revoke/drop events move pages between
        a victim's own step-end samples — its own spills/allocations
        are refreshed by its own next sample before anyone else
        samples (single-threaded drivers interleave whole steps)."""
        if not self._kv_enabled:
            return
        if pages is None:
            self._kv_enabled = False
            self.notes.append(
                f"kv-conservation disabled: {ev.name!r} at "
                f"{ev.ts:.9f}s carries no page count (pre-instrumented "
                f"trace)")
            return
        est = self._hot.get(track, 0.0) - float(pages)
        self._hot[track] = est
        self.checks["kv-conservation"] += 1
        if est < -0.5:
            self._fail("kv-conservation", track, ev.ts,
                       f"{ev.name!r} takes {pages} pages from a tenant "
                       f"holding {est + float(pages):.0f} — pages freed "
                       f"twice")

    def _check_kv_sample(self, ev: Event) -> None:
        if not self._kv_enabled:
            return
        track = ev.track
        free = self._free.get(track)
        if free is None:
            return
        if track in self._pool_tracks and self._pool_total is not None:
            pool = self._pool_total
            hot = sum(self._hot.get(t, 0.0) for t in self._pool_tracks)
            what = (f"shared pool: free {free:.0f} + "
                    f"sum(hot) {hot:.0f}")
        else:
            pool = self._pool_pages.get(track)
            if pool is None:
                return                  # no geometry announced (yet)
            hot = self._hot[track]
            what = f"free {free:.0f} + hot {hot:.0f}"
        self.checks["kv-conservation"] += 1
        if abs(free + hot - pool) > 0.5:
            self._fail("kv-conservation", track, ev.ts,
                       f"{what} != pool {pool:.0f} — "
                       f"{'leaked' if free + hot < pool else 'conjured'}"
                       f" {abs(free + hot - pool):.0f} page(s)")

    # ---- pool-scheduler lifecycle (track "pool:sched") -------------------
    def _feed_sched(self, ev: Event) -> None:
        if ev.track != _SCHED_TRACK:
            return
        if ev.ph == PH_COUNTER and ev.name.startswith("drf_share:"):
            # stateless bound — checked even on truncated recordings
            v = float(ev.args.get("value", 0.0))
            self.checks["sched-drf-share"] += 1
            if not -1e-9 <= v <= 1.0 + 1e-9:
                self._fail("sched-drf-share", ev.track, ev.ts,
                           f"{ev.name!r} = {v!r} outside [0, 1] — DRF "
                           f"admitted past a resource's capacity")
            return
        if self.truncated:
            return          # stateful baselines below may be dropped
        if ev.ph == PH_COUNTER:
            if ev.name == "free_accels":
                self._sched_free = float(ev.args.get("value", 0.0))
            elif ev.name == "busy_accels":
                busy = float(ev.args.get("value", 0.0))
                free = self._sched_free
                if self._sched_total is None or free is None:
                    return      # no geometry announced (pre-instrumented)
                self.checks["sched-accel-conservation"] += 1
                if abs(free + busy - self._sched_total) > 0.5:
                    what = ("leaked" if free + busy < self._sched_total
                            else "conjured")
                    self._fail(
                        "sched-accel-conservation", ev.track, ev.ts,
                        f"free {free:.0f} + busy {busy:.0f} != pool "
                        f"{self._sched_total:.0f} accels — {what} "
                        f"{abs(free + busy - self._sched_total):.0f}")
            return
        if ev.ph == PH_SPAN and ev.name.startswith("run:"):
            job = ev.args.get("job")
            if job is None:
                return
            self.checks["sched-job-span"] += 1
            if not self._job_live.get(job):
                self._fail("sched-job-span", ev.track, ev.ts,
                           f"run segment for job {job!r} at {ev.ts:.9f}s "
                           f"while the job is not admitted")
            admit = self._job_admit.get(job)
            if admit is not None and ev.ts < admit - _tol(admit):
                self._fail("sched-job-span", ev.track, ev.ts,
                           f"run segment for job {job!r} starts at "
                           f"{ev.ts:.9f}s, before its last admit at "
                           f"{admit:.9f}s")
            return
        if ev.ph != PH_INSTANT:
            return
        if ev.name == "sched_pool":
            self._sched_total = float(ev.args.get("accels", 0.0))
        elif ev.name == "submit":
            job = ev.args.get("job")
            if job is not None:
                self._job_submit.setdefault(job, ev.ts)
        elif ev.name == "hold":
            self._check_job_after_submit(ev, "hold")
        elif ev.name == "admit":
            job = self._check_job_after_submit(ev, "admit")
            if job is None:
                return
            self.checks["sched-job-span"] += 1
            if self._job_live.get(job):
                self._fail("sched-job-span", ev.track, ev.ts,
                           f"job {job!r} admitted twice with no "
                           f"intervening preempt/finish")
            self._job_admit[job] = ev.ts
            self._job_live[job] = True
            gang = ev.args.get("gang") or ""
            if gang:
                self._gang_admits.setdefault(gang, []).append((ev.ts, job))
        elif ev.name == "gang_admit":
            self._check_gang_admit(ev)
        elif ev.name == "preempt":
            job = ev.args.get("job")
            if job is not None:
                self._job_live[job] = False
        elif ev.name == "finish":
            job = ev.args.get("job")
            if job is None:
                return
            self.checks["sched-job-span"] += 1
            if not self._job_live.get(job):
                self._fail("sched-job-span", ev.track, ev.ts,
                           f"job {job!r} finished while not admitted")
            self._job_live[job] = False
            admit = self._job_admit.get(job)
            if admit is not None and ev.ts < admit - _tol(admit):
                self._fail("sched-job-span", ev.track, ev.ts,
                           f"job {job!r} finishes at {ev.ts:.9f}s, before "
                           f"its last admit at {admit:.9f}s")
            submit = self._job_submit.get(job)
            jct = ev.args.get("jct_s")
            if submit is not None and jct is not None \
                    and abs(float(jct) - (ev.ts - submit)) > _tol(ev.ts):
                self._fail("sched-job-span", ev.track, ev.ts,
                           f"job {job!r} reports jct_s={float(jct):.9f} "
                           f"but finish - submit = "
                           f"{ev.ts - submit:.9f}s")

    def _check_job_after_submit(self, ev: Event,
                                what: str) -> Optional[str]:
        """Shared submit-precedes check; returns the job name (None if
        the event is unattributable, which is its own violation)."""
        job = ev.args.get("job")
        self.checks["sched-job-span"] += 1
        if job is None:
            self._fail("sched-job-span", ev.track, ev.ts,
                       f"{ev.name!r} instant carries no job name")
            return None
        submit = self._job_submit.get(job)
        if submit is None:
            self._fail("sched-job-span", ev.track, ev.ts,
                       f"job {job!r} {what} at {ev.ts:.9f}s was never "
                       f"submitted")
        elif ev.ts < submit - _tol(submit):
            self._fail("sched-job-span", ev.track, ev.ts,
                       f"job {job!r} {what} at {ev.ts:.9f}s precedes its "
                       f"submit at {submit:.9f}s")
        return job

    def _check_gang_admit(self, ev: Event) -> None:
        gang = ev.args.get("gang")
        want = int(ev.args.get("members", 0))
        buf = self._gang_admits.pop(gang, [])
        got = [j for ts, j in buf if abs(ts - ev.ts) <= _tol(ev.ts)]
        stale = [j for ts, j in buf if abs(ts - ev.ts) > _tol(ev.ts)]
        self.checks["sched-gang-atomic"] += 1
        for j in sorted(stale):
            self._fail("sched-gang-atomic", ev.track, ev.ts,
                       f"gang {gang!r}: member {j!r} admitted at a "
                       f"different timestamp than its gang_admit "
                       f"({ev.ts:.9f}s) — gang split across rounds")
        if len(got) != want:
            self._fail("sched-gang-atomic", ev.track, ev.ts,
                       f"gang {gang!r}: gang_admit names {want} "
                       f"member(s) but {len(got)} gang-tagged admit(s) "
                       f"landed at {ev.ts:.9f}s "
                       f"({sorted(got)})")

    # ---- disaggregated KV handoff (tracks "disagg:req*") -----------------
    def _feed_disagg(self, ev: Event) -> None:
        if not ev.track.startswith("disagg:"):
            return
        st = self._handoff.setdefault(ev.track, [None, {}, 0.0, False])
        if ev.ph == PH_SPAN and ev.name == "handoff":
            self.checks["disagg-handoff"] += 1
            if st[0] is not None:
                self._fail("disagg-handoff", ev.track, ev.ts,
                           f"second handoff span on one request track — "
                           f"a request's KV is streamed exactly once")
                return
            st[0] = (ev.ts, int(ev.args.get("pages", 0)),
                     float(ev.args.get("bytes", 0.0)))
            return
        if ev.ph != PH_INSTANT:
            return
        if ev.name == "handoff_page":
            # pages precede their stream span (the span's end is the
            # last page's landing); completeness is checked at use time
            self.checks["disagg-handoff"] += 1
            if st[3]:
                self._fail("disagg-handoff", ev.track, ev.ts,
                           f"page transferred after the request's first "
                           f"decode already used the stream")
                return
            idx = int(ev.args.get("page", -1))
            if idx in st[1]:
                self._fail("disagg-handoff", ev.track, ev.ts,
                           f"page {idx} transferred twice in one handoff")
                return
            st[1][idx] = float(ev.args.get("ready_ts", ev.ts))
            st[2] += float(ev.args.get("bytes", 0.0))
        elif ev.name == "handoff_use":
            self.checks["disagg-handoff"] += 1
            if st[0] is None:
                self._fail("disagg-handoff", ev.track, ev.ts,
                           f"handoff_use with no handoff span — decode "
                           f"consumed KV nobody streamed")
                return
            begin_ts, want_pages, want_bytes = st[0]
            st[3] = True
            if len(st[1]) != want_pages:
                self._fail("disagg-handoff", ev.track, ev.ts,
                           f"decode started with {len(st[1])} of "
                           f"{want_pages} announced page(s) transferred")
            if abs(st[2] - want_bytes) > 0.5 + _REL * abs(want_bytes):
                self._fail("disagg-handoff", ev.track, ev.ts,
                           f"per-page payloads total {st[2]:.0f}B but "
                           f"the handoff span announced "
                           f"{want_bytes:.0f}B")
            late = {i: r for i, r in st[1].items()
                    if r > ev.ts + _tol(ev.ts)}
            for i in sorted(late):
                self._fail("disagg-handoff", ev.track, ev.ts,
                           f"page {i} decoded before its transfer "
                           f"completed (ready at {late[i]:.9f}s, first "
                           f"decode at {ev.ts:.9f}s)")

    def _feed_attribution(self, ev: Event) -> None:
        if ev.ph != PH_INSTANT or ev.track != _ARBITER_TRACK:
            return
        if ev.name == "revoke":
            v = ev.args.get("victim")
            self._revoked_s[v] = (self._revoked_s.get(v, 0.0)
                                  + float(ev.args.get("cost_s", 0.0)))
        elif ev.name == "charge":
            t = ev.args.get("tenant")
            c = self._charged_s.get(t, 0.0) + float(ev.args.get(
                "cost_s", 0.0))
            self._charged_s[t] = c
            owed = self._revoked_s.get(t, 0.0)
            self.checks["revocation-attribution"] += 1
            if c > owed + _tol(owed):
                self._fail("revocation-attribution", ev.track, ev.ts,
                           f"tenant {t!r} charged {c:.9f}s total but "
                           f"only {owed:.9f}s of revocation cost was "
                           f"recorded against it — billed for traffic "
                           f"nobody priced")

    # ---- end of stream ---------------------------------------------------
    def finish(self) -> SanitizerReport:
        for track, nbytes in sorted(self._link_bytes.items()):
            cap = self._link_cap.get(track, 0.0)
            if cap <= 0.0:
                continue
            busy = sum(e - s for s, e in self._link_iv.get(track, ()))
            self.checks["link-conservation"] += 1
            if nbytes > cap * busy * (1.0 + 1e-6) + 0.5:
                self._fail("link-conservation", track, 0.0,
                           f"{nbytes:.0f} total bytes but only "
                           f"{busy:.9f}s of occupied time at "
                           f"{cap:.3e} B/s — more payload than the "
                           f"link's busy window can carry")
        for gang in sorted(self._gang_admits):
            members = sorted(j for _, j in self._gang_admits[gang])
            self.checks["sched-gang-atomic"] += 1
            self._fail("sched-gang-atomic", _SCHED_TRACK,
                       self._gang_admits[gang][0][0],
                       f"gang {gang!r}: gang-tagged admit(s) {members} "
                       f"never covered by a gang_admit — split gang")
        unused = sorted(t for t, st in self._handoff.items()
                        if st[0] is not None and not st[3])
        if unused:
            self.notes.append(
                f"{len(unused)} KV handoff(s) streamed but never used "
                f"by a decode step ({unused[:5]}"
                f"{'...' if len(unused) > 5 else ''}) — request dropped "
                f"or recording ended early")
        if self._begun:
            fids = sorted(self._begun, key=str)[:5]
            self.notes.append(
                f"{len(self._begun)} transfer(s) still in flight at end "
                f"of stream (fids {fids}{'...' if len(self._begun) > 5 else ''}"
                f") — exporter ran before quiesce()")
        if self._paired:
            self.notes.append(f"{self._paired} transfer span(s) paired "
                              f"with their begin instants")
        return SanitizerReport(
            violations=list(self.violations),
            events=self._events,
            tracks=list(self._tracks),
            checks=dict(self.checks),
            notes=list(self.notes),
        )


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def attach(tracer: Tracer) -> Sanitizer:
    """Hook a live sanitizer onto ``tracer``: every subsequently
    emitted event is checked as it happens (before the ring can drop
    it).  Call ``finish()`` for the report and ``detach()`` to stop
    observing."""
    s = Sanitizer()
    tracer.add_hook(s.feed)
    s._tracer = tracer
    return s


def sanitize_events(events: Iterable[Event], *,
                    truncated: bool = False) -> SanitizerReport:
    s = Sanitizer(truncated=truncated)
    for ev in events:
        s.feed(ev)
    return s.finish()


def sanitize_tracer(tracer: Tracer) -> SanitizerReport:
    """Offline pass over a tracer's surviving ring contents."""
    return sanitize_events(tracer.events(), truncated=tracer.dropped > 0)


def events_from_trace_doc(doc: Dict[str, Any]
                          ) -> Tuple[List[Event], int]:
    """Rebuild ``(events, dropped)`` from an exported Chrome trace_event
    document: thread-name metadata maps (pid, tid) back to tracks, µs
    back to modeled seconds.  Event order in the file IS emission
    order (the exporter appends metadata first, then the ring)."""
    names: Dict[int, Dict[int, str]] = {}
    for e in doc.get("traceEvents", []):
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            names.setdefault(e["pid"], {})[e["tid"]] = e["args"]["name"]
    out: List[Event] = []
    for e in doc.get("traceEvents", []):
        ph = e.get("ph")
        if ph not in (PH_SPAN, PH_INSTANT, PH_COUNTER):
            continue
        track = names.get(e.get("pid"), {}).get(e.get("tid"))
        if track is None:
            track = f"pid{e.get('pid')}:tid{e.get('tid')}"
        out.append(Event(ph, e.get("cat", ""), track, e.get("name", ""),
                         e.get("ts", 0.0) / 1e6,
                         e.get("dur", 0.0) / 1e6,
                         dict(e.get("args", {}))))
    dropped = int(doc.get("otherData", {}).get("recorder_dropped", 0))
    return out, dropped


def sanitize_trace_doc(doc: Dict[str, Any]) -> SanitizerReport:
    events, dropped = events_from_trace_doc(doc)
    return sanitize_events(events, truncated=dropped > 0)


def sanitize_trace_file(path: str) -> SanitizerReport:
    with open(path) as f:
        return sanitize_trace_doc(json.load(f))

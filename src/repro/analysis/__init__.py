"""repro.analysis — static + dynamic correctness tooling for the estate.

Four layers, one discipline (modeled-time determinism — the property
every headline claim in this repo rests on):

    lints     — pluggable AST rule engine (``repro.analysis.lints``)
                with per-line ``# repro: allow(<rule>)`` suppressions:
                ``no-bare-print``, ``no-wallclock``, ``compat-imports``,
                ``no-mutable-default``, ``no-unordered-iteration``,
                ``no-float-equality``.  CLI:
                ``python -m repro.analysis.lints src/repro``.
    sanitizer — modeled-time causality checker over ``obs.Tracer``
                event streams, live (``attach(tracer)``) or offline
                from an exported Perfetto JSON
                (``sanitize_trace_file``); wired into every benchmark
                CLI as ``--sanitize`` and ``scripts/sanitize_trace.py``.
    racecheck — schedule-perturbation determinism harness: the
                ``tiebreak`` seam shuffles incidental candidate
                enumerations in the scheduler/arbiter/transport/
                interleave drivers, and ``racecheck`` proves a
                scenario's outcomes and trace are bit-identical under
                K perturbed schedules (``--racecheck K`` on the fig
                CLIs).
    tracediff — structural A/B differ over two trace event streams:
                per-track first divergent event, clock drift, and
                by-label byte drift; ``scripts/trace_diff.py`` is the
                CLI.

Invariants the sanitizer enforces
---------------------------------

* **finite-clock** — every ``ts``/``dur`` finite, ``dur >= 0``.
* **track-monotone** — per-track event *end* times never regress: one
  track is one actor's timeline.  (Exempt: the arbiter's track, which
  stamps events at victims' clocks; future-dated ``submit`` instants;
  ``recompute_drop`` decisions that precede already-emitted spill
  ends.)
* **span-serial** — an engine's compute spans (prefill/decode) never
  overlap: one engine runs one program at a time.
* **transfer-causality** — every fabric transfer span pairs 1:1 with a
  ``begin_transfer`` instant of the same flow id, begin <= completion,
  payload bytes agree.
* **link-conservation** — per link span ``dur >= solo_s`` and
  ``bytes <= capacity * dur``; per link, total bytes fit inside the
  interval-union of its occupancy spans times capacity (concurrent
  flows share a link, they don't multiply it).
* **kv-conservation** — at every engine step-end sample, free pages +
  resident pages across the pool's tenants == pool size: no page
  leaked or double-freed, arbiter revocations included.
* **revocation-attribution** — seconds charged to a victim tenant
  never exceed the revocation costs recorded against it.
* **disagg-handoff** — disaggregated prefill->decode KV streams
  (``disagg:req*`` tracks): every page is transferred before the
  request's first decode step uses it, the page set is complete, and
  per-page payload bytes agree with the handoff span's total.

This module deliberately imports nothing heavyweight (no jax): the
lint CLI and offline sanitizer must start fast enough to run on every
commit.
"""

from repro.analysis import tiebreak
from repro.analysis.racecheck import (RaceDivergence, RaceReport,
                                      SeedResult, racecheck)
from repro.analysis.sanitizer import (RULES, Sanitizer, SanitizerReport,
                                      TraceViolation, attach,
                                      events_from_trace_doc,
                                      sanitize_events, sanitize_tracer,
                                      sanitize_trace_doc,
                                      sanitize_trace_file)
from repro.analysis.tracediff import (EventDelta, TraceDiff, diff_events,
                                      diff_tracers, diff_trace_docs,
                                      diff_trace_files)

__all__ = [
    "EventDelta", "RULES", "RaceDivergence", "RaceReport", "Sanitizer",
    "SanitizerReport", "SeedResult", "TraceDiff", "TraceViolation",
    "attach", "diff_events", "diff_tracers", "diff_trace_docs",
    "diff_trace_files", "events_from_trace_doc", "racecheck",
    "sanitize_events", "sanitize_tracer", "sanitize_trace_doc",
    "sanitize_trace_file", "tiebreak",
]

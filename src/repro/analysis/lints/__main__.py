"""``python -m repro.analysis.lints [PATH...]``."""

import sys

from repro.analysis.lints import main

sys.exit(main())

"""Pluggable AST lint rules for the modeled-time estate.

The repo's headline claims (bit-identical traced/untraced runs,
solo-exact transport pricing, conservation of link busy-seconds) rest
on *modeled-time determinism*: library code must never read the host
wall clock, draw unseeded randomness, or bypass the observability
layer.  These rules are the static half of that discipline — the
dynamic half is ``repro.analysis.sanitizer``, which checks the event
streams the instrumented runs actually emit.

Each rule is an AST visitor keyed by a stable name; violations carry
``path:line`` plus a message.  A justified exception is annotated
inline on the offending line::

    t0 = time.time()    # repro: allow(no-wallclock) host-side profiling

Shipped rules:

``no-bare-print``
    No ``print(`` calls anywhere under ``src/repro`` — human-facing
    output goes through ``repro.obs.console``, reports through the
    metrics registry.  (Migrated from ``scripts/lint_no_print.py``,
    which is now a shim over this framework.)
``no-wallclock``
    Inside the modeled-time subsystems (``serve/``, ``fabric/``,
    ``pool/``, ``colo/``, ``obs/``): no ``time.time()`` /
    ``perf_counter()`` / ``datetime.now()`` and no *unseeded* module-
    level ``random`` / ``np.random`` calls.  Wall clocks and ambient
    RNG state make event streams host-dependent; modeled clocks and
    explicitly-seeded generators do not.
``compat-imports``
    The jax surfaces that drifted across 0.4.x vs >=0.6 (``shard_map``
    kwargs, ``set_mesh``/``use_mesh``, pallas compiler params,
    ``Compiled.cost_analysis()`` shape) must be reached through
    ``repro.core.compat``, never imported from jax directly.
``no-mutable-default``
    No mutable literals (list/dict/set displays or comprehensions) as
    function-parameter or dataclass-field defaults — the shared-
    instance aliasing bug class.
``no-unordered-iteration``
    In the scheduling decision paths (``pool/scheduler.py``,
    ``serve/arbiter.py``, ``fabric/transport.py``): no ``for`` loop or
    comprehension directly over a dict view (``.items()`` /
    ``.values()`` / ``.keys()``) or a set.  Insertion order is
    deterministic *today*, which is the trap — a refactor that changes
    insertion order silently changes scheduling outcomes and every run
    of the changed code agrees with itself.  Route the enumeration
    through ``sorted(...)`` (canonical) or
    ``repro.analysis.tiebreak.order(...)`` (the racecheck
    perturbation seam), or annotate a proof of order-insensitivity
    (integer sums, ``any``/``all``, total-order ``min``/``max`` keys,
    per-key independent writes).
``no-float-equality``
    Inside the modeled-time subsystems (``serve/``, ``fabric/``,
    ``pool/``, ``colo/``): no ``==`` / ``!=`` against a modeled-time
    value (``clock``, ``*_s``, ``t``, ``dt``, ``completion``, ...).
    Accumulated floats are association-sensitive; two clocks that are
    "the same time" may differ in the last ulp, so float equality on
    them is a latent heisenbug.  The sanctioned patterns — identity
    tests of an uncopied stored float (heap keys, progress checks) —
    are annotated where they occur.

CLI::

    PYTHONPATH=src python -m repro.analysis.lints [PATH...]   # default src/repro
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "LintViolation", "Rule", "RULES", "iter_py_files", "lint_file",
    "lint_paths", "main", "suppressed_lines",
]

# one inline annotation silences one rule on one line:
#   ``# repro: allow(<rule>)`` with an optional trailing reason
_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\(\s*([\w\-,\s]+?)\s*\)")

# subsystems that run on the modeled clock: the no-wallclock scope
MODELED_TIME_DIRS = ("serve", "fabric", "pool", "colo", "obs",
                     "disagg")


@dataclasses.dataclass(frozen=True)
class LintViolation:
    rule: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"


def suppressed_lines(source: str) -> dict:
    """Map line number -> set of rule names allowed on that line."""
    out: dict = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(text)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


class Rule:
    """One lint rule.  Subclasses set ``name``/``description`` and
    implement ``check``; ``applies_to`` scopes the rule by path."""

    name: str = ""
    description: str = ""

    def applies_to(self, path: Path) -> bool:
        return True

    def check(self, tree: ast.AST, path: Path,
              source: str) -> Iterator[Tuple[int, str]]:
        raise NotImplementedError


def _call_name(node: ast.AST) -> Optional[str]:
    """Dotted name of a call target: ``a.b.c`` -> "a.b.c", else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class NoBarePrint(Rule):
    name = "no-bare-print"
    description = ("bare print() in library code — use repro.obs.console "
                   "or the metrics registry")

    def check(self, tree, path, source):
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "print"):
                yield node.lineno, ("bare print() in library code (route "
                                    "through repro.obs.console)")


class NoWallclock(Rule):
    name = "no-wallclock"
    description = ("wall-clock reads / unseeded RNG inside modeled-time "
                   "subsystems break trace determinism")

    # module-level calls that read host state
    _WALLCLOCK_CALLS = {
        "time.time", "time.time_ns", "time.perf_counter",
        "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
        "time.process_time",
        "datetime.now", "datetime.utcnow", "datetime.today", "date.today",
        "datetime.datetime.now", "datetime.datetime.utcnow",
        "datetime.datetime.today", "datetime.date.today",
    }
    # module-state RNG namespaces: any call into them is ambient/unseeded
    _RNG_MODULES = ("random.", "np.random.", "numpy.random.",
                    "jax.random.")            # jax.random.* is keyed, so
    # jax.random is NOT ambient — exclude it below; listed here only to
    # document the decision
    _RNG_CLASS_OK = {"Random", "RandomState", "Generator", "SeedSequence",
                     "default_rng", "PRNGKey", "key"}
    _WALLCLOCK_IMPORTS = {
        ("time", "time"), ("time", "time_ns"), ("time", "perf_counter"),
        ("time", "perf_counter_ns"), ("time", "monotonic"),
        ("time", "monotonic_ns"), ("time", "process_time"),
        ("datetime", "datetime"), ("datetime", "date"),
    }

    def applies_to(self, path: Path) -> bool:
        parts = set(path.parts)
        return "repro" in parts and bool(parts & set(MODELED_TIME_DIRS))

    def _rng_violation(self, dotted: str, node: ast.Call) -> Optional[str]:
        for mod in ("random.", "np.random.", "numpy.random."):
            if dotted.startswith(mod):
                fn = dotted[len(mod):]
                if fn in ("seed",):
                    return (f"{dotted}() mutates global RNG state — "
                            f"construct a seeded generator instead")
                if fn not in self._RNG_CLASS_OK:
                    return (f"{dotted}() draws from ambient RNG state — "
                            f"use a seeded RandomState/Generator")
                # constructing a generator is fine only when seeded
                if not node.args and not any(
                        kw.arg in ("seed", "x") for kw in node.keywords):
                    return (f"{dotted}() without a seed is "
                            f"host-nondeterministic")
        return None

    def check(self, tree, path, source):
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                dotted = _call_name(node.func)
                if dotted is None:
                    continue
                if dotted in self._WALLCLOCK_CALLS:
                    yield node.lineno, (
                        f"{dotted}() reads the host wall clock inside a "
                        f"modeled-time subsystem")
                    continue
                msg = self._rng_violation(dotted, node)
                if msg is not None:
                    yield node.lineno, msg
            elif isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    if (node.module, alias.name) in self._WALLCLOCK_IMPORTS:
                        yield node.lineno, (
                            f"'from {node.module} import {alias.name}' "
                            f"pulls a wall-clock surface into a "
                            f"modeled-time subsystem")


class CompatImports(Rule):
    name = "compat-imports"
    description = ("version-drifted jax surfaces must be reached via "
                   "repro.core.compat")

    _DRIFTED_NAMES = {"shard_map", "set_mesh", "use_mesh",
                      "CompilerParams", "TPUCompilerParams"}
    # receivers sanctioned to expose the drifted call shape
    _OK_RECEIVERS = {"compat"}

    def applies_to(self, path: Path) -> bool:
        return not str(path).endswith("core/compat.py")

    def check(self, tree, path, source):
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module \
                    and node.module.split(".")[0] == "jax":
                for alias in node.names:
                    if alias.name in self._DRIFTED_NAMES:
                        yield node.lineno, (
                            f"'from {node.module} import {alias.name}' — "
                            f"this surface drifted across jax versions; "
                            f"import it from repro.core.compat")
            elif isinstance(node, ast.Call):
                dotted = _call_name(node.func)
                if dotted is None:
                    continue
                head, _, tail = dotted.rpartition(".")
                if tail == "cost_analysis" and head \
                        and head not in self._OK_RECEIVERS:
                    yield node.lineno, (
                        f"{dotted}() — Compiled.cost_analysis() changed "
                        f"shape across jax versions; call "
                        f"repro.core.compat.cost_analysis(compiled)")
                elif tail in ("CompilerParams", "TPUCompilerParams") \
                        and head.split(".")[0] not in self._OK_RECEIVERS:
                    yield node.lineno, (
                        f"{dotted}() — pallas compiler params drifted; "
                        f"use repro.core.compat.tpu_compiler_params()")


class NoMutableDefault(Rule):
    name = "no-mutable-default"
    description = ("mutable literal as a function/dataclass default "
                   "aliases one instance across calls")

    _MUTABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                ast.SetComp)

    def _defaults(self, fn) -> Iterator[ast.AST]:
        args = fn.args
        yield from (d for d in args.defaults if d is not None)
        yield from (d for d in args.kw_defaults if d is not None)

    def _is_dataclass(self, cls: ast.ClassDef) -> bool:
        for dec in cls.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            name = _call_name(target) or ""
            if name.split(".")[-1] == "dataclass":
                return True
        return False

    def check(self, tree, path, source):
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for d in self._defaults(node):
                    if isinstance(d, self._MUTABLE):
                        yield d.lineno, (
                            f"mutable default in {node.name}() is shared "
                            f"across calls — default to None (or a "
                            f"dataclasses.field factory)")
            elif isinstance(node, ast.ClassDef) and self._is_dataclass(node):
                for stmt in node.body:
                    value = None
                    if isinstance(stmt, ast.AnnAssign):
                        value = stmt.value
                    elif isinstance(stmt, ast.Assign):
                        value = stmt.value
                    if isinstance(value, self._MUTABLE):
                        yield value.lineno, (
                            f"mutable default on dataclass {node.name} "
                            f"field — use dataclasses.field("
                            f"default_factory=...)")


class NoUnorderedIteration(Rule):
    name = "no-unordered-iteration"
    description = ("dict/set enumeration order must not feed scheduling "
                   "decisions — sort it, seam it, or prove it "
                   "order-insensitive")

    # the decision paths whose enumeration order picks winners: event
    # draining / DRF admission, water-filling / victim selection, and
    # in-flight flow re-rating
    _FILES = ("pool/scheduler.py", "serve/arbiter.py",
              "fabric/transport.py", "disagg/router.py")
    _VIEWS = {"items", "values", "keys"}
    # wrappers that make enumeration order canonical (sorted) or
    # deliberately perturbed (the repro.analysis.tiebreak seam)
    _SAFE_CALLS = {"sorted"}
    _SEAM_ATTR = "order"

    def applies_to(self, path: Path) -> bool:
        p = str(path)
        return any(p.endswith(f) for f in self._FILES)

    def _iter_violation(self, it: ast.AST) -> Optional[str]:
        if isinstance(it, ast.Call):
            fn = it.func
            if isinstance(fn, ast.Name) and fn.id in self._SAFE_CALLS:
                return None
            if isinstance(fn, ast.Attribute) \
                    and fn.attr == self._SEAM_ATTR:
                return None         # tiebreak.order(...) racecheck seam
            if isinstance(fn, ast.Attribute) and fn.attr in self._VIEWS:
                return (f"iteration over .{fn.attr}() exposes dict "
                        f"insertion order to a scheduling decision — "
                        f"wrap in sorted(...) or tiebreak.order(...), "
                        f"or annotate a proof of order-insensitivity")
            if isinstance(fn, ast.Name) and fn.id in ("set", "frozenset"):
                return ("iteration over a set exposes hash order — "
                        "wrap in sorted(...)")
        if isinstance(it, (ast.Set, ast.SetComp)):
            return ("iteration over a set display exposes hash order — "
                    "wrap in sorted(...)")
        return None

    def check(self, tree, path, source):
        # a comprehension fed DIRECTLY to sorted(...) is canonicalized
        # by construction — its internal enumeration order cannot leak
        sanctioned = {
            id(arg)
            for node in ast.walk(tree)
            if isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id in self._SAFE_CALLS
            for arg in node.args
            if isinstance(arg, (ast.ListComp, ast.SetComp,
                                ast.GeneratorExp))
        }
        for node in ast.walk(tree):
            iters: List[ast.AST] = []
            if isinstance(node, ast.For):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.SetComp,
                                   ast.DictComp, ast.GeneratorExp)):
                if id(node) in sanctioned:
                    continue
                iters.extend(g.iter for g in node.generators)
            for it in iters:
                msg = self._iter_violation(it)
                if msg is not None:
                    yield it.lineno, msg


class NoFloatEquality(Rule):
    name = "no-float-equality"
    description = ("== / != on modeled-time values — accumulated floats "
                   "are association-sensitive; compare with a tolerance "
                   "or annotate the identity-test exceptions")

    # modeled-time subsystems (obs excluded: it never *computes* times,
    # only records them)
    _DIRS = ("serve", "fabric", "pool", "colo", "disagg")
    # identifier heuristics for "this is a modeled-time value"
    _EXACT = {"t", "ts", "dt", "now", "t0", "t1", "t_req", "t_eff",
              "before", "clock", "horizon", "deadline"}
    _SUBSTR = ("time", "clock", "deadline", "arrival", "completion",
               "latency", "horizon")
    _SUFFIXES = ("_s", "_t", "_ts")

    def applies_to(self, path: Path) -> bool:
        parts = set(path.parts)
        return "repro" in parts and bool(parts & set(self._DIRS))

    def _timeish(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Attribute):
            ident = node.attr
        elif isinstance(node, ast.Name):
            ident = node.id
        else:
            return None
        low = ident.lower()
        if low in self._EXACT or low.endswith(self._SUFFIXES) \
                or any(s in low for s in self._SUBSTR):
            return ident
        return None

    def check(self, tree, path, source):
        for node in ast.walk(tree):
            if not isinstance(node, ast.Compare):
                continue
            if not any(isinstance(op, (ast.Eq, ast.NotEq))
                       for op in node.ops):
                continue
            for operand in [node.left, *node.comparators]:
                ident = self._timeish(operand)
                if ident is not None:
                    yield node.lineno, (
                        f"float equality against modeled-time value "
                        f"{ident!r} — accumulated clocks differ in the "
                        f"last ulp across association orders; compare "
                        f"with a tolerance (or annotate an identity "
                        f"test of one stored float)")
                    break


RULES: Tuple[Rule, ...] = (NoBarePrint(), NoWallclock(), CompatImports(),
                           NoMutableDefault(), NoUnorderedIteration(),
                           NoFloatEquality())


def iter_py_files(roots: Sequence[Path]) -> Iterator[Path]:
    for root in roots:
        if root.is_file():
            yield root
        else:
            yield from sorted(root.rglob("*.py"))


def lint_file(path: Path, rules: Iterable[Rule] = RULES
              ) -> List[LintViolation]:
    """All un-suppressed violations in one file."""
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as err:
        return [LintViolation("syntax", str(path), err.lineno or 0,
                              f"does not parse: {err.msg}")]
    allowed = suppressed_lines(source)
    out: List[LintViolation] = []
    for rule in rules:
        if not rule.applies_to(path):
            continue
        for line, message in rule.check(tree, path, source):
            if rule.name in allowed.get(line, ()):
                continue
            out.append(LintViolation(rule.name, str(path), line, message))
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def lint_paths(paths: Sequence[Path], rules: Iterable[Rule] = RULES
               ) -> List[LintViolation]:
    out: List[LintViolation] = []
    for f in iter_py_files([Path(p) for p in paths]):
        out.extend(lint_file(f, rules))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI: lint the given trees (default ``src/repro``); exit 1 on any
    un-annotated violation."""
    import argparse

    from repro.obs.console import emit, warn

    ap = argparse.ArgumentParser(
        prog="repro.analysis.lints",
        description="AST lint rules guarding modeled-time determinism")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    metavar="PATH", help="files or trees to lint")
    ap.add_argument("--rule", action="append", default=None,
                    metavar="NAME", dest="rules",
                    help="run only the named rule (repeatable)")
    ap.add_argument("--list-rules", action="store_true",
                    help="list available rules and exit")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule in RULES:
            emit(f"{rule.name:20s} {rule.description}")
        return 0
    rules: Iterable[Rule] = RULES
    if args.rules:
        by_name = {r.name: r for r in RULES}
        unknown = [n for n in args.rules if n not in by_name]
        if unknown:
            warn(f"unknown rule(s): {', '.join(unknown)} "
                 f"(have: {', '.join(by_name)})")
            return 2
        rules = tuple(by_name[n] for n in args.rules)
    violations = lint_paths([Path(p) for p in args.paths], rules)
    for v in violations:
        emit(v.format())
    names = ", ".join(r.name for r in rules)
    where = ", ".join(str(p) for p in args.paths)
    if violations:
        warn(f"{len(violations)} lint violation(s) over {where} "
             f"[{names}] — annotate justified lines with "
             f"'# repro: allow(<rule>) <reason>'")
        return 1
    import sys
    emit(f"repro.analysis.lints: clean ({where}) [{names}]",
         stream=sys.stderr)
    return 0

"""whisper-small [arXiv:2212.04356]: 12L (enc) + 12L (dec) d=768 12H
d_ff=3072 vocab=51865 — enc-dec, conv frontend STUBBED (input_specs
provides 1500 precomputed frame embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865,
    norm_type="layernorm", mlp_gated=False, mlp_activation="gelu",
    enc_seq=1500, frontend="audio",
)

SMOKE = ModelConfig(
    name="whisper-small-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, norm_type="layernorm", mlp_gated=False,
    mlp_activation="gelu", enc_seq=32, frontend="audio",
)

"""Architecture registry: --arch <id> resolves here."""
from repro.models.config import ModelConfig, ShapeConfig, SHAPES, supports_shape

from repro.configs import (
    qwen1_5_0_5b, qwen3_14b, command_r_plus_104b, olmo_1b, mamba2_780m,
    pixtral_12b, mixtral_8x7b, olmoe_1b_7b, zamba2_7b, whisper_small,
)

_MODULES = {
    "qwen1.5-0.5b": qwen1_5_0_5b,
    "qwen3-14b": qwen3_14b,
    "command-r-plus-104b": command_r_plus_104b,
    "olmo-1b": olmo_1b,
    "mamba2-780m": mamba2_780m,
    "pixtral-12b": pixtral_12b,
    "mixtral-8x7b": mixtral_8x7b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "zamba2-7b": zamba2_7b,
    "whisper-small": whisper_small,
}

ARCHS = {name: mod.CONFIG for name, mod in _MODULES.items()}
SMOKE_ARCHS = {name: mod.SMOKE for name, mod in _MODULES.items()}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    table = SMOKE_ARCHS if smoke else ARCHS
    if arch not in table:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(table)}")
    return table[arch]

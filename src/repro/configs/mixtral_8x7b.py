"""mixtral-8x7b [arXiv:2401.04088]: 32L d=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, SWA 4096."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000,
    n_experts=8, top_k=2, expert_d_ff=14336,
    sliding_window=4096, norm_type="rmsnorm", rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, n_experts=4, top_k=2, expert_d_ff=128,
    sliding_window=32, norm_type="rmsnorm",
)

"""zamba2-7b [arXiv:2411.15242]: 81L d=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block
every 6 layers (13 invocations + 3 tail mamba layers)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    attn_every=6, norm_type="rmsnorm",
)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke", family="hybrid",
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
    attn_every=3, norm_type="rmsnorm",
)

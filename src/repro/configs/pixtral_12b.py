"""pixtral-12b [hf:mistralai/Pixtral-12B-2409]: 40L d=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072 — mistral-nemo decoder backbone; pixtral-ViT
frontend STUBBED (input_specs provides patch embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072,
    norm_type="rmsnorm", rope_theta=1_000_000_000.0,
    frontend="vision",
)

SMOKE = ModelConfig(
    name="pixtral-12b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, norm_type="rmsnorm", frontend="vision",
)

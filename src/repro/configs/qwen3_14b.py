"""qwen3-14b [hf:Qwen/Qwen3-*]: 40L d=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm, GQA.  head_dim=128."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab=151936,
    qk_norm=True, norm_type="rmsnorm", rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-14b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=5, n_kv_heads=1, head_dim=16,
    d_ff=192, vocab=256, qk_norm=True, norm_type="rmsnorm",
)

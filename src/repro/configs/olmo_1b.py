"""olmo-1b [arXiv:2402.00838]: 16L d=2048 16H (GQA kv=16) d_ff=8192
vocab=50304 — non-parametric LayerNorm, untied ff (SwiGLU d_ff=8192
interpreted as the MLP hidden)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=50304,
    norm_type="nonparam_ln", mlp_gated=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="olmo-1b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=256, norm_type="nonparam_ln", tie_embeddings=True,
)

"""mamba2-780m [arXiv:2405.21060]: 48L d=1536 attn-free, ssm_state=128,
SSD (state-space duality).  d_inner = 2*d = 3072, headdim 64 -> 48 heads."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, head_dim=1,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    norm_type="rmsnorm", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=256, head_dim=1,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
    norm_type="rmsnorm", tie_embeddings=True,
)

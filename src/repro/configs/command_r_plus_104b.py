"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-*]: 64L d=12288 96H
(GQA kv=8) d_ff=33792 vocab=256000 — GQA, no-bias, parallel block,
non-RoPE-scaled LayerNorm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab=256000,
    qkv_bias=False, norm_type="layernorm", parallel_block=True,
    rope_theta=75_000_000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="command-r-plus-104b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=160, vocab=256, norm_type="layernorm", parallel_block=True,
    tie_embeddings=True,
)

"""repro.colo — train+serve co-residency on one contended estate.

The paper's headline claims are about LLM *training* on the unified
XLink-CXL fabric, yet training collectives priced on a whole-fabric
``core.fabric.FabricSpec`` are invisible to the ``fabric.Transport``
that serving spill/fetch traffic rides — the two workload classes can
never contend for the same links.  This package closes that gap:

    collectives — per-job routed collective phases: each training
        job's fabric-crossing phases (PP boundary, exposed DP
        gradient, optimizer offload) become in-flight transfers on
        the shared ``Transport``, max-min sharing links with serving
        traffic, with the closed-form ``core.simulator`` time as the
        uncontended base (bit-exact when solo);
    driver      — a clock-interleaved co-residency driver advancing
        training step events and ``run_multi_trace`` serving engines
        on one shared modeled clock and one shared ``Transport``.

Contention-aware *placement* for co-resident jobs lives in
``repro.pool.allocator`` (``policy="contention"``); the joint frontier
benchmark is ``benchmarks/fig11_colocation.py``.
"""

from repro.colo.collectives import (CollectivePhase, TrainActor,
                                    job_routes, plan_phases)
from repro.colo.driver import ColoResult, run_colo

__all__ = ["CollectivePhase", "ColoResult", "TrainActor", "job_routes",
           "plan_phases", "run_colo"]

"""Per-job routed collective phases over a shared ``fabric.Transport``.

``core.simulator.simulate_step`` prices a training step with closed-form
collective algebra on whole-fabric ``FabricSpec``s.  Co-residency needs
the fabric-crossing slices of that step to be *visible* on the estate
graph: registered as in-flight transfers so they max-min share links
with serving spill/fetch traffic (and other jobs' collectives), and so
their link occupancy shows up in ``obs.link_report`` under the job's
label.

The decomposition keeps the legacy step time as the uncontended base
and adds only the *contention stretch* the transport observes
(``core.costmodel.routed_phase_time``): a solo job's routed step is
bit-identical to ``simulate_step(...).total``, because the stretch
compares the transport's duration against the identical float
expression its solo fast path evaluates.  The registered volume per
phase is chosen so the phase occupies its route for exactly its base
duration at the route's bottleneck bandwidth
(``core.costmodel.phase_volume``) — attribution is scale-invariant in
the estate's absolute link capacities.

Phase-to-route mapping for a placed job (gateway = lowest pod id):

    pp       — gateway pod -> next pod (stage boundary traffic)
    dp       — gateway pod -> farthest pod (inter-group gradient phase)
    offload  — gateway pod -> tier-2 memory node (optimizer shuttle)

Phases whose closed-form base fits inside the route latency register
nothing (there is no meaningful payload to serialize).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

from repro.core import costmodel as cm
from repro.core.simulator import StepBreakdown
from repro.fabric.topology import Route, Topology

# step phases that cross the inter fabric, in intra-step order: the
# StepBreakdown field carrying each phase's closed-form base seconds
_PHASE_FIELDS = (("pp", "comm_pp"), ("dp", "comm_dp_exposed"),
                 ("offload", "offload"))


@dataclass(frozen=True)
class CollectivePhase:
    """One fabric-crossing slice of a training step, pinned to a route."""
    name: str          # "pp" | "dp" | "offload"
    base_s: float      # legacy closed-form seconds (uncontended)
    route: Route
    volume: float      # payload bytes registered on the transport


def job_routes(topo: Topology, pods: Sequence[int],
               mem_nodes: Sequence[int] = ()) -> Dict[str, Route]:
    """Pin a placed job's collective routes on the estate graph: the
    gang's gateway (lowest pod id) anchors the PP boundary to its
    nearest peer, the DP inter-group phase to its farthest peer, and
    the offload shuttle to the job's first tier-2 node."""
    pods = sorted(set(pods))
    routes: Dict[str, Route] = {}
    if len(pods) > 1:
        gw = f"pod:{pods[0]}"
        routes["pp"] = topo.route(gw, f"pod:{pods[1]}")
        routes["dp"] = topo.route(gw, f"pod:{pods[-1]}")
    if mem_nodes:
        routes["offload"] = topo.route(f"pod:{pods[0]}",
                                       f"mem:{sorted(mem_nodes)[0]}")
    return routes


def plan_phases(bd: StepBreakdown,
                routes: Dict[str, Route]) -> Tuple[CollectivePhase, ...]:
    """The fabric-crossing phases of one step that actually carry
    payload on this job's routes, in intra-step order."""
    phases: List[CollectivePhase] = []
    for name, fld in _PHASE_FIELDS:
        base = getattr(bd, fld)
        route = routes.get(name)
        if base <= 0.0 or route is None:
            continue
        vol = cm.phase_volume(base, route)
        if vol <= 0.0:
            continue
        phases.append(CollectivePhase(name, base, route, vol))
    return tuple(phases)


@dataclass
class TrainActor:
    """A training job as a co-residency event source: every ``step()``
    prices one training step at the actor's clock, registering each
    fabric-crossing phase on the shared transport (labeled
    ``train:<name>``) and absorbing whatever contention stretch the
    in-flight serving/collective traffic inflicts.  Drop-in peer of a
    serving ``Engine`` for ``colo.driver.run_colo``: exposes ``clock``,
    ``idle``, ``step() -> dt``, ``advance_clock``."""
    name: str
    breakdown: StepBreakdown
    transport: object                    # fabric.Transport (duck-typed)
    routes: Dict[str, Route]
    n_steps: int
    clock: float = 0.0
    steps_done: int = 0
    stretch_s: float = 0.0               # contention-induced excess
    step_times: List[float] = field(default_factory=list)

    def __post_init__(self) -> None:
        self.phases = plan_phases(self.breakdown, self.routes)
        self._label = f"train:{self.name}"

    @property
    def idle(self) -> bool:
        return self.steps_done >= self.n_steps

    def advance_clock(self, t: float) -> None:
        self.clock = max(self.clock, t)

    def step(self) -> float:
        """One training step at the actor's clock.  Returns modeled
        seconds: the closed-form step time plus the contention stretch
        of each routed phase (0.0 exactly when the fabric is quiet).

        The fabric phases are priced at the *head* of the step window
        (the non-fabric compute/TP/bubble remainder follows them): the
        driver schedules the actor when its clock is the estate's
        minimum, so begin times at the head land among the serving
        flows its peers have in flight — pricing at the tail would date
        every begin past traffic the co-resident engines already
        charged into their own clocks, and the step would never observe
        the contention it causes."""
        t = self.clock
        dt = self.breakdown.total
        for p in self.phases:
            phase_s = cm.routed_phase_time(self.transport, p.route,
                                           p.base_s, t, label=self._label)
            stretch = phase_s - p.base_s
            dt += stretch
            self.stretch_s += stretch
            t += phase_s
        self.clock += dt
        self.steps_done += 1
        self.step_times.append(dt)
        return dt

    # ---- observability ---------------------------------------------------
    def stats(self) -> Dict[str, float]:
        done = max(1, self.steps_done)
        return {
            "steps": self.steps_done,
            "clock_s": self.clock,
            "step_s_avg": sum(self.step_times) / done,
            "step_s_max": max(self.step_times, default=0.0),
            "base_step_s": self.breakdown.total,
            "stretch_s": self.stretch_s,
            "phases": {p.name: {"base_s": p.base_s, "bytes": p.volume,
                                "route": f"{p.route.src}->{p.route.dst}"}
                       for p in self.phases},
        }

"""Clock-interleaved co-residency driver: training step events and
serving engines on ONE shared modeled clock and ONE shared transport.

``run_colo`` generalizes ``serve.trace.run_multi_trace``: each round
the event source with the earliest next event steps once — a serving
engine decodes/pages, a ``colo.TrainActor`` prices one training step —
so their transfers interleave causally on the shared ``Transport`` and
max-min share its links.

Equivalence contracts (pinned by ``tests/test_colo.py``):

* serving engines occupy candidate indices ``0..n-1`` in pair order —
  exactly ``run_multi_trace``'s ordering — and the per-round selection
  logic is identical, so a run with no training actors is bit-identical
  (tokens AND clocks) to ``run_multi_trace`` on the same pairs;
* a training actor always makes modeled progress (a step is never
  zero seconds), so it participates in the blocked-set protocol only
  by clearing it, never by joining it;
* with no serving pairs the driver just steps each actor to
  completion — bit-identical to calling ``actor.step()`` in a loop,
  which on a quiet fabric is bit-identical to
  ``simulate_step(...).total`` per step.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.analysis import tiebreak
from repro.colo.collectives import TrainActor
from repro.serve.engine import RequestHandle

Pair = Tuple[object, Sequence]          # (Engine, trace of Requests)


@dataclass
class ColoResult:
    """One co-resident run: serving handle lists (in pair order) plus
    the training actors with their per-step accounting."""
    serve_handles: List[List[RequestHandle]]
    train: List[TrainActor]

    def train_stats(self) -> Dict[str, Dict[str, float]]:
        return {a.name: a.stats() for a in self.train}


def run_colo(pairs: Sequence[Pair], train: Sequence[TrainActor] = (), *,
             max_steps: int = 1_000_000) -> ColoResult:
    """Drive serving engines (per-engine arrival traces) and training
    actors interleaved by modeled clock on their shared transport.

    Candidate order: serving pairs at indices ``0..n-1`` (identical to
    ``run_multi_trace``), training actors appended after — on equal
    clocks serving steps first, deterministically.  A serving engine
    whose step makes no modeled progress (blocked on pages another
    tenant holds) is clock-synced to the next other event and skipped
    until someone progresses; training steps always progress, so a
    co-resident estate deadlocks only if every *serving* engine is
    blocked with no training left to run.
    """
    state = [[eng, sorted(tr, key=lambda r: r.arrival_time), 0, []]
             for eng, tr in pairs]
    n_serve = len(state)
    actors = list(train)
    blocked: set = set()
    for _ in range(max_steps):
        for st in state:
            eng, pend = st[0], st[1]
            while st[2] < len(pend) \
                    and pend[st[2]].arrival_time <= eng.clock:
                st[3].append(eng.submit(pend[st[2]]))
                st[2] += 1
        cands = []
        for j, (eng, pend, i, _) in enumerate(state):
            if not eng.idle:
                cands.append((eng.clock, j))
            elif i < len(pend):
                cands.append((pend[i].arrival_time, j))
        for k, actor in enumerate(actors):
            if not actor.idle:
                cands.append((actor.clock, n_serve + k))
        if not cands:
            return ColoResult([st[3] for st in state], actors)
        live = [c for c in cands if c[1] not in blocked]
        if not live:
            raise RuntimeError(
                "co-residency deadlock: every engine is blocked on pages "
                "another tenant holds and no training remains")
        # total-order selection over (clock, candidate index): equal
        # clocks break serve-before-train by index (spec, not incident)
        # — the racecheck seam permutes the list to prove the selection
        # never depends on construction order
        t, j = min(tiebreak.order(live))
        if j >= n_serve:
            actors[j - n_serve].step()      # always makes progress
            blocked.clear()
            continue
        eng, pend = state[j][0], state[j][1]
        if eng.idle:
            eng.advance_clock(t)
            while state[j][2] < len(pend) \
                    and pend[state[j][2]].arrival_time <= eng.clock:
                state[j][3].append(eng.submit(pend[state[j][2]]))
                state[j][2] += 1
        before = eng.clock
        dt = eng.step()
        if dt > 0.0 or eng.idle or eng.clock != before:  # repro: allow(no-float-equality) identity test — did step() assign a new clock value at all, not a time comparison
            blocked.clear()
        else:
            others = [c[0] for c in cands if c[1] != j]
            if others:
                eng.advance_clock(min(others))
            blocked.add(j)
    raise RuntimeError(f"co-resident workloads not drained after "
                       f"{max_steps} steps")

"""Contended transfer pricing over a routed ``Topology``.

``Transport`` is the ONE place modeled transfer seconds come from: it
tracks every in-flight transfer on the fabric and prices each by
*interval-based max-min fair sharing* of link bandwidth.  Between
events (a transfer starting or finishing) every flow drains at its
max-min fair rate — on each link, unfrozen flows split the residual
capacity evenly; the most-contended link freezes its flows first
(progressive filling / water-filling, the standard fluid flow model).
When a transfer starts or finishes, everything sharing a link with it
is re-rated.

``begin_transfer(route, nbytes, t) -> completion_time`` registers the
transfer and returns its completion under the *current* in-flight set
(future arrivals will slow flows further; like any online model the
returned time is the best estimate at begin time — by construction it
is exact whenever nothing else arrives, and a lower bound otherwise).

Two guarantees the rest of the repo builds on:

* **solo exactness** — a transfer whose route carries no other flow
  completes in exactly ``route.latency() + nbytes /
  route.bottleneck_bw`` seconds, the same float the legacy
  ``ServeCostModel.swap_s`` computed, so single-tenant degenerate
  runs are bit-identical to the pre-``repro.fabric`` engine;
* **no free lunch** — k concurrent transfers over a shared link each
  finish no earlier than the serial solo transfer (fair sharing never
  exceeds link capacity); the property suite in
  ``tests/test_fabric_transport.py`` pins both.

The transport owns a modeled clock frontier (``now``): transfers
beginning in another consumer's past (engines interleave on their own
clocks) are clamped forward to it, keeping link state causal.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.fabric.topology import Link, Route, Topology

# a flow whose residue dips below this is finished: absorbs the float
# dust of ``(now + rem/rate) - now`` round trips (up to ~rate * ulp(now)
# bytes) so back-to-back sequential transfers take the exact solo fast
# path instead of "contending" with a ghost holding micro-bytes.  A
# thousandth of a byte at fabric rates is ~1e-12 modeled seconds.
_EPS_BYTES = 1e-3


@dataclass
class _Flow:
    fid: int
    route: Route
    remaining: float                  # payload bytes left to serialize
    started: float
    completion: Optional[float] = None   # estimate returned at begin time


class Transport:
    """Owns the in-flight transfer set (and the modeled clock frontier)
    for one fabric ``Topology``."""

    def __init__(self, topology: Topology):
        self.topology = topology
        self.now = 0.0                  # clock frontier (last event time)
        self._flows: Dict[int, _Flow] = {}
        self._fid = itertools.count()
        # observability
        self.transfers = 0
        self.bytes_moved = 0.0
        self.peak_inflight = 0
        self.contended_transfers = 0    # began while sharing >= 1 link

    # ---- public API ------------------------------------------------------
    def route(self, src: str, dst: str) -> Route:
        return self.topology.route(src, dst)

    def begin_transfer(self, route: Route, nbytes: float,
                       t: Optional[float] = None) -> float:
        """Start a transfer of ``nbytes`` payload bytes at modeled time
        ``t`` (>= the frontier; earlier begins are clamped forward).
        Returns the modeled completion time.  In-flight transfers
        sharing any link are re-rated from ``t`` on."""
        return self._begin(route, nbytes, t)[0]

    def transfer_s(self, route: Route, nbytes: float,
                   t: Optional[float] = None) -> float:
        """``begin_transfer`` returning the *duration* as seen from the
        requested begin time.  A begin dated before the frontier waits
        for it (causality), and that wait is part of the returned
        duration — so a consumer charging sequential transfers on its
        own (possibly lagging) clock starts each one after the last
        completed instead of stacking them onto one frontier instant
        and contending with itself.  On the solo path the duration is
        the exact ``latency + nbytes/bw`` float (no ``(t + d) - t``
        rounding), so callers accumulating step deltas stay
        bit-identical to the pre-transport cost models."""
        t_req = self.now if t is None else float(t)
        completion, solo, t_eff = self._begin(route, nbytes, t_req)
        if solo and nbytes > 0 and t_eff == t_req:
            return route.latency() + nbytes / route.bottleneck_bw
        return completion - t_req

    def _begin(self, route: Route, nbytes: float,
               t: Optional[float]) -> Tuple[float, bool, float]:
        """Shared begin path: (completion, was_solo, effective_begin)."""
        t = self.now if t is None else max(float(t), self.now)
        self._advance(t)
        self.transfers += 1
        self.bytes_moved += max(0.0, nbytes)
        if nbytes <= 0:
            return t + route.latency(), True, t
        solo = not any(self._on_link(l) for l in route.links)
        flow = _Flow(next(self._fid), route, float(nbytes), t)
        self._flows[flow.fid] = flow
        self.peak_inflight = max(self.peak_inflight, len(self._flows))
        if solo:
            # exact solo formula — bit-identical to the legacy
            # ServeCostModel.swap_s path (and to Route.transfer_time)
            flow.completion = t + (route.latency()
                                   + nbytes / route.bottleneck_bw)
        else:
            self.contended_transfers += 1
            flow.completion = self._project_completion(flow.fid) \
                + route.latency()
        return flow.completion, solo, t

    @property
    def inflight(self) -> int:
        return len(self._flows)

    def link_flows(self, link_name: str) -> int:
        """In-flight transfers currently crossing ``link_name``."""
        link = self.topology.links[link_name]
        return sum(1 for f in self._flows.values() if link in f.route.links)

    def stats(self) -> Dict[str, float]:
        return {
            "now_s": self.now,
            "transfers": self.transfers,
            "bytes_moved": self.bytes_moved,
            "inflight": len(self._flows),
            "peak_inflight": self.peak_inflight,
            "contended_transfers": self.contended_transfers,
        }

    # ---- fluid simulation ------------------------------------------------
    def _on_link(self, link: Link) -> bool:
        return any(link in f.route.links for f in self._flows.values())

    def _rates(self, remaining: Dict[int, float]) -> Dict[int, float]:
        """Max-min fair rate per flow (progressive filling): repeatedly
        find the most-contended link, freeze its flows at the equal
        split of its residual capacity, remove them, repeat."""
        rates: Dict[int, float] = {}
        live = set(remaining)
        residual = {name: l.capacity for name, l in self.topology.links.items()}
        members: Dict[str, List[int]] = {}
        for fid in sorted(live):
            for l in self._flows[fid].route.links:
                members.setdefault(l.name, []).append(fid)
        while live:
            # bottleneck link: smallest equal share among links with
            # unfrozen flows (ties broken by link name: deterministic)
            best: Optional[Tuple[float, str]] = None
            for name, fids in members.items():
                unfrozen = [f for f in fids if f in live]
                if not unfrozen:
                    continue
                share = residual[name] / len(unfrozen)
                if best is None or (share, name) < best:
                    best = (share, name)
            if best is None:        # flows with no shared-capacity links
                for fid in live:
                    rates[fid] = self._flows[fid].route.bottleneck_bw
                break
            share, name = best
            for fid in [f for f in members[name] if f in live]:
                rates[fid] = share
                live.discard(fid)
                for l in self._flows[fid].route.links:
                    residual[l.name] -= share
            residual = {k: max(0.0, v) for k, v in residual.items()}
        return rates

    def _drain_interval(self, remaining: Dict[int, float], now: float,
                        cap: Optional[float] = None
                        ) -> Tuple[float, List[int]]:
        """One fluid interval shared by ``_advance`` and
        ``_project_completion``: drain ``remaining`` in place from
        ``now`` to the earlier of ``cap`` and the earliest finish
        event, at current max-min rates.  Returns ``(horizon, finished
        fids)``.  A flow whose computed finish time sets (or precedes)
        the horizon is finished *by that event*, not by its float
        residue — ``(now + rem/rate) - now`` round-trips are not
        exact — with the residue epsilon as a backstop."""
        rates = self._rates(remaining)
        fts = {fid: now + rem / rates[fid]
               for fid, rem in remaining.items()
               if rates.get(fid, 0.0) > 0}
        if not fts and cap is None:
            raise RuntimeError("transport: in-flight set cannot drain "
                               "(zero-rate flow)")
        horizon = min(fts.values()) if fts else cap
        if cap is not None:
            horizon = min(horizon, cap)
        dt = horizon - now
        finished: List[int] = []
        for fid in list(remaining):
            remaining[fid] -= rates.get(fid, 0.0) * dt
            if fts.get(fid, float("inf")) <= horizon \
                    or remaining[fid] <= _EPS_BYTES:
                finished.append(fid)
        return horizon, finished

    def _advance(self, t: float) -> None:
        """Drain every in-flight flow from the frontier to ``t``,
        re-rating at each completion event in between."""
        while self.now < t and self._flows:
            remaining = {fid: f.remaining for fid, f in self._flows.items()}
            horizon, finished = self._drain_interval(remaining, self.now,
                                                     cap=t)
            for fid, rem in remaining.items():
                self._flows[fid].remaining = rem
            for fid in finished:
                del self._flows[fid]
            self.now = horizon
        self.now = max(self.now, t)

    def _project_completion(self, target: int) -> float:
        """Forward-simulate the current in-flight set (no future
        arrivals) until ``target`` drains; pure projection — real state
        is only advanced by ``_advance`` as begin times arrive."""
        remaining = {fid: f.remaining for fid, f in self._flows.items()}
        now = self.now
        for _ in range(len(remaining) + 1):
            horizon, finished = self._drain_interval(remaining, now)
            if target in finished:
                return horizon
            for fid in finished:
                del remaining[fid]
            now = horizon
        raise RuntimeError("transport projection failed to converge")

"""Contended transfer pricing over a routed ``Topology``.

``Transport`` is the ONE place modeled transfer seconds come from: it
tracks every in-flight transfer on the fabric and prices each by
*interval-based max-min fair sharing* of link bandwidth.  Between
events (a transfer starting or finishing) every flow drains at its
max-min fair rate — on each link, unfrozen flows split the residual
capacity evenly; the most-contended link freezes its flows first
(progressive filling / water-filling, the standard fluid flow model).
When a transfer starts or finishes, everything sharing a link with it
is re-rated.

``begin_transfer(route, nbytes, t) -> completion_time`` registers the
transfer and returns its completion under the *current* in-flight set
(future arrivals will slow flows further; like any online model the
returned time is the best estimate at begin time — by construction it
is exact whenever nothing else arrives, and a lower bound otherwise).

Two guarantees the rest of the repo builds on:

* **solo exactness** — a transfer whose route carries no other flow
  completes in exactly ``route.latency() + nbytes /
  route.bottleneck_bw`` seconds, the same float the legacy
  ``ServeCostModel.swap_s`` computed, so single-tenant degenerate
  runs are bit-identical to the pre-``repro.fabric`` engine;
* **no free lunch** — k concurrent transfers over a shared link each
  finish no earlier than the serial solo transfer (fair sharing never
  exceeds link capacity); the property suite in
  ``tests/test_fabric_transport.py`` pins both.

The transport owns a modeled clock frontier (``now``): transfers
beginning in another consumer's past (engines interleave on their own
clocks) are clamped forward to it, keeping link state causal.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis import tiebreak
from repro.fabric.topology import Link, Route, Topology
from repro.obs.export import link_tier
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import CAT_FABRIC, CAT_LINK, Tracer, resolve

# a flow whose residue dips below this is finished: absorbs the float
# dust of ``(now + rem/rate) - now`` round trips (up to ~rate * ulp(now)
# bytes) so back-to-back sequential transfers take the exact solo fast
# path instead of "contending" with a ghost holding micro-bytes.  A
# thousandth of a byte at fabric rates is ~1e-12 modeled seconds.
_EPS_BYTES = 1e-3


@dataclass
class _Flow:
    fid: int
    route: Route
    remaining: float                  # payload bytes left to serialize
    started: float
    nbytes: float = 0.0               # original payload size
    completion: Optional[float] = None   # estimate returned at begin time
    label: Optional[str] = None       # "<class>:<owner>" attribution tag
    rates: List[Tuple[float, float]] = field(default_factory=list)
    # (t, bytes/s) at each re-rating interval — recorded only when a
    # tracer is enabled; exported on the transfer's link-occupancy span


class Transport:
    """Owns the in-flight transfer set (and the modeled clock frontier)
    for one fabric ``Topology``.  Pass a ``repro.obs.Tracer`` to record
    per-transfer link-occupancy spans (with the max-min fair rate at
    every re-rating interval) into the flight recorder; per-link busy
    seconds / bytes / peak-concurrency / queueing-stretch gauges are
    always accumulated (plain float adds on the paths the fluid
    simulation already walks)."""

    def __init__(self, topology: Topology, *,
                 tracer: Optional[Tracer] = None):
        self.topology = topology
        self.tracer = resolve(tracer)
        self.now = 0.0                  # clock frontier (last event time)
        self._flows: Dict[int, _Flow] = {}
        self._fid = itertools.count()
        # observability
        self.transfers = 0
        self.bytes_moved = 0.0
        self.peak_inflight = 0
        self.contended_transfers = 0    # began while sharing >= 1 link
        # per-link accounting (bugfix: stats() used to drop link
        # information entirely, making conservation uncheckable):
        #   busy_s      — modeled seconds the link carried >= 1 flow
        #   bytes       — payload bytes serialized across the link
        #   peak_flows  — max concurrent flows ever crossing it
        #   stretch_s   — contention-induced excess (actual minus solo
        #                 serialization) of flows that crossed it
        self.link_busy_s: Dict[str, float] = {}
        self.link_bytes: Dict[str, float] = {}
        self.link_peak_flows: Dict[str, int] = {}
        self.link_stretch_s: Dict[str, float] = {}
        # per-link payload bytes keyed by flow label ("serve:a",
        # "train:job0", "kv:a", ...) — who occupied the link, not just
        # how much.  Label classes are conventions, not pricing: the
        # "kv:<tenant>" class marks disaggregated prefill->decode page
        # streams (repro.disagg) so link occupancy separates handoff
        # traffic from the same tenant's "serve:" spill traffic.
        # Only labeled flows accrue here; unlabeled traffic keeps the
        # exact legacy accounting and emits byte-identical spans.
        self.link_label_bytes: Dict[str, Dict[str, float]] = {}

    # ---- public API ------------------------------------------------------
    def route(self, src: str, dst: str) -> Route:
        return self.topology.route(src, dst)

    def begin_transfer(self, route: Route, nbytes: float,
                       t: Optional[float] = None, *,
                       label: Optional[str] = None) -> float:
        """Start a transfer of ``nbytes`` payload bytes at modeled time
        ``t`` (>= the frontier; earlier begins are clamped forward).
        Returns the modeled completion time.  In-flight transfers
        sharing any link are re-rated from ``t`` on.  ``label`` tags
        the flow for per-tenant/per-job link attribution (convention:
        ``"<class>:<owner>"``, e.g. ``"serve:a"``, ``"train:job0"``)."""
        return self._begin(route, nbytes, t, label=label)[0]

    def transfer_s(self, route: Route, nbytes: float,
                   t: Optional[float] = None, *,
                   label: Optional[str] = None) -> float:
        """``begin_transfer`` returning the *duration* as seen from the
        requested begin time.  A begin dated before the frontier waits
        for it (causality), and that wait is part of the returned
        duration — so a consumer charging sequential transfers on its
        own (possibly lagging) clock starts each one after the last
        completed instead of stacking them onto one frontier instant
        and contending with itself.  On the solo path the duration is
        the exact ``latency + nbytes/bw`` float (no ``(t + d) - t``
        rounding), so callers accumulating step deltas stay
        bit-identical to the pre-transport cost models."""
        t_req = self.now if t is None else float(t)
        completion, solo, t_eff = self._begin(route, nbytes, t_req,
                                              label=label)
        if solo and nbytes > 0 and t_eff == t_req:  # repro: allow(no-float-equality) identity test of an unclamped begin time, not a tolerance compare — t_eff IS t_req unless max() replaced it
            return route.latency() + nbytes / route.bottleneck_bw
        return completion - t_req

    def _begin(self, route: Route, nbytes: float,
               t: Optional[float], *,
               label: Optional[str] = None) -> Tuple[float, bool, float]:
        """Shared begin path: (completion, was_solo, effective_begin)."""
        t = self.now if t is None else max(float(t), self.now)
        self._advance(t)
        self.transfers += 1
        self.bytes_moved += max(0.0, nbytes)
        if nbytes <= 0:
            return t + route.latency(), True, t
        solo = not any(self._on_link(l) for l in route.links)
        flow = _Flow(next(self._fid), route, float(nbytes), t,
                     nbytes=float(nbytes), label=label)
        self._flows[flow.fid] = flow
        self.peak_inflight = max(self.peak_inflight, len(self._flows))
        for link in route.links:
            n_on = sum(1 for f in self._flows.values()  # repro: allow(no-unordered-iteration) integer count — exact and commutative in any order
                       if link in f.route.links)
            if n_on > self.link_peak_flows.get(link.name, 0):
                self.link_peak_flows[link.name] = n_on
        if solo:
            # exact solo formula — bit-identical to the legacy
            # ServeCostModel.swap_s path (and to Route.transfer_time)
            flow.completion = t + (route.latency()
                                   + nbytes / route.bottleneck_bw)
        else:
            self.contended_transfers += 1
            flow.completion = self._project_completion(flow.fid) \
                + route.latency()
        if self.tracer.enabled:
            rate0 = self._rates({fid: f.remaining for fid, f
                                 in self._flows.items()})[flow.fid]  # repro: allow(no-unordered-iteration) per-key dict build — no cross-key effects
            flow.rates.append((t, rate0))
            self.tracer.instant(
                "fabric", "begin_transfer", t, cat=CAT_FABRIC,
                fid=flow.fid, bytes=flow.nbytes, src=route.src,
                dst=route.dst, solo=solo, rate=rate0,
                est_completion=flow.completion)
        return flow.completion, solo, t

    @property
    def inflight(self) -> int:
        return len(self._flows)

    def link_flows(self, link_name: str) -> int:
        """In-flight transfers currently crossing ``link_name``."""
        link = self.topology.links[link_name]
        return sum(1 for f in self._flows.values() if link in f.route.links)  # repro: allow(no-unordered-iteration) integer count — exact and commutative in any order

    def quiesce(self) -> float:
        """Advance the frontier until every in-flight flow has drained
        (no new arrivals assumed) and return the final ``now``.  Call
        before reading per-link accounting for a whole run: transfers
        only *actually* drain as later begins advance the clock, so the
        last transfers' busy seconds are otherwise still pending."""
        while self._flows:
            remaining = {fid: f.remaining for fid, f in self._flows.items()}  # repro: allow(no-unordered-iteration) per-key dict build — no cross-key effects
            horizon, _, _ = self._drain_interval(remaining, self.now)
            self._advance(horizon)
        return self.now

    def metrics(self, registry: Optional[MetricsRegistry] = None,
                prefix: str = "fabric") -> MetricsRegistry:
        """The transport's observable state under the unified
        ``repro.obs`` schema; ``stats()`` is a thin adapter over this."""
        m = registry if registry is not None else MetricsRegistry()
        m.set(f"{prefix}/now_s", self.now)
        m.set(f"{prefix}/transfers", self.transfers)
        m.set(f"{prefix}/bytes_moved", self.bytes_moved)
        m.set(f"{prefix}/inflight", len(self._flows))
        m.set(f"{prefix}/peak_inflight", self.peak_inflight)
        m.set(f"{prefix}/contended_transfers", self.contended_transfers)
        for name in sorted(self.topology.links):
            lp = f"{prefix}/link/{name}"
            m.set(f"{lp}/busy_s", self.link_busy_s.get(name, 0.0))
            m.set(f"{lp}/bytes", self.link_bytes.get(name, 0.0))
            m.set(f"{lp}/peak_flows", self.link_peak_flows.get(name, 0))
            m.set(f"{lp}/stretch_s", self.link_stretch_s.get(name, 0.0))
        return m

    _STATS_KEYS = ("now_s", "transfers", "bytes_moved", "inflight",
                   "peak_inflight", "contended_transfers")
    _LINK_KEYS = ("busy_s", "bytes", "peak_flows", "stretch_s")

    def stats(self) -> Dict[str, float]:
        """Legacy flat dict — a thin adapter over ``metrics()`` (old
        keys preserved) plus the per-link gauges under ``links``."""
        snap = self.metrics().snapshot()
        out: Dict[str, float] = {k: snap[f"fabric/{k}"]
                                 for k in self._STATS_KEYS}
        out["links"] = {
            name: {k: snap[f"fabric/link/{name}/{k}"]
                   for k in self._LINK_KEYS}
            for name in sorted(self.topology.links)}
        return out

    # ---- fluid simulation ------------------------------------------------
    def _on_link(self, link: Link) -> bool:
        return any(link in f.route.links for f in self._flows.values())  # repro: allow(no-unordered-iteration) boolean any() — commutative in any order

    def _rates(self, remaining: Dict[int, float]) -> Dict[int, float]:
        """Max-min fair rate per flow (progressive filling): repeatedly
        find the most-contended link, freeze its flows at the equal
        split of its residual capacity, remove them, repeat."""
        rates: Dict[int, float] = {}
        live = set(remaining)
        residual = {name: l.capacity for name, l in self.topology.links.items()}  # repro: allow(no-unordered-iteration) per-key dict build — no cross-key effects
        members: Dict[str, List[int]] = {}
        # member-list order is incidental: flows frozen on one
        # bottleneck all receive the SAME share, so the residual
        # subtractions commute bit-exactly (equal values in any
        # association) — the racecheck seam permutes the build
        for fid in tiebreak.order(sorted(live)):
            for l in self._flows[fid].route.links:
                members.setdefault(l.name, []).append(fid)
        while live:
            # bottleneck link: smallest equal share among links with
            # unfrozen flows — a TOTAL-order min over (share, name), so
            # the enumeration order of ``members`` cannot pick the
            # winner
            best: Optional[Tuple[float, str]] = None
            for name, fids in members.items():  # repro: allow(no-unordered-iteration) total-order min over (share, name) — enumeration order irrelevant
                unfrozen = [f for f in fids if f in live]
                if not unfrozen:
                    continue
                share = residual[name] / len(unfrozen)
                if best is None or (share, name) < best:
                    best = (share, name)
            if best is None:        # flows with no shared-capacity links
                for fid in live:
                    rates[fid] = self._flows[fid].route.bottleneck_bw
                break
            share, name = best
            for fid in [f for f in members[name] if f in live]:
                rates[fid] = share
                live.discard(fid)
                for l in self._flows[fid].route.links:
                    residual[l.name] -= share
            residual = {k: max(0.0, v) for k, v in residual.items()}  # repro: allow(no-unordered-iteration) per-key clamp rebuild — no cross-key effects
        return rates

    def _drain_interval(self, remaining: Dict[int, float], now: float,
                        cap: Optional[float] = None
                        ) -> Tuple[float, List[int], Dict[int, float]]:
        """One fluid interval shared by ``_advance`` and
        ``_project_completion``: drain ``remaining`` in place from
        ``now`` to the earlier of ``cap`` and the earliest finish
        event, at current max-min rates.  Returns ``(horizon, finished
        fids, rates)``.  A flow whose computed finish time sets (or
        precedes) the horizon is finished *by that event*, not by its
        float residue — ``(now + rem/rate) - now`` round-trips are not
        exact — with the residue epsilon as a backstop."""
        rates = self._rates(remaining)
        fts = {fid: now + rem / rates[fid]
               for fid, rem in remaining.items()  # repro: allow(no-unordered-iteration) per-key dict build — no cross-key effects
               if rates.get(fid, 0.0) > 0}
        if not fts and cap is None:
            raise RuntimeError("transport: in-flight set cannot drain "
                               "(zero-rate flow)")
        horizon = min(fts.values()) if fts else cap  # repro: allow(no-unordered-iteration) min() of floats — commutative in any order
        if cap is not None:
            horizon = min(horizon, cap)
        dt = horizon - now
        finished: List[int] = []
        # scan order is incidental (per-key updates only) — the seam
        # permutes it; ``finished`` is canonicalized to fid order below
        # because finish order FEEDS order-sensitive effects downstream
        # (trace span emission, float stretch accumulation)
        for fid in tiebreak.order(remaining):
            remaining[fid] -= rates.get(fid, 0.0) * dt
            if fts.get(fid, float("inf")) <= horizon \
                    or remaining[fid] <= _EPS_BYTES:
                finished.append(fid)
        finished.sort()
        return horizon, finished, rates

    def _advance(self, t: float) -> None:
        """Drain every in-flight flow from the frontier to ``t``,
        re-rating at each completion event in between.  This is the
        ONE place flows really progress, so it is also where per-link
        busy/byte accounting accrues and where a finished flow's
        link-occupancy spans hit the flight recorder (its actual
        modeled finish is known here, not at begin time)."""
        while self.now < t and self._flows:
            remaining = {fid: f.remaining for fid, f in self._flows.items()}  # repro: allow(no-unordered-iteration) per-key dict build — no cross-key effects
            horizon, finished, rates = self._drain_interval(
                remaining, self.now, cap=t)
            dt = horizon - self.now
            if dt > 0:
                self._account_interval(dt, rates)
            if self.tracer.enabled:
                for fid, rate in rates.items():  # repro: allow(no-unordered-iteration) per-flow independent appends — no cross-key effects
                    fl = self._flows[fid]
                    if not fl.rates or fl.rates[-1][1] != rate:
                        fl.rates.append((self.now, rate))
            for fid, rem in remaining.items():  # repro: allow(no-unordered-iteration) per-key write-back — no cross-key effects
                self._flows[fid].remaining = rem
            # ``finished`` is in canonical fid order (begin order):
            # trace span emission and stretch accumulation are
            # order-sensitive, so the drain scan's order must not leak
            # into them
            for fid in finished:
                self._finish_flow(self._flows.pop(fid), horizon)
            self.now = horizon
        self.now = max(self.now, t)

    def _account_interval(self, dt: float, rates: Dict[int, float]) -> None:
        """Accrue one fluid interval into the per-link gauges: a link
        is busy for the interval if any flow crosses it, and carries
        each crossing flow's drained bytes (hops pipeline, so a flow's
        payload is serialized across every link of its route)."""
        on_link: Dict[str, float] = {}
        # canonical (fid-sorted) accumulation: per-link byte totals are
        # float adds of UNEQUAL values, which do not commute bit-exactly
        # — the in-flight dict's insertion order must never pick the
        # association.  (Today insertion order IS fid order, so this is
        # an identity change that pins the invariant.)
        for fid in sorted(self._flows):
            flow = self._flows[fid]
            drained = rates.get(fid, 0.0) * dt
            for link in flow.route.links:
                on_link[link.name] = on_link.get(link.name, 0.0) + drained
                if flow.label is not None:
                    by = self.link_label_bytes.setdefault(link.name, {})
                    by[flow.label] = by.get(flow.label, 0.0) + drained
        for name, nbytes in on_link.items():  # repro: allow(no-unordered-iteration) per-key single add into each gauge — no cross-key effects
            self.link_busy_s[name] = self.link_busy_s.get(name, 0.0) + dt
            self.link_bytes[name] = self.link_bytes.get(name, 0.0) + nbytes

    def _finish_flow(self, flow: _Flow, at: float) -> None:
        """A flow fully serialized at modeled time ``at``: attribute
        its queueing stretch to every link it crossed and emit its
        link-occupancy spans."""
        dur = at - flow.started
        solo_s = flow.nbytes / flow.route.bottleneck_bw
        stretch = max(0.0, dur - solo_s)
        for link in flow.route.links:
            self.link_stretch_s[link.name] = \
                self.link_stretch_s.get(link.name, 0.0) + stretch
        if self.tracer.enabled:
            name = f"{flow.route.src}->{flow.route.dst}"
            rates = [(round(t, 9), r) for t, r in flow.rates]
            extra = {} if flow.label is None else {"label": flow.label}
            self.tracer.span(
                "fabric", name, flow.started, dur, cat=CAT_FABRIC,
                fid=flow.fid, bytes=flow.nbytes, solo_s=solo_s,
                stretch_s=stretch, hops=flow.route.hops, rates=rates,
                **extra)
            for link in flow.route.links:
                self.tracer.span(
                    f"link:{link.name}", name, flow.started, dur,
                    cat=CAT_LINK, fid=flow.fid, bytes=flow.nbytes,
                    solo_s=solo_s, capacity=link.capacity,
                    tier=link_tier(link, self.topology), **extra)

    def _project_completion(self, target: int) -> float:
        """Forward-simulate the current in-flight set (no future
        arrivals) until ``target`` drains; pure projection — real state
        is only advanced by ``_advance`` as begin times arrive."""
        remaining = {fid: f.remaining for fid, f in self._flows.items()}  # repro: allow(no-unordered-iteration) per-key dict build — no cross-key effects
        now = self.now
        for _ in range(len(remaining) + 1):
            horizon, finished, _ = self._drain_interval(remaining, now)
            if target in finished:
                return horizon
            for fid in finished:
                del remaining[fid]
            now = horizon
        raise RuntimeError("transport projection failed to converge")

"""Routed fabric topology: the graph the whole repo prices transfers on.

Until now every layer carried its own private copy of the fabric's
price list: ``ServeCostModel.swap_s`` handed each tenant the full
tier-2 bandwidth, ``pool.allocator`` reserved per-node bandwidth
scalars, and the collective models in ``core.costmodel`` saw a bare
``FabricSpec`` with no switch hierarchy.  Cross-consumer contention on
the *shared* hierarchical CXL fabric — the phenomenon the paper's
tier-2 claim lives or dies on — was structurally unrepresentable.

This module centralizes the structure once:

``Link``
    One *directed* capacity-carrying edge between two nodes (full
    duplex fabrics are two ``Link``s).  Wraps an existing
    ``core.fabric.LinkSpec`` for the PHY/flit identity and adds the
    instance quantities a router needs: effective payload capacity
    (bytes/s, flit efficiency and queuing already folded in, exactly
    ``FabricSpec.bandwidth()`` semantics) and fixed traversal latency.

``Route``
    A hop list of ``Link``s from ``Topology.route(src, dst)``.  Prices
    a *solo* transfer with ``transfer_time(nbytes)`` — the same
    contract as ``FabricSpec.transfer_time``, so a ``Route`` can be
    passed anywhere ``core.costmodel`` expects a fabric.  Contended
    pricing (several in-flight transfers fair-sharing each link) lives
    in ``repro.fabric.transport.Transport``.

``Topology``
    The node/edge graph: accelerators, XLink pods, CXL switch tiers
    (leaf / spine / the capacity-fabric switch) and tier-2 memory
    nodes.  ``Topology.from_inventory`` derives it from a
    ``pool.inventory.Inventory``; ``Topology.degenerate`` builds the
    1-link graph the legacy ``ServeCostModel`` facade runs on.

Units follow ``core.fabric``: bytes, seconds, bytes/s.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.fabric import GB, FabricSpec, LinkSpec, Protocol

# node-kind tags (informational; routing treats all nodes alike)
ACCEL = "accel"
POD = "pod"
SWITCH = "switch"
MEMORY = "memory"
ENDPOINT = "endpoint"


@dataclass(frozen=True)
class Link:
    """One directed edge of the fabric graph.

    ``capacity`` is the sustainable *payload* rate (bytes/s) the link
    can serialize — flit efficiency and queuing inflation already
    folded in, i.e. the ``FabricSpec.bandwidth()`` number, so a solo
    transfer of ``n`` bytes serializes in ``n / capacity`` seconds.
    ``latency`` is the fixed one-way traversal time (PHY + switch hop
    + any per-transfer software overhead).
    """

    name: str
    src: str
    dst: str
    spec: LinkSpec
    capacity: float             # payload bytes/s
    latency: float              # seconds per traversal

    def __post_init__(self):
        if self.capacity <= 0:
            raise ValueError(f"link {self.name}: capacity must be positive")
        if self.latency < 0:
            raise ValueError(f"link {self.name}: negative latency")


@dataclass(frozen=True)
class Route:
    """An ordered hop list of ``Link``s from one endpoint to another."""

    links: Tuple[Link, ...]

    def __post_init__(self):
        if not self.links:
            raise ValueError("empty route")
        for a, b in zip(self.links, self.links[1:]):
            if a.dst != b.src:
                raise ValueError(f"route discontinuity: {a.name} ends at "
                                 f"{a.dst!r} but {b.name} starts at {b.src!r}")

    @property
    def src(self) -> str:
        return self.links[0].src

    @property
    def dst(self) -> str:
        return self.links[-1].dst

    @property
    def hops(self) -> int:
        return len(self.links)

    @property
    def specs(self) -> Tuple[LinkSpec, ...]:
        """The underlying ``core.fabric.LinkSpec`` per hop."""
        return tuple(l.spec for l in self.links)

    def latency(self) -> float:
        """Zero-byte end-to-end latency (sum of hop latencies)."""
        return sum(l.latency for l in self.links)

    @property
    def bottleneck_bw(self) -> float:
        """Payload bytes/s of the slowest hop — the solo transfer rate
        (hops pipeline flit-by-flit, so serialization is paid once at
        the bottleneck, while latency accumulates per hop)."""
        return min(l.capacity for l in self.links)

    def transfer_time(self, nbytes: float, *, contention: float = 1.0
                      ) -> float:
        """Solo end-to-end time — the ``FabricSpec.transfer_time``
        contract, so a ``Route`` drops into ``core.costmodel``
        collectives wherever a fabric is expected.  ``contention``
        divides the bottleneck bandwidth (static flow counting); for
        *dynamic* contention between actual in-flight transfers use
        ``Transport.begin_transfer``."""
        if nbytes <= 0:
            return self.latency()
        return self.latency() + nbytes / (self.bottleneck_bw / contention)

    # alias matching FabricSpec's observability surface
    def bandwidth(self) -> float:
        """Effective end-to-end bandwidth in GB/s (FabricSpec parity)."""
        return self.bottleneck_bw / GB


class Topology:
    """The routed fabric graph.  Nodes are string ids tagged with a
    kind; links are directed.  ``connect`` adds the two directions of
    a full-duplex link as independent capacity (per-direction
    bandwidth, matching ``LinkSpec.bandwidth``'s convention)."""

    def __init__(self, name: str = "fabric"):
        self.name = name
        self.nodes: Dict[str, str] = {}            # id -> kind
        self.links: Dict[str, Link] = {}           # name -> Link
        self._adj: Dict[str, List[Link]] = {}      # src -> outgoing links
        self._route_cache: Dict[Tuple[str, str], Route] = {}

    # ---- construction ----------------------------------------------------
    def add_node(self, node: str, kind: str = ENDPOINT) -> str:
        if node in self.nodes and self.nodes[node] != kind:
            raise ValueError(f"node {node!r} already exists as "
                             f"{self.nodes[node]!r}")
        self.nodes[node] = kind
        self._adj.setdefault(node, [])
        return node

    def add_link(self, src: str, dst: str, spec: LinkSpec, *,
                 capacity: float, latency: float,
                 name: Optional[str] = None) -> Link:
        """Add one *directed* edge."""
        for n in (src, dst):
            if n not in self.nodes:
                raise KeyError(f"unknown node {n!r} (add_node first)")
        link = Link(name or f"{src}->{dst}", src, dst, spec,
                    capacity, latency)
        if link.name in self.links:
            raise ValueError(f"duplicate link {link.name!r}")
        self.links[link.name] = link
        self._adj[src].append(link)
        self._route_cache.clear()
        return link

    def connect(self, a: str, b: str, spec: LinkSpec, *,
                capacity: float, latency: float) -> Tuple[Link, Link]:
        """Full-duplex: both directions, each with its own capacity."""
        return (self.add_link(a, b, spec, capacity=capacity, latency=latency),
                self.add_link(b, a, spec, capacity=capacity, latency=latency))

    # ---- routing ---------------------------------------------------------
    def route(self, src: str, dst: str) -> Route:
        """Min-hop route (BFS; deterministic neighbor order = insertion
        order, so equal-hop ties resolve to the earliest-added links)."""
        key = (src, dst)
        cached = self._route_cache.get(key)
        if cached is not None:
            return cached
        for n in (src, dst):
            if n not in self.nodes:
                raise KeyError(f"unknown node {n!r}")
        if src == dst:
            raise ValueError(f"route {src!r} -> itself")
        prev: Dict[str, Link] = {}
        seen = {src}
        q = deque([src])
        while q:
            cur = q.popleft()
            if cur == dst:
                break
            for link in self._adj[cur]:
                if link.dst not in seen:
                    seen.add(link.dst)
                    prev[link.dst] = link
                    q.append(link.dst)
        if dst not in prev:
            raise ValueError(f"no route {src!r} -> {dst!r} in {self.name}")
        hops: List[Link] = []
        cur = dst
        while cur != src:
            link = prev[cur]
            hops.append(link)
            cur = link.src
        route = Route(tuple(reversed(hops)))
        self._route_cache[key] = route
        return route

    def nodes_of_kind(self, kind: str) -> List[str]:
        return [n for n, k in self.nodes.items() if k == kind]

    def describe(self) -> str:
        kinds: Dict[str, int] = {}
        for k in self.nodes.values():
            kinds[k] = kinds.get(k, 0) + 1
        parts = ", ".join(f"{v} {k}" for k, v in sorted(kinds.items()))
        return f"{self.name}: {parts}, {len(self.links)} directed links"

    # ---- canned shapes ---------------------------------------------------
    @classmethod
    def degenerate(cls, bandwidth: float, latency: float, *,
                   name: str = "degenerate",
                   spec: Optional[LinkSpec] = None) -> "Topology":
        """The 1-link graph (``src`` -> ``dst``) the legacy
        ``ServeCostModel`` facade runs on: a solo transfer of ``n``
        bytes takes exactly ``latency + n / bandwidth`` seconds."""
        topo = cls(name)
        topo.add_node("src", ENDPOINT)
        topo.add_node("dst", MEMORY)
        lk = spec or dataclasses.replace(
            _NULL_SPEC, name=name, bandwidth=bandwidth / GB)
        topo.connect("src", "dst", lk, capacity=bandwidth, latency=latency)
        return topo

    @classmethod
    def from_fabric_spec(cls, fabric: FabricSpec, *,
                         name: Optional[str] = None) -> "Topology":
        """Collapse a whole ``FabricSpec`` (link + topology + queuing)
        into one equivalent routed link: capacity is the spec's
        effective large-message bandwidth, latency its zero-byte
        latency — so the 1-link route's ``transfer_time`` matches
        ``FabricSpec.transfer_time`` for flit-aligned payloads."""
        return cls.degenerate(fabric.bandwidth() * GB, fabric.latency(),
                              name=name or fabric.name, spec=fabric.link)

    @classmethod
    def from_inventory(cls, inv, *, accels: bool = False,
                       tier2_trunk_bw: float = 0.0) -> "Topology":
        """Build the estate graph from a ``pool.inventory.Inventory``.

        Shape (scalepool): ``accel:<p>.<i>`` (optional) -- XLink -->
        ``pod:<p>`` -- coherence CXL --> ``leaf:<l>`` --> ``spine`` -->
        ``t2sw`` (capacity-fabric switch) --> ``mem:<k>``.  Baseline
        inventories (no tier-2 fabric) stop at the spine (IB core).

        ``tier2_trunk_bw``: capacity of the shared spine->t2sw trunk in
        bytes/s; 0 derives full bisection (sum of memory-node
        bandwidths), i.e. the trunk never binds before the nodes.  An
        ``Inventory.tier2_trunk_bw`` field, when positive, is the
        default — the knob an oversubscribed capacity fabric turns.
        """
        topo = cls(f"estate[{inv.interconnect}]")
        inter = inv.inter_fabric
        leaf_lat = inter.topology.switch.hop_latency + inter.link.phy_latency
        topo.add_node("spine", SWITCH)
        leaves = sorted({inv.leaf_of(p.id) for p in inv.pods})
        for l in leaves:
            topo.add_node(f"leaf:{l}", SWITCH)
            pods_on = [p for p in inv.pods if inv.leaf_of(p.id) == l]
            up = sum(inter.bandwidth() * GB * p.n_accels for p in pods_on)
            topo.connect(f"leaf:{l}", "spine", inter.link,
                         capacity=up / inter.topology.oversubscription,
                         latency=leaf_lat)
        for p in inv.pods:
            topo.add_node(f"pod:{p.id}", POD)
            # pod uplink into its leaf: one inter-fabric port per accel
            topo.connect(f"pod:{p.id}", f"leaf:{inv.leaf_of(p.id)}",
                         inter.link,
                         capacity=inter.bandwidth() * GB * p.n_accels,
                         latency=inter.link.sw_overhead + leaf_lat)
            if accels:
                pf = p.fabric
                for i in p.accel_ids():
                    a = topo.add_node(f"accel:{p.id}.{i}", ACCEL)
                    topo.connect(a, f"pod:{p.id}", pf.link,
                                 capacity=pf.bandwidth() * GB,
                                 latency=pf.latency())
        t2 = inv.tier2_fabric
        if t2 is not None and inv.memory_nodes:
            topo.add_node("t2sw", SWITCH)
            node_bw = [m.bandwidth or t2.bandwidth() * GB
                       for m in inv.memory_nodes]
            trunk = (tier2_trunk_bw
                     or getattr(inv, "tier2_trunk_bw", 0.0)
                     or float(sum(node_bw)))
            topo.connect("spine", "t2sw", t2.link, capacity=trunk,
                         latency=t2.topology.switch.hop_latency)
            for m, bw in zip(inv.memory_nodes, node_bw):
                topo.add_node(f"mem:{m.id}", MEMORY)
                topo.connect("t2sw", f"mem:{m.id}", t2.link,
                             capacity=bw, latency=t2.link.phy_latency)
        return topo


# placeholder PHY identity for synthetic/degenerate links (payload ==
# wire: efficiency 1.0, no software on the data path)
_NULL_SPEC = LinkSpec(name="modeled", protocol=Protocol.CXL,
                      bandwidth=1.0, phy_latency=0.0,
                      flit_bytes=1, flit_payload=1)

"""repro.fabric — routed transport over the XLink-CXL estate.

The single source of modeled transfer seconds (the API redesign that
retired the scattered per-layer cost models):

    topology  — Link / Route / Topology: the estate graph (accels,
                XLink pods, CXL switch tiers, tier-2 memory nodes)
                with min-hop routing; built from ``pool.inventory``
    transport — Transport: interval-based max-min fair sharing of
                link bandwidth among concurrently in-flight transfers

Quickstart::

    from repro.fabric import Topology, Transport
    from repro.pool import build_inventory

    topo = Topology.from_inventory(build_inventory())
    tx = Transport(topo)
    route = topo.route("pod:0", "mem:0")
    done = tx.begin_transfer(route, 64 << 20, t=0.0)   # modeled seconds

Consumers:

* ``repro.serve.Engine`` charges KV spill/fetch through a transport
  (pass ``transport=``/``route=``; defaults to a private degenerate
  1-link topology that reproduces the legacy ``ServeCostModel.swap_s``
  numbers bit-exactly);
* ``repro.pool.Allocator`` admission-controls ``tier2_bw``
  reservations against the topology's shared link capacities;
* ``repro.core.costmodel`` collectives accept a ``Route`` anywhere a
  ``FabricSpec`` is expected (``Route.transfer_time`` implements the
  same contract).
"""

from repro.fabric.topology import (ACCEL, ENDPOINT, MEMORY, POD, SWITCH,
                                   Link, Route, Topology)
from repro.fabric.transport import Transport

__all__ = [
    "ACCEL", "ENDPOINT", "MEMORY", "POD", "SWITCH",
    "Link", "Route", "Topology", "Transport",
]

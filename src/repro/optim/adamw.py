"""AdamW from scratch (no optax dependency), with:

* fp32 moments regardless of param dtype (mixed-precision training),
* optional tier-2 offload of the moments (see repro.core.tiering),
* optimizer-state sharding that follows the parameter sharding (with
  FSDP parameter layouts this is the ZeRO analogue: states live only on
  the shard that owns the parameters).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # ()
    mu: Any                  # fp32 pytree like params
    nu: Any                  # fp32 pytree like params


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def state_axes(self, param_axes) -> AdamWState:
        """Logical axes for the state pytree (moments follow params)."""
        return AdamWState(step=(), mu=param_axes, nu=param_axes)

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState, jax.Array]:
        """Returns (new_params, new_state, grad_norm)."""
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(gf)) + 1e-30)
        if self.grad_clip is not None:
            scale = jnp.minimum(1.0, self.grad_clip / gnorm)
            gf = jax.tree.map(lambda g: g * scale, gf)

        step = state.step + 1
        c1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            m = self.b1 * m + (1.0 - self.b1) * g
            v = self.b2 * v + (1.0 - self.b2) * jnp.square(g)
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            pf = p.astype(jnp.float32)
            pf = pf - self.lr * (delta + self.weight_decay * pf)
            return pf.astype(p.dtype), m, v

        out = jax.tree.map(upd, params, gf, state.mu, state.nu)
        # out is a tree of 3-tuples; split it
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step, new_mu, new_nu), gnorm

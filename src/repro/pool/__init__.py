"""repro.pool — composable resource-disaggregation orchestrator.

The software layer the paper's title promises: composes disaggregated
accelerators (XLink pods stitched by the hierarchical CXL fabric) and
tier-2 memory nodes into per-job allocations, schedules multi-job
workloads over them, and materializes grants as JAX meshes + tiering
policies for the runtime.

    inventory   — the static estate (pods, CXL tiers, memory nodes)
    allocator   — topology-aware composable allocation + pool metrics
    scheduler   — discrete-event multi-job admit/preempt/elastic engine
    lease       — allocation → concrete mesh + TieringPolicy binding
"""

from repro.pool.allocator import (Allocation, AllocationError, Allocator,
                                  FreeList, JobRequest, PoolMetrics)
from repro.pool.inventory import (Inventory, MemoryNodeSpec, PodSpec,
                                  build_inventory)
from repro.pool.lease import Lease, ResourcePool, smoke_pool
from repro.pool.scheduler import (JobRecord, PoolJob, ScheduleResult,
                                  Scheduler, offload_bw, offload_bytes)

__all__ = [
    "Allocation", "AllocationError", "Allocator", "FreeList", "Inventory",
    "JobRecord", "JobRequest", "Lease", "MemoryNodeSpec", "PodSpec",
    "PoolJob", "PoolMetrics", "ResourcePool", "ScheduleResult", "Scheduler",
    "build_inventory", "offload_bw", "offload_bytes", "smoke_pool",
]

"""Discrete-event multi-job scheduler over the composable pool.

Jobs are LLM training runs described by the same ``LLMConfig`` /
``ParallelismConfig`` pairs the §6 simulator uses; a job's execution rate
comes from ``core.simulator.simulate_step`` under the pool's interconnect
(``baseline`` IB vs ``scalepool`` CXL), so every second of simulated time
is derived from the paper's cost models — the scheduler adds only
*when* jobs run and *where* they are placed.

Mechanics: submit → FIFO queue (+ backfill) → admit via the topology-
aware allocator → finish.  Higher-priority head-of-line jobs may preempt
(newest, lowest-priority victims first, requeued with their remaining
steps); elastic jobs admit shrunk (dp halved until they fit) and grow
back toward their full data-parallel width when resources free up.

``Scheduler(queueing="drf")`` replaces FIFO+backfill with dominant-
resource fairness over ⟨accels, tier-2 bytes, tier-2 bandwidth⟩: each
admission round offers resources to the user with the smallest dominant
share, and jobs naming the same ``gang`` admit all-or-nothing (a
partially-placed gang would strand resources waiting for its peers).

Gangs may be declared with ``PoolJob.gang_size``: members submitted at
*different* timestamps are held in a pending-gang buffer until the
gang is complete, then queued together and admitted atomically (in
both queueing modes) — an early member can never admit alone.  Tier-2
bandwidth demands are admitted by the allocator against the routed
estate graph's link capacities (``repro.fabric``), so the shared
capacity-fabric trunk caps the aggregate, not just per-node scalars.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis import tiebreak
from repro.core import simulator as sim
from repro.obs.trace import CAT_SCHED, resolve
from repro.pool.allocator import Allocation, Allocator, JobRequest
from repro.pool.inventory import Inventory


@dataclass(frozen=True)
class PoolJob:
    """One training job submitted to the pool."""

    name: str
    model: sim.LLMConfig
    par: sim.ParallelismConfig
    n_steps: int
    tier2_bytes: float = 0.0
    tier2_bw: float = 0.0         # capacity-fabric bandwidth demand, bytes/s
    submit_t: float = 0.0
    priority: int = 0
    elastic: bool = False
    min_dp: int = 1
    # DRF queueing (Scheduler(queueing="drf")): jobs of one ``user``
    # share a dominant-resource fairness account; jobs naming the same
    # ``gang`` are co-scheduled all-or-nothing (submit them together).
    user: str = ""
    gang: str = ""
    # declared gang width: members submitted at *different* timestamps
    # are held in the scheduler's pending-gang buffer until this many
    # have arrived, then queued (and admitted) together.  0 = undeclared
    # (legacy: whatever is queued at one timestamp is the gang).
    gang_size: int = 0

    @property
    def n_accels(self) -> int:
        return self.par.n_gpus

    @property
    def drf_user(self) -> str:
        return self.user or self.name

    @property
    def gang_key(self) -> Tuple[str, str]:
        # RAW user, not drf_user: the drf fallback (user or name) would
        # scatter a no-user gang's members across per-job keys and hold
        # each "1/N-member gang" forever.  A gang belongs to one user;
        # all-unset is one user too.
        return (self.user, self.gang)


def offload_bytes(model: sim.LLMConfig,
                  calib: sim.Calibration) -> float:
    """Capacity-tier demand of an offloaded optimizer for ``model`` —
    the same constant the §6 step simulator charges per step."""
    return calib.optimizer_bytes_per_param * model.n_params


def offload_bw(model: sim.LLMConfig, calib: sim.Calibration,
               steps_per_sec: float) -> float:
    """Sustained capacity-fabric bandwidth (bytes/s) an offloaded
    optimizer streams: moments read + written back every step.  Feed
    this into ``PoolJob.tier2_bw`` so concurrent offload-heavy jobs
    contend on tier-2 bandwidth, not just bytes."""
    return 2.0 * offload_bytes(model, calib) * steps_per_sec


@dataclass
class JobRecord:
    """Per-job outcome of a schedule."""

    name: str
    submit_t: float
    start_t: Optional[float] = None     # first admission
    finish_t: Optional[float] = None
    preemptions: int = 0
    resizes: int = 0
    dp_granted: int = 0                 # dp at final admission
    accel_seconds: float = 0.0          # busy integral

    @property
    def jct(self) -> Optional[float]:
        return None if self.finish_t is None else self.finish_t - self.submit_t

    @property
    def queue_delay(self) -> Optional[float]:
        return None if self.start_t is None else self.start_t - self.submit_t


@dataclass
class _Running:
    job: PoolJob
    par: sim.ParallelismConfig          # possibly shrunk
    alloc: Allocation
    step_time: float
    steps_done: float
    seg_start: float                    # start of the current segment
    epoch: int                          # invalidates stale finish events


@dataclass
class ScheduleResult:
    records: Dict[str, JobRecord]
    trace: List[str]                    # deterministic event log
    makespan: float
    util_area: float                    # busy accel-seconds
    granted_area: float                 # held accel-seconds
    frag_samples: List[float]
    total_accels: int

    @property
    def utilization(self) -> float:
        denom = self.total_accels * self.makespan
        return self.util_area / denom if denom > 0 else 0.0

    @property
    def stranded_frac(self) -> float:
        denom = self.total_accels * self.makespan
        return (self.granted_area - self.util_area) / denom if denom > 0 else 0.0

    @property
    def mean_jct(self) -> float:
        jcts = [r.jct for r in self.records.values() if r.jct is not None]  # repro: allow(no-unordered-iteration) records insert in submit() call order — spec'd, not incidental
        return sum(jcts) / len(jcts) if jcts else 0.0

    @property
    def mean_queue_delay(self) -> float:
        qs = [r.queue_delay for r in self.records.values()  # repro: allow(no-unordered-iteration) records insert in submit() call order — spec'd, not incidental
              if r.queue_delay is not None]
        return sum(qs) / len(qs) if qs else 0.0

    @property
    def mean_fragmentation(self) -> float:
        return (sum(self.frag_samples) / len(self.frag_samples)
                if self.frag_samples else 0.0)

    def summary(self) -> Dict[str, float]:
        return dict(utilization=self.utilization,
                    stranded_frac=self.stranded_frac,
                    mean_jct=self.mean_jct,
                    mean_queue_delay=self.mean_queue_delay,
                    mean_fragmentation=self.mean_fragmentation,
                    makespan=self.makespan,
                    n_finished=sum(r.finish_t is not None
                                   for r in self.records.values()))  # repro: allow(no-unordered-iteration) integer count — exact and commutative in any order


class Scheduler:
    """Event-driven scheduler; fully deterministic for a fixed job list."""

    _TRACK = "pool:sched"

    def __init__(self, inventory: Inventory, policy: Optional[str] = None,
                 *, backfill: bool = True,
                 calib: Optional[sim.Calibration] = None,
                 queueing: str = "fifo", tracer=None):
        if queueing not in ("fifo", "drf"):
            raise ValueError(f"unknown queueing policy {queueing!r} "
                             f"(expected 'fifo' or 'drf')")
        self.tracer = resolve(tracer)
        self.inv = inventory
        self.alloc = Allocator(inventory, policy)
        self.policy = self.alloc.policy
        self.backfill = backfill
        self.queueing = queueing
        self.calib = calib or dataclasses.replace(
            sim.Calibration(), cluster_size=inventory.pod_size)
        self._events: List[Tuple[float, int, str, object]] = []
        self._seq = 0
        self._queue: List[PoolJob] = []
        # partial gangs (gang_size declared, not all members arrived):
        # held OUT of the admission queue so an early member can never
        # admit alone — all-or-nothing needs the whole gang visible
        self._pending_gangs: Dict[Tuple[str, str], List[PoolJob]] = {}
        self._running: Dict[str, _Running] = {}
        self.records: Dict[str, JobRecord] = {}
        self.trace: List[str] = []
        self._now = 0.0
        self._last_t = 0.0
        self._util_area = 0.0
        self._granted_area = 0.0
        self._frag_samples: List[float] = []
        self._step_cache: Dict[Tuple, float] = {}
        self._geom_emitted = False

    # ---- public API ------------------------------------------------------
    def submit(self, job: PoolJob) -> None:
        self._push(job.submit_t, "submit", job)
        self.records[job.name] = JobRecord(job.name, job.submit_t)

    def run(self, until: float = math.inf) -> ScheduleResult:
        if self.tracer.enabled and not self._geom_emitted:
            # pool geometry, once: the conservation baseline the
            # repro.analysis sanitizer checks accel counters against
            self._geom_emitted = True
            self.tracer.instant(self._TRACK, "sched_pool", self._now,
                                cat=CAT_SCHED,
                                accels=self.inv.total_accels)
        while self._events:
            if self._events[0][0] > until:
                break   # leave the event for a later run() call
            t, seq, kind, data = heapq.heappop(self._events)
            self._advance(t)
            # drain every event sharing this timestamp BEFORE admitting:
            # co-submitted jobs (a DRF gang in particular) must be
            # visible to one admission round together, or the first
            # member admits alone and all-or-nothing is vacuous
            batch = [(seq, kind, data)]
            while self._events and self._events[0][0] == t:  # repro: allow(no-float-equality) heap keys are stored floats compared by identity — equality DEFINES the same-timestamp batch
                _, seq, kind, data = heapq.heappop(self._events)
                batch.append((seq, kind, data))
            # canonical handling order for one timestamp: submits FIFO
            # by submission sequence (spec), then finishes by sequence.
            # Heap pop order within a timestamp is thereby provably
            # irrelevant — the racecheck seam permutes the batch and the
            # sort restores the canonical order bit-exactly
            for _, kind, data in sorted(tiebreak.order(batch),
                                        key=lambda e: (e[1] != "submit",
                                                       e[0])):
                self._handle(kind, data)
            self._admit_and_grow()
        # partial horizon: accrue the tail window [last_event, until) —
        # without this, util_area/granted_area/makespan stop at the last
        # *processed* event and utilization over the horizon is overstated
        # (jobs straddling ``until`` contribute nothing past it).  With
        # work left (pending events or running jobs) the horizon is
        # ``until``; an already-drained schedule keeps its natural end.
        if math.isfinite(until) and (self._events or self._running):
            self._advance(until)
        for (user, gang), buf in sorted(self._pending_gangs.items()):
            want = max(j.gang_size for j in buf)
            self._log(f"WARNING gang {gang!r} incomplete at end of run: "
                      f"{len(buf)}/{want} members held, never admitted")
        return ScheduleResult(
            records=self.records, trace=self.trace, makespan=self._now,
            util_area=self._util_area, granted_area=self._granted_area,
            frag_samples=self._frag_samples,
            total_accels=self.inv.total_accels)

    # ---- internals -------------------------------------------------------
    def _handle(self, kind: str, data) -> None:
        if kind == "submit":
            self._log(f"submit {data.name} "
                      f"(n={data.n_accels}, t2={data.tier2_bytes/1e9:.0f}GB)")
            if self.tracer.enabled:
                self.tracer.instant(self._TRACK, "submit", self._now,
                                    cat=CAT_SCHED, job=data.name,
                                    accels=data.n_accels,
                                    tier2_bytes=data.tier2_bytes)
            if data.gang:
                held = self._pending_gangs.get(data.gang_key)
                if held is not None and data.gang_size != held[0].gang_size:
                    # a mixed declaration either splits the gang (an
                    # undeclared member admits alone) or strands it (a
                    # too-big size never completes) — both silently
                    raise ValueError(
                        f"{data.name}: gang {data.gang!r} declared with "
                        f"gang_size={held[0].gang_size} but this member "
                        f"says {data.gang_size} — every member of a "
                        f"gang must declare the same size")
            if data.gang and data.gang_size > 1:
                buf = self._pending_gangs.setdefault(data.gang_key, [])
                buf.append(data)
                want = buf[0].gang_size
                if len(buf) < want:
                    self._log(f"hold {data.name} "
                              f"(gang {data.gang!r} {len(buf)}/{want})")
                    if self.tracer.enabled:
                        self.tracer.instant(self._TRACK, "hold", self._now,
                                            cat=CAT_SCHED, job=data.name,
                                            gang=data.gang,
                                            arrived=len(buf), want=want)
                    return
                del self._pending_gangs[data.gang_key]
                self._queue.extend(buf)
                self._log(f"gang {data.gang!r} complete "
                          f"({len(buf)} jobs) -> queue")
                if self.tracer.enabled:
                    self.tracer.instant(self._TRACK, "gang_complete",
                                        self._now, cat=CAT_SCHED,
                                        gang=data.gang, members=len(buf))
                return
            self._queue.append(data)
        elif kind == "finish":
            name, epoch = data
            run = self._running.get(name)
            if run is None or run.epoch != epoch:
                return      # stale: job was preempted/resized
            self._finish(run)

    def _push(self, t: float, kind: str, data) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, kind, data))

    def _log(self, msg: str) -> None:
        self.trace.append(f"t={self._now:.2f} {msg}")

    def _advance(self, t: float) -> None:
        dt = t - self._last_t
        if dt > 0:
            busy = sum(r.alloc.n_requested for r in self._running.values())  # repro: allow(no-unordered-iteration) integer sum — exact and commutative in any order
            granted = sum(r.alloc.n_granted for r in self._running.values())  # repro: allow(no-unordered-iteration) integer sum — exact and commutative in any order
            self._util_area += busy * dt
            self._granted_area += granted * dt
            self._last_t = t
        self._now = t

    def step_time(self, job: PoolJob, par: sim.ParallelismConfig,
                  alloc: Allocation) -> float:
        """Seconds per training step under the pool's interconnect, from
        the §6 cost models.  The inter-cluster fabric a job sees is sized
        to its own placement span (a 1-pod job never pays multi-level
        CXL/IB switching; a wide job does)."""
        span_endpoints = max(self.inv.pod_size,
                             alloc.n_pods * self.inv.pod_size)
        offloads = job.tier2_bytes > 0
        # contention-aware placement changes WHERE a gang lands, not the
        # fabric it trains over: step costs price on the scalepool system
        sys_kind = "scalepool" if self.policy == "contention" else self.policy
        key = (job.model.name, par.tp, par.pp, par.dp,
               par.global_batch_seqs, par.microbatch_seqs, par.vpp,
               sys_kind, span_endpoints, offloads)
        if key not in self._step_cache:
            system = sim.make_system(sys_kind, span_endpoints, self.calib)
            bd = sim.simulate_step(job.model, par, system)
            # jobs without a capacity reservation run no offload traffic;
            # charging them the (policy-dependent) offload path would leak
            # a difference that is not about resource composition.
            self._step_cache[key] = bd.total - (0.0 if offloads else bd.offload)
        return self._step_cache[key]

    # ---- admission -------------------------------------------------------
    def _request(self, job: PoolJob, par: sim.ParallelismConfig) -> JobRequest:
        return JobRequest(job.name, par.tp * par.pp * par.dp, job.tier2_bytes,
                          tier2_bw=job.tier2_bw)

    def _try_admit(self, job: PoolJob) -> bool:
        """Full size, then elastic shrink (dp halving) if allowed."""
        dp = job.par.dp
        while dp >= max(1, job.min_dp):
            par = dataclasses.replace(job.par, dp=dp)
            alloc = self.alloc.allocate(self._request(job, par))
            if alloc is not None:
                self._start(job, par, alloc)
                return True
            if not job.elastic or dp == job.min_dp:
                return False
            dp = max(job.min_dp, dp // 2)
        return False

    def _try_admit_with_preemption(self, job: PoolJob) -> bool:
        """Head-of-line high-priority admission: preempt newest lowest-
        priority victims until the job fits (all-or-nothing).  Members
        of a declared gang are not preemptable — yanking one would
        leave its peers running, breaking the gang's all-or-nothing
        placement (gang-wide preemption is a follow-up)."""
        victims = sorted(
            (r for r in self._running.values()
             if r.job.priority < job.priority
             and not (r.job.gang and r.job.gang_size > 1)),
            key=lambda r: (r.job.priority, -r.seg_start, r.job.name))
        if not victims:
            return False
        snapshot = self.alloc.snapshot()
        preempted: List[_Running] = []
        ok = False
        for v in victims:
            self._suspend(v)
            preempted.append(v)
            alloc = self.alloc.allocate(self._request(job, job.par))
            if alloc is not None:
                self._start(job, job.par, alloc)
                ok = True
                break
        if not ok:
            # restore: nobody should have been harmed
            self.alloc.restore(snapshot)
            for v in preempted:
                self._running[v.job.name] = v
            return False
        for v in preempted:
            rec = self.records[v.job.name]
            rec.preemptions += 1
            remaining = max(1, math.ceil(v.job.n_steps - v.steps_done))
            requeue = dataclasses.replace(v.job, n_steps=remaining,
                                          submit_t=self._now)
            self._queue.append(requeue)
            self._log(f"preempt {v.job.name} ({remaining} steps left) "
                      f"for {job.name}")
            if self.tracer.enabled:
                self.tracer.instant(self._TRACK, "preempt", self._now,
                                    cat=CAT_SCHED, job=v.job.name,
                                    by=job.name, steps_left=remaining)
        return True

    def _admit_and_grow(self) -> None:
        if self.queueing == "drf":
            self._admit_drf()
        else:
            self._admit_fifo()
        self._grow_elastic()
        if self.tracer.enabled:
            # accel conservation sample, once per admission round:
            # free + granted-to-running == pool total, checked by the
            # repro.analysis sanitizer's sched-accel-conservation rule
            free = self.alloc.free_accels()
            busy = sum(r.alloc.n_granted for r in self._running.values())  # repro: allow(no-unordered-iteration) integer sum — exact and commutative in any order
            self.tracer.counter(self._TRACK, "free_accels", self._now,
                                float(free), cat=CAT_SCHED)
            self.tracer.counter(self._TRACK, "busy_accels", self._now,
                                float(busy), cat=CAT_SCHED)

    def _admit_fifo(self) -> None:
        # FIFO with optional backfill; preemption only for head-of-line.
        # Declared gangs (gang_size > 1) are one queue unit: admitted
        # via the all-or-nothing path or skipped whole.
        pending = self._gang_groups()
        self._queue = []            # preemption victims requeue here
        still_queued: List[PoolJob] = []
        head_blocked = False
        i = 0
        while i < len(pending):
            group = pending[i]
            i += 1
            if head_blocked and not self.backfill:
                still_queued.extend(group)
                continue
            if len(group) > 1:
                if self._try_admit_gang(group):
                    continue
            elif self._try_admit(group[0]):
                continue
            elif i == 1 and group[0].priority > 0 and \
                    self._try_admit_with_preemption(group[0]):
                # victims were requeued onto self._queue: give them the
                # same later-in-this-round shot the pre-group code did
                pending.extend([j] for j in self._queue)
                self._queue = []
                continue
            head_blocked = True
            still_queued.extend(group)
        self._queue = still_queued

    def _gang_groups(self) -> List[List[PoolJob]]:
        """Queue order preserved; jobs of one declared gang collapse
        into a single group at the first member's position."""
        groups: Dict[Tuple, List[PoolJob]] = {}
        order: List[Tuple] = []
        for job in self._queue:
            key = (job.gang_key if job.gang and job.gang_size > 1
                   else ("", job.name, id(job)))
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(job)
        return [groups[k] for k in order]

    # ---- DRF queueing (gang-aware) ----------------------------------------
    def _dominant_share(self, user: str) -> float:
        """Dominant-resource share of ``user``'s running jobs over
        ⟨accels, tier-2 bytes, tier-2 bandwidth⟩ — the max across
        resource dimensions of demanded/total (Ghodsi et al.)."""
        caps = (self.inv.total_accels, self.inv.total_tier2,
                self.inv.total_tier2_bw)
        use = [0.0, 0.0, 0.0]
        # canonical (name-sorted) accumulation order: tier-2 bytes/bw
        # are float adds, and float addition is not associative — the
        # incidental insertion order of ``_running`` must never pick
        # which association the share gets
        for name in sorted(self._running):
            run = self._running[name]
            if run.job.drf_user != user:
                continue
            use[0] += run.alloc.n_requested
            use[1] += run.job.tier2_bytes
            use[2] += run.job.tier2_bw
        return max(u / c for u, c in zip(use, caps) if c > 0)

    def _try_admit_gang(self, jobs: List[PoolJob]) -> bool:
        """Admit every job of a gang or none of them: a partially-placed
        gang would hold resources while waiting for its peers — the
        all-or-nothing rule keeps the pool deadlock-free."""
        snapshot = self.alloc.snapshot()
        allocs = []
        for job in jobs:
            alloc = self.alloc.allocate(self._request(job, job.par))
            if alloc is None:
                self.alloc.restore(snapshot)
                return False
            allocs.append((job, alloc))
        for job, alloc in allocs:
            self._start(job, job.par, alloc, in_gang=len(jobs) > 1)
        if len(jobs) > 1:
            self._log(f"admit gang {jobs[0].gang!r} "
                      f"({len(jobs)} jobs, all-or-nothing)")
            if self.tracer.enabled:
                self.tracer.instant(self._TRACK, "gang_admit", self._now,
                                    cat=CAT_SCHED, gang=jobs[0].gang,
                                    members=len(jobs))
        return True

    def _admit_drf(self) -> None:
        """Dominant-resource-fair admission: repeatedly offer resources
        to the user with the smallest dominant share, admitting that
        user's oldest queued gang atomically.  Users whose head gang
        does not fit are skipped (work conservation: a later user's
        smaller gang may still be placed) — at full size only, no
        elastic shrink and no priority preemption in this mode."""
        while self._queue:
            gangs: Dict[Tuple[str, str], List[PoolJob]] = {}
            order: List[Tuple[str, str]] = []
            for job in self._queue:
                key = job.gang_key if job.gang else (job.drf_user, job.name)
                if key not in gangs:
                    gangs[key] = []
                    order.append(key)
                gangs[key].append(job)
            # gang identity keys on the raw user; fairness accounts stay
            # on drf_user (which falls back to the job name when unset)
            user_of = {k: gangs[k][0].drf_user for k in order}
            users = sorted({user_of[k] for k in order},
                           key=lambda u: (self._dominant_share(u), u))
            admitted = None
            for user in users:
                key = next(k for k in order if user_of[k] == user)
                if self._try_admit_gang(gangs[key]):
                    admitted = {id(j) for j in gangs[key]}
                    if self.tracer.enabled:
                        # post-admission dominant share of the user who
                        # just admitted — the sanitizer's
                        # sched-drf-share rule bounds it to [0, 1]
                        self.tracer.counter(
                            self._TRACK, f"drf_share:{user}", self._now,
                            self._dominant_share(user), cat=CAT_SCHED)
                    break
            if admitted is None:
                return
            self._queue = [j for j in self._queue if id(j) not in admitted]

    def _grow_elastic(self) -> None:
        """Double shrunk elastic jobs back toward full dp while it fits."""
        for name in sorted(self._running):
            run = self._running[name]
            if not run.job.elastic or run.par.dp >= run.job.par.dp:
                continue
            grew = False
            while run.par.dp < run.job.par.dp:
                new_dp = min(run.job.par.dp, run.par.dp * 2)
                new_par = dataclasses.replace(run.par, dp=new_dp)
                snapshot = self.alloc.snapshot()
                self.alloc.release(name)
                alloc = self.alloc.allocate(self._request(run.job, new_par))
                if alloc is None:
                    self.alloc.restore(snapshot)
                    break
                self._resize(run, new_par, alloc)
                grew = True
            if grew:
                self._log(f"grow {name} to dp={run.par.dp}")

    # ---- lifecycle -------------------------------------------------------
    def _start(self, job: PoolJob, par: sim.ParallelismConfig,
               alloc: Allocation, *, in_gang: bool = False) -> None:
        st = self.step_time(job, par, alloc)
        rec = self.records[job.name]
        if rec.start_t is None:
            rec.start_t = self._now
        rec.dp_granted = par.dp
        run = _Running(job, par, alloc, st, steps_done=0.0,
                       seg_start=self._now, epoch=rec.preemptions + rec.resizes)
        self._running[job.name] = run
        remaining = job.n_steps * st
        self._push(self._now + remaining, "finish", (job.name, run.epoch))
        self._frag_samples.append(self.alloc.metrics().fragmentation)
        self._log(f"admit {job.name} dp={par.dp} "
                  f"pods={list(alloc.pod_ids)} granted={alloc.n_granted} "
                  f"(stranded={alloc.n_stranded}) step={st*1e3:.1f}ms")
        if self.tracer.enabled:
            # ``gang`` is set ONLY for members co-admitted through the
            # all-or-nothing path: the sanitizer's sched-gang-atomic
            # rule requires every gang-tagged admit to be covered by a
            # same-timestamp gang_admit naming the full member count
            self.tracer.instant(self._TRACK, "admit", self._now,
                                cat=CAT_SCHED, job=job.name, dp=par.dp,
                                pods=list(alloc.pod_ids),
                                granted=alloc.n_granted,
                                stranded=alloc.n_stranded, step_s=st,
                                gang=job.gang if in_gang else "")

    def _account_segment(self, run: _Running) -> None:
        dt = self._now - run.seg_start
        if dt > 0:
            run.steps_done += dt / run.step_time
            self.records[run.job.name].accel_seconds += \
                run.alloc.n_requested * dt
            if self.tracer.enabled:
                # one span per contiguous execution segment: the job's
                # residency on the pool between admit/resize/preempt
                # boundaries, the rows a Perfetto "what ran when" view
                self.tracer.span(self._TRACK, f"run:{run.job.name}",
                                 run.seg_start, dt, cat=CAT_SCHED,
                                 job=run.job.name, dp=run.par.dp,
                                 accels=run.alloc.n_requested)
        run.seg_start = self._now

    def _suspend(self, run: _Running) -> None:
        self._account_segment(run)
        self.alloc.release(run.job.name)
        del self._running[run.job.name]

    def _resize(self, run: _Running, par: sim.ParallelismConfig,
                alloc: Allocation) -> None:
        self._account_segment(run)
        rec = self.records[run.job.name]
        rec.resizes += 1
        rec.dp_granted = par.dp
        run.par, run.alloc = par, alloc
        run.step_time = self.step_time(run.job, par, alloc)
        run.epoch += 1
        remaining = max(0.0, run.job.n_steps - run.steps_done) * run.step_time
        self._push(self._now + remaining, "finish",
                   (run.job.name, run.epoch))

    def _finish(self, run: _Running) -> None:
        self._account_segment(run)
        self.alloc.release(run.job.name)
        del self._running[run.job.name]
        rec = self.records[run.job.name]
        rec.finish_t = self._now
        self._frag_samples.append(self.alloc.metrics().fragmentation)
        self._log(f"finish {run.job.name} jct={rec.jct:.2f}s")
        if self.tracer.enabled:
            self.tracer.instant(self._TRACK, "finish", self._now,
                                cat=CAT_SCHED, job=run.job.name,
                                jct_s=rec.jct)

"""Topology-aware composable allocation over an ``Inventory``.

Two policies realize the paper's §6 comparison at the *resource* level:

``scalepool``
    Composable disaggregation: accelerators are allocated at single-accel
    granularity, pod selection minimizes CXL hop count (single pod →
    shared leaf switch → full fabric), and capacity requests are
    reserved on tier-2 memory nodes independently of compute.

``baseline``
    RDMA-era static partitioning: jobs receive *whole pods* (the unit of
    the fast interconnect domain), and — with no disaggregated memory
    pool — capacity beyond the job's own HBM must be scavenged from the
    HBM of idle accelerators inside its partition, stranding their
    compute.  This is the paper's "sharing data beyond static partitions"
    problem made quantitative.

The allocator is the bookkeeping core; admission/timing lives in
``repro.pool.scheduler``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.pool.inventory import Inventory

GB = 1e9


@dataclass(frozen=True)
class JobRequest:
    """What a job asks the pool for."""

    name: str
    n_accels: int
    tier2_bytes: float = 0.0      # capacity-tier reservation (offload state)

    def __post_init__(self):
        if self.n_accels <= 0:
            raise ValueError(f"{self.name}: n_accels must be positive")
        if self.tier2_bytes < 0:
            raise ValueError(f"{self.name}: negative tier2_bytes")


@dataclass(frozen=True)
class Allocation:
    """A granted, disjoint slice of the estate."""

    job: str
    accels: Dict[int, Tuple[int, ...]]   # pod id -> local accel ids
    tier2: Dict[int, float]              # memory-node id -> reserved bytes
    n_requested: int                     # accels the job will actually use
    whole_pods: bool                     # baseline partition granularity
    # capacity the job *asked* for: equals the tier-2 reservation under
    # scalepool; under baseline it is backed by scavenged idle-accel HBM
    # (tier2 stays empty) but the demand is still real.
    tier2_requested: float = 0.0

    @property
    def n_granted(self) -> int:
        return sum(len(v) for v in self.accels.values())

    @property
    def n_stranded(self) -> int:
        """Accelerators held by the partition but idle (baseline HBM
        scavenging / whole-pod rounding)."""
        return self.n_granted - self.n_requested

    @property
    def pod_ids(self) -> Tuple[int, ...]:
        return tuple(sorted(self.accels))

    @property
    def n_pods(self) -> int:
        return len(self.accels)

    @property
    def tier2_bytes(self) -> float:
        return sum(self.tier2.values())


@dataclass
class PoolMetrics:
    """Instantaneous pool health, the quantities Fig. 8 sweeps."""

    accels_total: int
    accels_granted: int        # held by any allocation
    accels_busy: int           # actually computing (requested)
    tier2_total: float
    tier2_reserved: float
    fragmentation: float       # 1 - largest free block / min(free, pod size)
    n_jobs: int

    @property
    def utilization(self) -> float:
        return self.accels_busy / self.accels_total if self.accels_total else 0.0

    @property
    def granted_frac(self) -> float:
        return self.accels_granted / self.accels_total if self.accels_total else 0.0

    @property
    def stranded_frac(self) -> float:
        return (self.accels_granted - self.accels_busy) / self.accels_total \
            if self.accels_total else 0.0


class AllocationError(RuntimeError):
    pass


class Allocator:
    """Mutable allocation state over an immutable ``Inventory``."""

    def __init__(self, inventory: Inventory, policy: Optional[str] = None):
        self.inv = inventory
        self.policy = policy or inventory.interconnect
        if self.policy not in ("scalepool", "baseline"):
            raise ValueError(f"unknown policy {self.policy!r}")
        # free local accel ids per pod, kept sorted for determinism
        self._free: Dict[int, List[int]] = {
            p.id: list(p.accel_ids()) for p in inventory.pods}
        self._free_t2: Dict[int, float] = {
            m.id: m.capacity for m in inventory.memory_nodes}
        self.live: Dict[str, Allocation] = {}

    # ---- queries ---------------------------------------------------------
    def free_accels(self, pod_id: Optional[int] = None) -> int:
        if pod_id is not None:
            return len(self._free[pod_id])
        return sum(len(v) for v in self._free.values())

    def free_tier2(self) -> float:
        return sum(self._free_t2.values())

    def fully_free_pods(self) -> List[int]:
        return [p.id for p in self.inv.pods
                if len(self._free[p.id]) == p.n_accels]

    # ---- allocation ------------------------------------------------------
    def allocate(self, req: JobRequest) -> Optional[Allocation]:
        """Grant ``req`` or return None (leaving state untouched)."""
        if req.name in self.live:
            raise AllocationError(f"job {req.name!r} already holds an allocation")
        if self.policy == "baseline":
            alloc = self._allocate_baseline(req)
        else:
            alloc = self._allocate_scalepool(req)
        if alloc is not None:
            self._commit(alloc)
        return alloc

    def release(self, job: str) -> None:
        alloc = self.live.pop(job, None)
        if alloc is None:
            raise AllocationError(f"job {job!r} holds no allocation")
        for pod_id, ids in alloc.accels.items():
            self._free[pod_id] = sorted(self._free[pod_id] + list(ids))
        for node_id, nbytes in alloc.tier2.items():
            self._free_t2[node_id] += nbytes

    # ---- transactional snapshot (for preemption / resize trials) ---------
    def snapshot(self):
        """Opaque copy of the allocation state; pair with ``restore`` to
        roll back a failed multi-step operation."""
        import copy
        return (copy.deepcopy(self._free), dict(self._free_t2),
                dict(self.live))

    def restore(self, snap) -> None:
        self._free = {k: list(v) for k, v in snap[0].items()}
        self._free_t2 = dict(snap[1])
        self.live = dict(snap[2])

    def _commit(self, alloc: Allocation) -> None:
        for pod_id, ids in alloc.accels.items():
            pool = self._free[pod_id]
            for i in ids:
                pool.remove(i)   # raises if double-allocated
        for node_id, nbytes in alloc.tier2.items():
            if self._free_t2[node_id] < nbytes - 1e-6:
                raise AllocationError("tier-2 over-reservation")
            self._free_t2[node_id] -= nbytes
        self.live[alloc.job] = alloc

    # ---- scalepool: composable, hop-minimizing ---------------------------
    def _allocate_scalepool(self, req: JobRequest) -> Optional[Allocation]:
        tier2 = self._reserve_tier2(req.tier2_bytes)
        if tier2 is None:
            return None
        pods = self._pick_pods_min_hops(req.n_accels)
        if pods is None:
            return None
        accels: Dict[int, Tuple[int, ...]] = {}
        remaining = req.n_accels
        for pod_id in pods:
            take = min(remaining, len(self._free[pod_id]))
            accels[pod_id] = tuple(self._free[pod_id][:take])
            remaining -= take
        assert remaining == 0
        return Allocation(req.name, accels, tier2, req.n_accels,
                          whole_pods=False, tier2_requested=req.tier2_bytes)

    def _pick_pods_min_hops(self, n: int) -> Optional[List[int]]:
        """Pod set minimizing (span hops, pod count): single pod best-fit,
        then one leaf-switch group, then greedy across the fabric."""
        free = {pid: len(v) for pid, v in self._free.items() if v}
        if sum(free.values()) < n:
            return None
        # 1. tightest single pod that fits (best-fit limits fragmentation)
        fitting = [pid for pid, f in free.items() if f >= n]
        if fitting:
            return [min(fitting, key=lambda pid: (free[pid], pid))]
        # 2. one leaf group (1 CXL hop), fewest pods: fill biggest first
        by_leaf: Dict[int, List[int]] = {}
        for pid in free:
            by_leaf.setdefault(self.inv.leaf_of(pid), []).append(pid)
        for leaf in sorted(by_leaf):
            group = by_leaf[leaf]
            if sum(free[p] for p in group) >= n:
                return self._greedy_fill(group, free, n)
        # 3. whole fabric
        return self._greedy_fill(list(free), free, n)

    @staticmethod
    def _greedy_fill(pods: List[int], free: Dict[int, int], n: int) -> List[int]:
        chosen, got = [], 0
        for pid in sorted(pods, key=lambda p: (-free[p], p)):
            chosen.append(pid)
            got += free[pid]
            if got >= n:
                return chosen
        raise AssertionError("caller guaranteed capacity")

    def _reserve_tier2(self, nbytes: float) -> Optional[Dict[int, float]]:
        if nbytes <= 0:
            return {}
        if self.free_tier2() < nbytes:
            return None
        out: Dict[int, float] = {}
        remaining = nbytes
        # fewest nodes: drain the fullest first (deterministic tie on id)
        for node_id in sorted(self._free_t2,
                              key=lambda i: (-self._free_t2[i], i)):
            if remaining <= 0:
                break
            take = min(remaining, self._free_t2[node_id])
            if take > 0:
                out[node_id] = take
                remaining -= take
        assert remaining <= 1e-6
        return out

    # ---- baseline: static whole-pod partitions ---------------------------
    def _allocate_baseline(self, req: JobRequest) -> Optional[Allocation]:
        pod_size = self.inv.pod_size
        hbm = self.inv.pods[0].hbm_per_accel
        import math
        pods_needed = math.ceil(req.n_accels / pod_size)
        # no memory pool: capacity beyond the job's accelerators comes from
        # idle accels' HBM inside the partition -> possibly more pods.
        if req.tier2_bytes > 0:
            while (pods_needed * pod_size - req.n_accels) * hbm < req.tier2_bytes:
                pods_needed += 1
                if pods_needed > self.inv.n_pods:
                    return None
        free_pods = self.fully_free_pods()
        if len(free_pods) < pods_needed:
            return None
        chosen = sorted(free_pods)[:pods_needed]   # first-fit, contiguous ids
        accels = {pid: tuple(self.inv.pods[pid].accel_ids()) for pid in chosen}
        return Allocation(req.name, accels, {}, req.n_accels, whole_pods=True,
                          tier2_requested=req.tier2_bytes)

    # ---- metrics & invariants --------------------------------------------
    def metrics(self) -> PoolMetrics:
        total = self.inv.total_accels
        granted = sum(a.n_granted for a in self.live.values())
        busy = sum(a.n_requested for a in self.live.values())
        free = self.free_accels()
        largest = max((len(v) for v in self._free.values()), default=0)
        # external fragmentation relative to the best a pod-local (XLink)
        # job could hope for: an empty estate scores 0, free capacity
        # shattered across partially-used pods scores toward 1.
        best_block = min(free, self.inv.pod_size)
        frag = 1.0 - largest / best_block if best_block > 0 else 0.0
        return PoolMetrics(
            accels_total=total, accels_granted=granted, accels_busy=busy,
            tier2_total=self.inv.total_tier2,
            tier2_reserved=self.inv.total_tier2 - self.free_tier2(),
            fragmentation=frag, n_jobs=len(self.live))

    def check_conservation(self) -> None:
        """Invariant: free + granted == inventory, no accel held twice."""
        seen = set()
        for alloc in self.live.values():
            for pod_id, ids in alloc.accels.items():
                for i in ids:
                    key = (pod_id, i)
                    if key in seen:
                        raise AssertionError(f"double allocation of {key}")
                    seen.add(key)
        for p in self.inv.pods:
            held = {(p.id, i) for i in p.accel_ids()}
            free = {(p.id, i) for i in self._free[p.id]}
            alloced = {k for k in seen if k[0] == p.id}
            if free | alloced != held or free & alloced:
                raise AssertionError(f"pod {p.id}: conservation violated")
        for m in self.inv.memory_nodes:
            reserved = sum(a.tier2.get(m.id, 0.0) for a in self.live.values())
            if abs(reserved + self._free_t2[m.id] - m.capacity) > 1e-3:
                raise AssertionError(f"memory node {m.id}: conservation violated")

"""Topology-aware composable allocation over an ``Inventory``.

Two policies realize the paper's §6 comparison at the *resource* level:

``scalepool``
    Composable disaggregation: accelerators are allocated at single-accel
    granularity, pod selection minimizes CXL hop count (single pod →
    shared leaf switch → full fabric), and capacity requests are
    reserved on tier-2 memory nodes independently of compute.  Tier-2
    *bandwidth* is a second schedulable resource, admitted against the
    routed estate graph (``repro.fabric.Topology``): a reservation
    claims its bytes/s on every link of the pod -> memory-node route,
    so concurrent offload-heavy jobs are refused not just when a node
    is saturated but when a *shared* link (the spine -> capacity-switch
    trunk) is.  A slice of the tier-2 byte reservation may be
    earmarked as a KV grant (``kv_bytes``) — the quantity a serving
    lease turns into a ``KVBudget`` for the ``repro.serve`` engine.

``baseline``
    RDMA-era static partitioning: jobs receive *whole pods* (the unit of
    the fast interconnect domain), and — with no disaggregated memory
    pool — capacity beyond the job's own HBM must be scavenged from the
    HBM of idle accelerators inside its partition, stranding their
    compute.  This is the paper's "sharing data beyond static partitions"
    problem made quantitative.

Free accelerators are tracked per pod in a heap-backed free-list
(O(log n) take/put), so 10^5-job schedules stay tractable — see
``benchmarks/pool_scale.py`` for the guard.

The allocator is the bookkeeping core; admission/timing lives in
``repro.pool.scheduler``.
"""

from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.pool.inventory import Inventory

GB = 1e9


class FreeList:
    """Free accelerator ids of one pod: a min-heap plus a membership set.

    ``take(k)`` pops the k smallest free ids in O(k log n); ``put``
    returns ids in O(log n) each — replacing the O(n) ``list.remove``
    scans that made 10^5-job traces quadratic.
    """

    __slots__ = ("_heap", "_live")

    def __init__(self, ids):
        self._heap = list(ids)
        heapq.heapify(self._heap)
        self._live = set(self._heap)

    def __len__(self) -> int:
        return len(self._live)

    def take(self, k: int) -> Tuple[int, ...]:
        # invariant: _heap and _live hold exactly the same ids (take pops
        # both; put raises on double-free before pushing), so every popped
        # id is live — no lazy-deletion sweep is needed.
        if k > len(self._live):
            raise AssertionError("caller must check capacity before take()")
        out: List[int] = []
        for _ in range(k):
            i = heapq.heappop(self._heap)
            self._live.discard(i)
            out.append(i)
        return tuple(out)

    def put(self, ids) -> None:
        for i in ids:
            if i in self._live:
                raise AssertionError(f"double free of accel {i}")
            self._live.add(i)
            heapq.heappush(self._heap, i)

    def ids(self) -> List[int]:
        return sorted(self._live)

    def clone(self) -> "FreeList":
        fl = FreeList.__new__(FreeList)
        fl._heap = list(self._heap)
        fl._live = set(self._live)
        return fl


@dataclass(frozen=True)
class JobRequest:
    """What a job asks the pool for."""

    name: str
    n_accels: int
    tier2_bytes: float = 0.0      # capacity-tier reservation (offload state)
    kv_bytes: float = 0.0         # slice of tier2_bytes granted to KV paging
    tier2_bw: float = 0.0         # capacity-fabric bandwidth, bytes/s
    # serving tenants sharing this job's kv_bytes as ONE pool: the grant
    # stays a single reservation (no per-tenant carve-up at the
    # allocator), and ``repro.serve.PoolArbiter`` divides the hot pages
    # max-min fairly at runtime while ``lease.kv_share`` hands each
    # tenant its demand-weighted slice of the cold-store bytes.
    tenants: Tuple[str, ...] = ()
    # disaggregated serving: the tier this member of a two-tier gang
    # plays (e.g. "prefill" / "decode").  Pure metadata at the
    # allocator; ``repro.disagg`` binds roles to engine modes.
    role: str = ""
    # live jobs this job will exchange KV handoffs with: under
    # ``policy="contention"`` the placement ALSO scores (and registers)
    # the gateway->peer-gateway handoff route, so the prefill->decode
    # page stream gets a low-overlap path and later jobs avoid it
    peers: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.n_accels <= 0:
            raise ValueError(f"{self.name}: n_accels must be positive")
        if self.tier2_bytes < 0:
            raise ValueError(f"{self.name}: negative tier2_bytes")
        if self.tier2_bw < 0:
            raise ValueError(f"{self.name}: negative tier2_bw")
        if not 0 <= self.kv_bytes <= self.tier2_bytes + 1e-6:
            raise ValueError(
                f"{self.name}: kv_bytes must lie within the tier-2 "
                f"reservation ({self.kv_bytes} vs {self.tier2_bytes})")
        object.__setattr__(self, "tenants",
                           tuple(str(t) for t in self.tenants))
        object.__setattr__(self, "peers",
                           tuple(str(p) for p in self.peers))
        if len(set(self.tenants)) != len(self.tenants):
            raise ValueError(f"{self.name}: duplicate tenant names "
                             f"{self.tenants}")
        if self.tenants and self.kv_bytes <= 0:
            raise ValueError(
                f"{self.name}: a multi-tenant lease shares a KV grant — "
                f"request kv_bytes > 0 for tenants {self.tenants}")


@dataclass(frozen=True)
class Allocation:
    """A granted, disjoint slice of the estate."""

    job: str
    accels: Dict[int, Tuple[int, ...]]   # pod id -> local accel ids
    tier2: Dict[int, float]              # memory-node id -> reserved bytes
    n_requested: int                     # accels the job will actually use
    whole_pods: bool                     # baseline partition granularity
    # capacity the job *asked* for: equals the tier-2 reservation under
    # scalepool; under baseline it is backed by scavenged idle-accel HBM
    # (tier2 stays empty) but the demand is still real.
    tier2_requested: float = 0.0
    # KV slice of the capacity grant (drives serving KVBudgets)
    kv_bytes: float = 0.0
    # capacity-fabric bandwidth: node id -> reserved bytes/s (scalepool);
    # under baseline the demand is recorded but rides the IB fabric.
    tier2_bw: Dict[int, float] = field(default_factory=dict)
    tier2_bw_requested: float = 0.0
    # serving tenants that share this allocation's kv_bytes as one pool
    tenants: Tuple[str, ...] = ()
    # gang role this member plays (disaggregated prefill/decode tiers)
    role: str = ""

    @property
    def n_granted(self) -> int:
        return sum(len(v) for v in self.accels.values())

    @property
    def n_stranded(self) -> int:
        """Accelerators held by the partition but idle (baseline HBM
        scavenging / whole-pod rounding)."""
        return self.n_granted - self.n_requested

    @property
    def pod_ids(self) -> Tuple[int, ...]:
        return tuple(sorted(self.accels))

    @property
    def n_pods(self) -> int:
        return len(self.accels)

    @property
    def tier2_bytes(self) -> float:
        return sum(self.tier2.values())

    @property
    def tier2_bw_total(self) -> float:
        return sum(self.tier2_bw.values())


@dataclass
class PoolMetrics:
    """Instantaneous pool health, the quantities Fig. 8 sweeps."""

    accels_total: int
    accels_granted: int        # held by any allocation
    accels_busy: int           # actually computing (requested)
    tier2_total: float
    tier2_reserved: float
    tier2_bw_total: float      # capacity-fabric bandwidth, bytes/s
    tier2_bw_reserved: float
    tier2_kv_reserved: float   # KV slice of the byte reservations
    fragmentation: float       # 1 - largest free block / min(free, pod size)
    n_jobs: int

    @property
    def utilization(self) -> float:
        return self.accels_busy / self.accels_total if self.accels_total else 0.0

    @property
    def granted_frac(self) -> float:
        return self.accels_granted / self.accels_total if self.accels_total else 0.0

    @property
    def stranded_frac(self) -> float:
        return (self.accels_granted - self.accels_busy) / self.accels_total \
            if self.accels_total else 0.0

    @property
    def tier2_bw_frac(self) -> float:
        return (self.tier2_bw_reserved / self.tier2_bw_total
                if self.tier2_bw_total else 0.0)


class AllocationError(RuntimeError):
    pass


class Allocator:
    """Mutable allocation state over an immutable ``Inventory``."""

    def __init__(self, inventory: Inventory, policy: Optional[str] = None):
        self.inv = inventory
        self.policy = policy or inventory.interconnect
        if self.policy not in ("scalepool", "baseline", "contention"):
            raise ValueError(f"unknown policy {self.policy!r}")
        # free local accel ids per pod, heap-backed (smallest id first for
        # determinism — the same order the old sorted-list scans produced)
        self._free: Dict[int, FreeList] = {
            p.id: FreeList(p.accel_ids()) for p in inventory.pods}
        self._free_t2: Dict[int, float] = {
            m.id: m.capacity for m in inventory.memory_nodes}
        self._free_t2bw: Dict[int, float] = {
            m.id: m.bandwidth for m in inventory.memory_nodes}
        # tier-2 bandwidth admission happens against the routed estate
        # graph, not just per-node scalars: a reservation claims its
        # bytes/s on EVERY link of the pod -> memory-node route, so the
        # shared trunk (spine -> capacity switch) genuinely caps the
        # aggregate even when individual nodes still have headroom
        self.topo = (inventory.topology()
                     if self.policy in ("scalepool", "contention")
                     and inventory.tier2_fabric is not None
                     and inventory.memory_nodes else None)
        self._link_free: Dict[str, float] = (
            {name: l.capacity for name, l in self.topo.links.items()}
            if self.topo is not None else {})
        self._job_links: Dict[str, List[Tuple[str, float]]] = {}
        # predicted collective/offload route links per live job (link
        # names on the estate graph) — what ``policy="contention"``
        # scores candidate placements against
        self._job_route_links: Dict[str, Tuple[str, ...]] = {}
        self.live: Dict[str, Allocation] = {}

    # ---- queries ---------------------------------------------------------
    def free_accels(self, pod_id: Optional[int] = None) -> int:
        if pod_id is not None:
            return len(self._free[pod_id])
        return sum(len(v) for v in self._free.values())

    def free_tier2(self) -> float:
        return sum(self._free_t2.values())

    def free_tier2_bw(self) -> float:
        return sum(self._free_t2bw.values())

    def free_link_bw(self, link_name: str) -> float:
        """Unreserved bytes/s on one link of the routed estate graph."""
        if self.topo is None:
            raise ValueError(
                "routed link admission is inactive for this allocator "
                "(baseline policy, or an inventory without a tier-2 "
                "fabric / memory nodes)")
        return self._link_free[link_name]

    def fully_free_pods(self) -> List[int]:
        return [p.id for p in self.inv.pods
                if len(self._free[p.id]) == p.n_accels]

    # ---- allocation ------------------------------------------------------
    def allocate(self, req: JobRequest) -> Optional[Allocation]:
        """Grant ``req`` or return None (leaving state untouched)."""
        if req.name in self.live:
            raise AllocationError(f"job {req.name!r} already holds an allocation")
        if self.policy == "baseline":
            alloc = self._allocate_baseline(req)
        else:
            alloc = self._allocate_scalepool(req)
        if alloc is not None:
            self.live[alloc.job] = alloc
        return alloc

    def allocate_gang(self, reqs) -> Optional[List[Allocation]]:
        """Two-tier (or N-tier) gang placement: grant every member of
        ``reqs`` in order or none of them (snapshot/rollback).  Each
        member after the first is wired as a handoff peer of all the
        earlier members, so under ``policy="contention"`` the later
        tiers' placement scores the prefill->decode handoff route
        against live traffic — and registers it, keeping later jobs
        off the page stream's links."""
        names = [r.name for r in reqs]
        if len(set(names)) != len(names):
            raise AllocationError(f"duplicate gang member names {names}")
        snap = self.snapshot()
        out: List[Allocation] = []
        for i, req in enumerate(reqs):
            wired = dataclasses.replace(
                req, peers=tuple(dict.fromkeys(req.peers + tuple(names[:i]))))
            alloc = self.allocate(wired)
            if alloc is None:
                self.restore(snap)
                return None
            out.append(alloc)
        return out

    def handoff_route(self, a: Allocation, b: Allocation):
        """The estate route the ``a -> b`` KV handoff stream rides
        (gateway pod to gateway pod), or None when the tiers share a
        gateway pod (the degenerate zero-cost handoff) or the
        allocator has no routed estate graph."""
        if self.topo is None:
            return None
        gw_a, gw_b = min(a.pod_ids), min(b.pod_ids)
        if gw_a == gw_b:
            return None
        return self.topo.route(f"pod:{gw_a}", f"pod:{gw_b}")

    def release(self, job: str) -> None:
        alloc = self.live.pop(job, None)
        if alloc is None:
            raise AllocationError(f"job {job!r} holds no allocation")
        for pod_id, ids in alloc.accels.items():
            self._free[pod_id].put(ids)
        for node_id, nbytes in alloc.tier2.items():
            self._free_t2[node_id] += nbytes
        for node_id, bw in alloc.tier2_bw.items():
            self._free_t2bw[node_id] += bw
        for link_name, bw in self._job_links.pop(job, ()):
            self._link_free[link_name] += bw
        self._job_route_links.pop(job, None)

    # ---- transactional snapshot (for preemption / resize trials) ---------
    def snapshot(self):
        """Opaque copy of the allocation state; pair with ``restore`` to
        roll back a failed multi-step operation."""
        return ({k: v.clone() for k, v in self._free.items()},
                dict(self._free_t2), dict(self._free_t2bw), dict(self.live),
                dict(self._link_free),
                {k: list(v) for k, v in self._job_links.items()},
                dict(self._job_route_links))

    def restore(self, snap) -> None:
        self._free = {k: v.clone() for k, v in snap[0].items()}
        self._free_t2 = dict(snap[1])
        self._free_t2bw = dict(snap[2])
        self.live = dict(snap[3])
        self._link_free = dict(snap[4])
        self._job_links = {k: list(v) for k, v in snap[5].items()}
        self._job_route_links = dict(snap[6])

    # ---- scalepool: composable, hop-minimizing ---------------------------
    def _allocate_scalepool(self, req: JobRequest) -> Optional[Allocation]:
        for peer in req.peers:
            if peer not in self.live:
                raise AllocationError(
                    f"{req.name}: handoff peer {peer!r} holds no live "
                    f"allocation — allocate gang members in order "
                    f"(allocate_gang wires peers automatically)")
        peer_pods = tuple(sorted(min(self.live[p].pod_ids)
                                 for p in req.peers))
        tier2 = self._reserve_pool(self._free_t2, req.tier2_bytes)
        if tier2 is None:
            return None
        tier2_bw = self._reserve_pool(self._free_t2bw, req.tier2_bw)
        if tier2_bw is None:
            return None
        mem_ids = tuple(sorted(set(tier2) | set(tier2_bw)))
        if self.policy == "contention":
            pods = self._pick_pods_contention(req.n_accels, mem_ids,
                                              peer_pods)
        else:
            pods = self._pick_pods_min_hops(req.n_accels)
        if pods is None:
            return None
        link_plan = self._plan_link_bw(min(pods), tier2_bw)
        if link_plan is None:
            return None         # a shared link (e.g. the trunk) is full
        # commit: pop the smallest free ids from the chosen pods
        accels: Dict[int, Tuple[int, ...]] = {}
        remaining = req.n_accels
        for pod_id in pods:
            take = min(remaining, len(self._free[pod_id]))
            accels[pod_id] = self._free[pod_id].take(take)
            remaining -= take
        assert remaining == 0
        for node_id, nbytes in tier2.items():
            self._free_t2[node_id] -= nbytes
        for node_id, bw in tier2_bw.items():
            self._free_t2bw[node_id] -= bw
        for link_name, bw in link_plan:
            self._link_free[link_name] -= bw
        if link_plan:
            self._job_links[req.name] = link_plan
        if self.topo is not None:
            self._job_route_links[req.name] = \
                self._route_link_names(pods, mem_ids, peer_pods)
        return Allocation(req.name, accels, tier2, req.n_accels,
                          whole_pods=False, tier2_requested=req.tier2_bytes,
                          kv_bytes=req.kv_bytes, tier2_bw=tier2_bw,
                          tier2_bw_requested=req.tier2_bw,
                          tenants=req.tenants, role=req.role)

    def _plan_link_bw(self, gateway_pod: int, tier2_bw: Dict[int, float]
                      ) -> Optional[List[Tuple[str, float]]]:
        """Admission-check a per-node bandwidth split against the routed
        estate graph: each node's bytes/s must fit on EVERY link of the
        ``pod:<gateway> -> mem:<node>`` route (the job's offload traffic
        egresses its primary pod — a first-order gateway model; links
        shared between routes, the spine->t2sw trunk above all, see the
        aggregate).  Returns the per-link reservation list, or None if
        any link lacks headroom.  Plan-only: nothing is mutated."""
        if not tier2_bw or self.topo is None:
            return []
        claim: Dict[str, float] = {}
        for node_id, bw in sorted(tier2_bw.items()):
            route = self.topo.route(f"pod:{gateway_pod}", f"mem:{node_id}")
            for link in route.links:
                claim[link.name] = claim.get(link.name, 0.0) + bw
        for name, bw in claim.items():
            if bw > self._link_free[name] + 1e-6:
                return None
        return sorted(claim.items())

    def _pick_pods_min_hops(self, n: int) -> Optional[List[int]]:
        """Pod set minimizing (span hops, pod count): single pod best-fit,
        then one leaf-switch group, then greedy across the fabric."""
        free = {pid: len(v) for pid, v in self._free.items() if len(v)}
        if sum(free.values()) < n:
            return None
        # 1. tightest single pod that fits (best-fit limits fragmentation)
        fitting = [pid for pid, f in free.items() if f >= n]
        if fitting:
            return [min(fitting, key=lambda pid: (free[pid], pid))]
        # 2. one leaf group (1 CXL hop), fewest pods: fill biggest first
        by_leaf: Dict[int, List[int]] = {}
        for pid in free:
            by_leaf.setdefault(self.inv.leaf_of(pid), []).append(pid)
        for leaf in sorted(by_leaf):
            group = by_leaf[leaf]
            if sum(free[p] for p in group) >= n:
                return self._greedy_fill(group, free, n)
        # 3. whole fabric
        return self._greedy_fill(list(free), free, n)

    # ---- contention: hop-minimizing, overlap-avoiding --------------------
    def _route_link_names(self, pods: List[int],
                          mem_ids: Tuple[int, ...],
                          peer_pods: Tuple[int, ...] = ()
                          ) -> Tuple[str, ...]:
        """Predicted estate links a placement's collective + offload
        traffic will occupy: gateway (lowest pod) to every other pod of
        the gang, gateway to every reserved tier-2 node — the same
        routes ``repro.colo.job_routes`` pins at run time, widened to
        the whole gang — and, for a gang member with handoff peers,
        gateway to every peer gateway (the prefill->decode KV stream's
        route, scored and registered like any other traffic)."""
        if self.topo is None:
            return ()
        gw = min(pods)
        names = set()
        for pid in pods:
            if pid == gw:
                continue
            for link in self.topo.route(f"pod:{gw}", f"pod:{pid}").links:
                names.add(link.name)
        for node_id in mem_ids:
            for link in self.topo.route(f"pod:{gw}",
                                        f"mem:{node_id}").links:
                names.add(link.name)
        for peer_gw in peer_pods:
            if peer_gw == gw:
                continue            # colocated peer: degenerate handoff
            for link in self.topo.route(f"pod:{gw}",
                                        f"pod:{peer_gw}").links:
                names.add(link.name)
        return tuple(sorted(names))

    def _pick_pods_contention(self, n: int, mem_ids: Tuple[int, ...],
                              peer_pods: Tuple[int, ...] = ()
                              ) -> Optional[List[int]]:
        """Hop-minimizing placement that breaks ties by predicted link
        overlap with already-placed jobs' routes: same candidate tiers
        as ``_pick_pods_min_hops`` (single pod, one leaf group, whole
        fabric — hops stay the primary key), but within a tier the
        candidate sharing the fewest links with live jobs wins.  With
        no live jobs every overlap is zero and the choice reduces
        exactly to the min-hops pick.  ``peer_pods`` (handoff peers'
        gateway pods) widen the scored route set with the KV-handoff
        legs, so a decode tier lands where its page stream from the
        prefill tier crosses the fewest already-busy links."""
        free = {pid: len(v) for pid, v in self._free.items() if len(v)}
        if sum(free.values()) < n:
            return None
        busy: set = set()
        for links in self._job_route_links.values():
            busy.update(links)

        def overlap(pods: List[int]) -> int:
            return sum(1 for name in self._route_link_names(pods, mem_ids,
                                                            peer_pods)
                       if name in busy)

        # 1. single pod: (overlap, tightest fit, id) — legacy order when
        #    nothing is placed yet
        fitting = [pid for pid, f in free.items() if f >= n]
        if fitting:
            return [min(fitting,
                        key=lambda pid: (overlap([pid]), free[pid], pid))]
        # 2. one leaf group: legacy takes the first leaf with capacity;
        #    here the least-overlapping one (leaf id breaks ties)
        by_leaf: Dict[int, List[int]] = {}
        for pid in free:
            by_leaf.setdefault(self.inv.leaf_of(pid), []).append(pid)
        best = None
        for leaf in sorted(by_leaf):
            group = by_leaf[leaf]
            if sum(free[p] for p in group) < n:
                continue
            pods = self._greedy_fill(group, free, n)
            key = (overlap(pods), leaf)
            if best is None or key < best[0]:
                best = (key, pods)
        if best is not None:
            return best[1]
        # 3. whole fabric (one candidate — nothing to score)
        return self._greedy_fill(list(free), free, n)

    @staticmethod
    def _greedy_fill(pods: List[int], free: Dict[int, int], n: int) -> List[int]:
        chosen, got = [], 0
        for pid in sorted(pods, key=lambda p: (-free[p], p)):
            chosen.append(pid)
            got += free[pid]
            if got >= n:
                return chosen
        raise AssertionError("caller guaranteed capacity")

    @staticmethod
    def _reserve_pool(free: Dict[int, float], amount: float) \
            -> Optional[Dict[int, float]]:
        """Plan a reservation of ``amount`` over a per-node scalar resource
        (bytes or bytes/s): fewest nodes, drain the fullest first."""
        if amount <= 0:
            return {}
        if sum(free.values()) < amount:
            return None
        out: Dict[int, float] = {}
        remaining = amount
        for node_id in sorted(free, key=lambda i: (-free[i], i)):
            if remaining <= 0:
                break
            take = min(remaining, free[node_id])
            if take > 0:
                out[node_id] = take
                remaining -= take
        assert remaining <= 1e-6
        return out

    # ---- baseline: static whole-pod partitions ---------------------------
    def _allocate_baseline(self, req: JobRequest) -> Optional[Allocation]:
        pod_size = self.inv.pod_size
        hbm = self.inv.pods[0].hbm_per_accel
        import math
        pods_needed = math.ceil(req.n_accels / pod_size)
        # no memory pool: capacity beyond the job's accelerators comes from
        # idle accels' HBM inside the partition -> possibly more pods.
        if req.tier2_bytes > 0:
            while (pods_needed * pod_size - req.n_accels) * hbm < req.tier2_bytes:
                pods_needed += 1
                if pods_needed > self.inv.n_pods:
                    return None
        free_pods = self.fully_free_pods()
        if len(free_pods) < pods_needed:
            return None
        chosen = sorted(free_pods)[:pods_needed]   # first-fit, contiguous ids
        accels = {pid: self._free[pid].take(len(self._free[pid]))
                  for pid in chosen}
        return Allocation(req.name, accels, {}, req.n_accels, whole_pods=True,
                          tier2_requested=req.tier2_bytes,
                          kv_bytes=req.kv_bytes,
                          tier2_bw_requested=req.tier2_bw,
                          tenants=req.tenants)

    # ---- metrics & invariants --------------------------------------------
    def metrics(self) -> PoolMetrics:
        total = self.inv.total_accels
        granted = sum(a.n_granted for a in self.live.values())
        busy = sum(a.n_requested for a in self.live.values())
        free = self.free_accels()
        largest = max((len(v) for v in self._free.values()), default=0)
        # external fragmentation relative to the best a pod-local (XLink)
        # job could hope for: an empty estate scores 0, free capacity
        # shattered across partially-used pods scores toward 1.
        best_block = min(free, self.inv.pod_size)
        frag = 1.0 - largest / best_block if best_block > 0 else 0.0
        return PoolMetrics(
            accels_total=total, accels_granted=granted, accels_busy=busy,
            tier2_total=self.inv.total_tier2,
            tier2_reserved=self.inv.total_tier2 - self.free_tier2(),
            tier2_bw_total=self.inv.total_tier2_bw,
            tier2_bw_reserved=self.inv.total_tier2_bw - self.free_tier2_bw(),
            tier2_kv_reserved=sum(a.kv_bytes for a in self.live.values()),
            fragmentation=frag, n_jobs=len(self.live))

    def check_conservation(self) -> None:
        """Invariant: free + granted == inventory, no accel held twice."""
        seen = set()
        for alloc in self.live.values():
            for pod_id, ids in alloc.accels.items():
                for i in ids:
                    key = (pod_id, i)
                    if key in seen:
                        raise AssertionError(f"double allocation of {key}")
                    seen.add(key)
        for p in self.inv.pods:
            held = {(p.id, i) for i in p.accel_ids()}
            free = {(p.id, i) for i in self._free[p.id].ids()}
            alloced = {k for k in seen if k[0] == p.id}
            if free | alloced != held or free & alloced:
                raise AssertionError(f"pod {p.id}: conservation violated")
        for m in self.inv.memory_nodes:
            reserved = sum(a.tier2.get(m.id, 0.0) for a in self.live.values())
            if abs(reserved + self._free_t2[m.id] - m.capacity) > 1e-3:
                raise AssertionError(f"memory node {m.id}: conservation violated")
            bw = sum(a.tier2_bw.get(m.id, 0.0) for a in self.live.values())
            if abs(bw + self._free_t2bw[m.id] - m.bandwidth) > 1e-3:
                raise AssertionError(
                    f"memory node {m.id}: bandwidth conservation violated")
        if self.topo is not None:
            held: Dict[str, float] = {}
            for job, links in self._job_links.items():
                if job not in self.live:
                    raise AssertionError(
                        f"link reservations for dead job {job!r}")
                for name, bw in links:
                    held[name] = held.get(name, 0.0) + bw
            for name, link in self.topo.links.items():
                reserved = held.get(name, 0.0)
                if abs(reserved + self._link_free[name] - link.capacity) > 1e-3:
                    raise AssertionError(
                        f"link {name}: bandwidth conservation violated")

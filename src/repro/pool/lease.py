"""Allocation leases: the bridge from the orchestrator to the runtime.

A ``Lease`` is a granted allocation plus everything the training/serving
stack needs to *use* it: a concrete JAX device mesh whose shape mirrors
the lease's pod topology, and a ``TieringPolicy`` that routes state to
the capacity tier exactly when the lease carries a tier-2 reservation.
Elastic grow/shrink produces a checkpoint re-sharding plan via
``repro.ckpt.elastic.resize_plan`` so a resized job can consume its old
checkpoint (the paper's composability axis made operational).

``ResourcePool`` is the user-facing facade: build one over an inventory,
take leases, hand them to ``launch/train.py`` / ``runtime/serve.py``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax

from repro.analysis import tiebreak
from repro.ckpt.elastic import resize_plan
from repro.core.tiering import KVBudget, TieringPolicy
from repro.pool.allocator import (Allocation, AllocationError, Allocator,
                                  JobRequest)
from repro.pool.inventory import Inventory, build_inventory

GB = 1e9


def _largest_divisor_leq(n: int, cap: int) -> int:
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


@dataclass(frozen=True)
class Lease:
    """A live claim on pool resources, materializable as mesh + policy."""

    allocation: Allocation
    model_parallel: int = 1

    @property
    def job(self) -> str:
        return self.allocation.job

    @property
    def n_accels(self) -> int:
        return self.allocation.n_requested

    @property
    def tier2_bytes(self) -> float:
        return self.allocation.tier2_bytes

    @property
    def kv_bytes(self) -> float:
        """The KV slice of the tier-2 grant (drives serving KV budgets)."""
        return self.allocation.kv_bytes

    @property
    def tier2_bw(self) -> float:
        return self.allocation.tier2_bw_total

    @property
    def spans_pods(self) -> bool:
        return self.allocation.n_pods > 1

    @property
    def tenants(self) -> Tuple[str, ...]:
        """Serving tenants sharing this lease's KV grant as one pool."""
        return self.allocation.tenants

    @property
    def role(self) -> str:
        """Gang role this sub-lease plays (disaggregated serving tiers,
        e.g. ``"prefill"`` / ``"decode"``); empty for a plain lease."""
        return self.allocation.role

    # ---- runtime binding -------------------------------------------------
    def kv_budget(self, *, page_size: int = 64) -> Optional[KVBudget]:
        """The lease's KV grant as an engine-consumable ``KVBudget``:
        tier-2 bytes are the allocator's actual grant; the tier-1 page
        quota is left for the engine to derive from its slot geometry."""
        if self.kv_bytes <= 0:
            return None
        return KVBudget(tier1_pages=None, tier2_bytes=self.kv_bytes,
                        page_size=page_size)

    def kv_shares(self, demands: Optional[Dict[str, float]] = None
                  ) -> Dict[str, float]:
        """Demand-weighted split of the shared cold-store grant: max-min
        water-filling over per-tenant byte demands, mirroring the hot
        page-share logic in ``repro.serve.PoolArbiter._shares``.  A
        tenant demanding no more than the even split is *saturated* —
        it gets exactly its demand and donates the surplus to heavier
        demanders (the elasticity staging-heavy disagg traffic needs);
        bytes left after every demand is met are returned to all
        tenants as an equal headroom bonus, so the shares always sum to
        ``kv_bytes`` and a quiet tenant keeps spill headroom.  With no
        demands (``None`` or all zero) every tenant gets exactly
        ``kv_bytes / N`` — the legacy static split.

        Sharing incentive (pinned by test): a tenant demanding at least
        the even split never receives less than ``kv_bytes / N``."""
        if not self.tenants:
            raise ValueError(
                f"lease {self.job!r} was not taken with tenants= — "
                f"use kv_budget() for single-tenant serving")
        demands = demands or {}
        unknown = sorted(set(demands) - set(self.tenants))
        if unknown:
            raise KeyError(
                f"{unknown[0]!r} is not a tenant of lease {self.job!r} "
                f"(tenants: {self.tenants})")
        shares = {t: 0.0 for t in self.tenants}
        pending = {t: max(0.0, float(demands.get(t, 0.0)))
                   for t in self.tenants}
        remaining = self.kv_bytes
        while pending:
            level = remaining / len(pending)
            # selection is a demand threshold — order() only permutes
            # the scan (racecheck seam); the filtered set is order-free
            sat = [t for t, d in tiebreak.order(sorted(pending.items()))
                   if d <= level]
            if not sat:
                # everyone still pending wants more than the even
                # split: level each, nothing left to donate
                for t in sorted(pending):
                    shares[t] += level
                remaining = 0.0
                break
            for t in sorted(sat):
                shares[t] += pending.pop(t)
                remaining -= shares[t]
        if remaining > 0.0 and self.kv_bytes > 0:
            bonus = remaining / len(self.tenants)
            for t in shares:
                shares[t] += bonus
        return shares

    def kv_share(self, tenant: str, *, page_size: int = 64,
                 demands: Optional[Dict[str, float]] = None) -> KVBudget:
        """One tenant's slice of the shared KV grant.  The cold-store
        *bytes* are split by demand-weighted water-filling over
        ``demands`` (see ``kv_shares``; omitted demands mean the legacy
        equal split — a tenant's spill headroom is its own, so a hog
        cannot exhaust a neighbor's tier-2 budget); the hot tier-1
        *pages* stay one shared pool, divided dynamically by
        ``repro.serve.PoolArbiter`` as a revocable max-min fair
        share."""
        if not self.tenants:
            raise ValueError(
                f"lease {self.job!r} was not taken with tenants= — "
                f"use kv_budget() for single-tenant serving")
        if tenant not in self.tenants:
            raise KeyError(
                f"{tenant!r} is not a tenant of lease {self.job!r} "
                f"(tenants: {self.tenants})")
        if not demands:
            # the exact legacy float: bit-compatible with every
            # existing from_lease construction
            share = self.kv_bytes / len(self.tenants)
        else:
            share = self.kv_shares(demands)[tenant]
        return KVBudget(tier1_pages=None, tier2_bytes=share,
                        page_size=page_size)

    def tiering_policy(self) -> TieringPolicy:
        """Capacity demand → offload policy: a lease with capacity
        backing offloads optimizer state (train) / budgets KV paging
        (serve).  Under the baseline policy that backing is scavenged
        idle-accel HBM (``tier2_requested`` with an empty reservation) —
        the demand still offloads, it just lands in the stranded
        partition."""
        has_t2 = self.allocation.tier2_requested > 0 or self.tier2_bytes > 0
        return TieringPolicy(offload_optimizer=has_t2,
                             kv_budget=self.kv_budget())

    def mesh_shape(self, n_devices: int) -> Tuple[Tuple[int, ...],
                                                  Tuple[str, ...]]:
        """Map the lease's logical topology onto ``n_devices`` local
        devices: the pod axis mirrors the allocation's pod span; model
        parallelism is honored as far as divisibility allows."""
        span = self.allocation.n_pods
        if span > 1 and n_devices % span == 0 and n_devices // span > 1:
            per_pod = n_devices // span
            m = _largest_divisor_leq(per_pod, self.model_parallel)
            return (span, per_pod // m, m), ("pod", "data", "model")
        m = _largest_divisor_leq(n_devices, self.model_parallel)
        return (n_devices // m, m), ("data", "model")

    def materialize(self, devices=None):
        """Build the concrete JAX mesh + tiering policy for this lease.

        ``devices``: optional explicit device list (defaults to all local
        devices — on a real deployment each host binds its slice; the
        mesh *shape* logic is identical).
        """
        devs = list(devices) if devices is not None else list(jax.devices())
        shape, axes = self.mesh_shape(len(devs))
        mesh = jax.make_mesh(shape, axes, devices=devs)
        return mesh, self.tiering_policy()

class ResourcePool:
    """Facade: inventory + allocator + lease lifecycle."""

    def __init__(self, inventory: Optional[Inventory] = None,
                 policy: Optional[str] = None, **inventory_kwargs):
        self.inv = inventory or build_inventory(**inventory_kwargs)
        self.alloc = Allocator(self.inv, policy)
        self.leases: Dict[str, Lease] = {}

    def lease(self, name: str, n_accels: int, *, tier2_gb: float = 0.0,
              kv_gb: float = 0.0, tier2_gbps: float = 0.0,
              model_parallel: int = 1,
              tenants: Tuple[str, ...] = ()) -> Lease:
        """Take a lease: ``kv_gb`` earmarks a slice of the tier-2
        reservation as a KV-paging grant (serving engines turn it into a
        ``KVBudget``); ``tier2_gbps`` reserves capacity-fabric bandwidth.
        ``tenants`` names serving tenants that will share the KV grant
        as ONE pool (see ``Lease.kv_share`` / ``serve.PoolArbiter``)."""
        allocation = self.alloc.allocate(
            JobRequest(name, n_accels, tier2_gb * GB, kv_bytes=kv_gb * GB,
                       tier2_bw=tier2_gbps * GB, tenants=tenants))
        if allocation is None:
            m = self.alloc.metrics()
            raise AllocationError(
                f"pool cannot satisfy {name!r}: wanted {n_accels} accels + "
                f"{tier2_gb:.0f}GB tier-2 + {tier2_gbps:.0f}GB/s; free: "
                f"{self.alloc.free_accels()} accels, "
                f"{self.alloc.free_tier2() / GB:.0f}GB, "
                f"{self.alloc.free_tier2_bw() / GB:.0f}GB/s "
                f"(utilization {m.utilization:.0%})")
        lease = Lease(allocation, model_parallel=model_parallel)
        self.leases[name] = lease
        return lease

    def lease_gang(self, name: str, roles: Dict[str, Dict],
                   *, model_parallel: int = 1) -> Dict[str, Lease]:
        """Role-tagged sub-leases off ONE gang grant (the disaggregated
        prefill/decode estate shape): ``roles`` maps a role name to its
        lease kwargs (``n_accels`` required; ``tier2_gb``/``kv_gb``/
        ``tier2_gbps``/``tenants`` optional).  Members are placed
        all-or-nothing in declaration order; each later member's
        placement scores the handoff route back to the earlier tiers
        (``policy="contention"``).  Each sub-lease is a full ``Lease``
        named ``<name>/<role>`` — releasable individually or together
        via ``release_gang``."""
        reqs = []
        for role, kw in roles.items():
            extra = sorted(set(kw) - {"n_accels", "tier2_gb", "kv_gb",
                                      "tier2_gbps", "tenants"})
            if extra:
                raise TypeError(f"{name}/{role}: unknown lease kwargs "
                                f"{extra}")
            reqs.append(JobRequest(
                f"{name}/{role}", kw["n_accels"],
                kw.get("tier2_gb", 0.0) * GB,
                kv_bytes=kw.get("kv_gb", 0.0) * GB,
                tier2_bw=kw.get("tier2_gbps", 0.0) * GB,
                tenants=tuple(kw.get("tenants", ())), role=role))
        allocs = self.alloc.allocate_gang(reqs)
        if allocs is None:
            m = self.alloc.metrics()
            raise AllocationError(
                f"pool cannot satisfy gang {name!r} "
                f"({', '.join(r.name for r in reqs)}); free: "
                f"{self.alloc.free_accels()} accels, "
                f"{self.alloc.free_tier2() / GB:.0f}GB "
                f"(utilization {m.utilization:.0%})")
        out: Dict[str, Lease] = {}
        for alloc in allocs:
            lease = Lease(alloc, model_parallel=model_parallel)
            self.leases[alloc.job] = lease
            out[alloc.role] = lease
        return out

    def release_gang(self, name: str) -> None:
        """Release every sub-lease of gang ``name`` (prefix match on
        ``<name>/``)."""
        members = [job for job in sorted(self.leases)
                   if job.startswith(f"{name}/")]
        if not members:
            raise AllocationError(f"no gang {name!r} sub-leases held")
        for job in members:
            self.release(job)

    def handoff_route(self, a: Lease, b: Lease):
        """The estate route an ``a -> b`` KV handoff stream rides, or
        None when the tiers share a gateway pod (degenerate handoff)."""
        return self.alloc.handoff_route(a.allocation, b.allocation)

    def release(self, lease_or_name) -> None:
        name = (lease_or_name if isinstance(lease_or_name, str)
                else lease_or_name.job)
        self.alloc.release(name)
        del self.leases[name]

    def resize(self, lease_or_name, n_accels: int,
               *, tier2_gb: Optional[float] = None) -> Tuple[Lease, Dict[str, int]]:
        """Elastic grow/shrink: atomically trade the old allocation for a
        new one (old resources count as free during re-placement)."""
        name = (lease_or_name if isinstance(lease_or_name, str)
                else lease_or_name.job)
        old = self.leases[name]
        t2 = old.tier2_bytes if tier2_gb is None else tier2_gb * GB
        # validate the re-sharding plan BEFORE touching allocator state so
        # an impossible decomposition can't leave a half-committed resize
        plan = resize_plan(old.n_accels, n_accels,
                           model_parallel=old.model_parallel)
        snapshot = self.alloc.snapshot()
        self.alloc.release(name)
        allocation = self.alloc.allocate(JobRequest(
            name, n_accels, t2,
            kv_bytes=min(old.allocation.kv_bytes, t2),
            tier2_bw=old.allocation.tier2_bw_requested,
            tenants=old.allocation.tenants))
        if allocation is None:
            self.alloc.restore(snapshot)
            raise AllocationError(
                f"cannot resize {name!r} to {n_accels} accels")
        new_lease = dataclasses.replace(old, allocation=allocation)
        self.leases[name] = new_lease
        return new_lease, plan

    def metrics(self):
        return self.alloc.metrics()


def smoke_pool(policy: str = "scalepool") -> ResourcePool:
    """A small deterministic estate for CPU tests/demos: 4 pods x 8
    accels, two 1TB memory nodes (scalepool/contention) or none
    (baseline)."""
    return ResourcePool(build_inventory(
        n_pods=4, pod_size=8, hbm_per_accel_gb=192.0,
        n_memory_nodes=(0 if policy == "baseline" else 2),
        memory_node_gb=1024.0, interconnect=policy))

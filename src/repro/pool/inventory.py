"""Cluster inventory for the ScalePool orchestrator (paper §3-§5).

Describes the *static* composable hardware estate: XLink pods
(accelerator clusters with single-hop switched fabrics), the hierarchical
CXL switching fabric stitching pods together, and the dedicated tier-2
memory nodes hanging off the capacity-oriented CXL fabric.  Everything is
derived from the link/switch/topology models in ``repro.core.fabric`` —
the inventory adds only *identity* (which accelerator, which pod, which
memory node) so an allocator can hand out disjoint subsets.

The inventory is immutable; allocation state lives in
``repro.pool.allocator``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import fabric as fb

GB = fb.GB


@dataclass(frozen=True)
class PodSpec:
    """One XLink accelerator cluster (a GB200-NVL72-class rack, §4)."""

    id: int
    n_accels: int
    hbm_per_accel: float          # bytes
    fabric: fb.FabricSpec         # single-hop XLink fabric inside the pod

    @property
    def hbm_total(self) -> float:
        return self.n_accels * self.hbm_per_accel

    def accel_ids(self) -> range:
        return range(self.n_accels)


@dataclass(frozen=True)
class MemoryNodeSpec:
    """A CPU-less tier-2 memory node on the capacity CXL fabric (§5).

    ``bandwidth`` is the node's sustainable capacity-fabric throughput
    (bytes/s) — a schedulable resource alongside capacity: concurrent
    offload-heavy jobs contend on it and the allocator admission-controls
    reservations (ROADMAP: tier-2 bandwidth, not just bytes).
    """

    id: int
    capacity: float               # bytes
    bandwidth: float = 0.0        # bytes/s sustainable on the CXL.io path


@dataclass(frozen=True)
class Inventory:
    """The composable estate: pods + inter-pod fabric + tier-2 nodes.

    ``interconnect`` selects the inter-pod technology: ``"scalepool"``
    (hierarchical CXL, tier-2 pool reachable) or ``"baseline"``
    (InfiniBand RDMA scale-out, no disaggregated memory pool — capacity
    beyond HBM must be scavenged from idle accelerators' HBM).
    """

    pods: Tuple[PodSpec, ...]
    memory_nodes: Tuple[MemoryNodeSpec, ...]
    inter_fabric: fb.FabricSpec           # pod-to-pod fabric (CXL or IB)
    tier2_fabric: Optional[fb.FabricSpec] # capacity fabric; None = baseline
    interconnect: str = "scalepool"   # scalepool | baseline | contention
    # shared spine -> capacity-switch trunk bandwidth (bytes/s) of the
    # routed estate graph; 0 = full bisection (sum of memory-node
    # bandwidths).  An oversubscribed trunk makes aggregate tier-2
    # bandwidth a *fabric* constraint the allocator admission-controls,
    # not just a per-node one.
    tier2_trunk_bw: float = 0.0

    # ---- sizes -----------------------------------------------------------
    @property
    def n_pods(self) -> int:
        return len(self.pods)

    @property
    def pod_size(self) -> int:
        return self.pods[0].n_accels if self.pods else 0

    @property
    def total_accels(self) -> int:
        return sum(p.n_accels for p in self.pods)

    @property
    def total_hbm(self) -> float:
        return sum(p.hbm_total for p in self.pods)

    @property
    def total_tier2(self) -> float:
        return sum(m.capacity for m in self.memory_nodes)

    @property
    def total_tier2_bw(self) -> float:
        return sum(m.bandwidth for m in self.memory_nodes)

    # ---- topology distance ----------------------------------------------
    @property
    def pods_per_leaf(self) -> int:
        """Pods sharing one leaf switch of the inter-pod fabric.  In a
        folded Clos, half the radix faces down; each pod consumes one
        downlink group."""
        return max(1, self.inter_fabric.topology.switch.radix // 2)

    def pod_hops(self, pod_a: int, pod_b: int) -> int:
        """Inter-pod switch traversals between two pods: 0 within a pod,
        1 through a shared leaf switch, full up-down path otherwise."""
        if pod_a == pod_b:
            return 0
        if pod_a // self.pods_per_leaf == pod_b // self.pods_per_leaf:
            return 1
        return self.inter_fabric.topology.hops()

    def span_hops(self, pod_ids: Iterable[int]) -> int:
        """Worst-case pairwise hop count across a set of pods — the
        quantity a topology-aware allocator minimizes."""
        ids = sorted(set(pod_ids))
        worst = 0
        for i, a in enumerate(ids):
            for b in ids[i + 1:]:
                worst = max(worst, self.pod_hops(a, b))
        return worst

    def leaf_of(self, pod_id: int) -> int:
        return pod_id // self.pods_per_leaf

    def topology(self, *, accels: bool = False):
        """The routed estate graph (``repro.fabric.Topology``): pods,
        CXL leaf/spine switch tiers, the capacity-fabric switch, and
        tier-2 memory nodes — the graph the allocator admission-
        controls ``tier2_bw`` reservations on and serving transports
        route transfers over."""
        from repro.fabric import Topology
        return Topology.from_inventory(self, accels=accels)

    def describe(self) -> str:
        t2 = (f"{self.total_tier2 / GB:.0f}GB tier-2 over "
              f"{len(self.memory_nodes)} nodes" if self.memory_nodes
              else "no tier-2 pool")
        return (f"{self.n_pods} pods x {self.pod_size} accels "
                f"({self.total_accels} total, "
                f"{self.total_hbm / GB:.0f}GB HBM), "
                f"inter={self.inter_fabric.name}, {t2}")


def build_inventory(
    *,
    n_pods: int = 4,
    pod_size: int = 72,
    hbm_per_accel_gb: float = 192.0,
    n_memory_nodes: int = 8,
    memory_node_gb: float = 4096.0,
    memory_node_gbps: Optional[float] = None,
    tier2_trunk_gbps: Optional[float] = None,
    interconnect: str = "scalepool",
    xlink: fb.LinkSpec = fb.NVLINK5,
) -> Inventory:
    """Construct an estate from the paper's hardware constants.

    Defaults mirror ``core.simulator.Calibration`` (72-accel NVL72-class
    pods, 192GB HBM) and §5's 4TB-class memory nodes.
    """
    pod_fabric = fb.xlink_cluster_fabric(pod_size, xlink)
    pods = tuple(PodSpec(i, pod_size, hbm_per_accel_gb * GB, pod_fabric)
                 for i in range(n_pods))
    n_endpoints = n_pods * pod_size
    if interconnect in ("scalepool", "contention"):
        # "contention" is the scalepool estate with overlap-aware
        # placement — the hardware is identical, only WHERE a gang
        # lands differs (repro.pool.allocator picks the policy up from
        # Inventory.interconnect)
        inter = fb.cxl_fabric(n_endpoints, link=fb.CXL_COHERENCE)
        tier2 = fb.tier2_memory_fabric(max(8, n_memory_nodes))
        # per-node sustainable bandwidth defaults to the capacity fabric's
        # effective large-message rate (CXL.io bulk path, §5)
        node_bw = (memory_node_gbps * GB if memory_node_gbps is not None
                   else tier2.bandwidth() * GB)
        nodes = tuple(MemoryNodeSpec(i, memory_node_gb * GB, node_bw)
                      for i in range(n_memory_nodes))
    elif interconnect == "baseline":
        inter = fb.infiniband_fabric(n_endpoints)
        tier2 = None
        nodes = ()   # RDMA era: no composable memory pool
    else:
        raise ValueError(f"unknown interconnect {interconnect!r}")
    return Inventory(pods=pods, memory_nodes=nodes, inter_fabric=inter,
                     tier2_fabric=tier2, interconnect=interconnect,
                     tier2_trunk_bw=(tier2_trunk_gbps * GB
                                     if tier2_trunk_gbps is not None else 0.0))

"""repro.serve — request-level serving engine over the XLink-CXL pool.

The serving API everything downstream builds on:

    api     — Request / RequestHandle / EngineConfig / ServeCostModel
    engine  — Engine: continuous batching + lease-budgeted KV tiering
    arbiter — PoolArbiter: N tenant engines share ONE physical page
              pool under revocable max-min fair shares
    trace   — arrival traces, the trace → engine driver, and the
              clock-interleaved multi-tenant driver

Quickstart::

    from repro.serve import Engine, EngineConfig, Request
    eng = Engine.local(model, EngineConfig(max_slots=4, max_seq=128))
    h = eng.submit(Request(prompt_tokens=(1, 2, 3), max_new_tokens=8))
    eng.run_until_idle()
    print(h.result(), eng.stats())

Lease-backed (the orchestrator composes capacity + KV budget)::

    lease = pool.lease("svc", 8, tier2_gb=256, kv_gb=64)
    eng = Engine.from_lease(model, lease, EngineConfig(max_slots=8))

Multi-tenant (N engines drawing on ONE shared page pool)::

    arb = PoolArbiter(tier1_pages=24, page_size=16)
    a = Engine.local(model, cfg, arbiter=arb, tenant="a")
    b = Engine.local(model, cfg, arbiter=arb, tenant="b")
    run_multi_trace([(a, trace_a), (b, trace_b)])
"""

from repro.core.tiering import KVBudget, KVBudgetExceeded, PagedKV
from repro.serve.api import (EngineConfig, Request, RequestHandle,
                             RequestStatus, ServeCostModel)
from repro.serve.arbiter import PoolArbiter
from repro.serve.engine import Engine, slice_page
from repro.serve.trace import (burst_trace, latency_summary, load_trace,
                               run_multi_trace, run_trace, synthetic_trace)

__all__ = [
    "Engine", "EngineConfig", "KVBudget", "KVBudgetExceeded", "PagedKV",
    "PoolArbiter", "Request", "RequestHandle", "RequestStatus",
    "ServeCostModel", "burst_trace", "latency_summary", "load_trace",
    "run_multi_trace", "run_trace", "slice_page", "synthetic_trace",
]

"""repro.serve — request-level serving engine over the XLink-CXL pool.

The serving API everything downstream (multi-tenant serving, fair-share
queueing, multi-host binding) builds on:

    api     — Request / RequestHandle / EngineConfig / ServeCostModel
    engine  — Engine: continuous batching + lease-budgeted KV tiering
    trace   — arrival traces and the trace → engine driver

Quickstart::

    from repro.serve import Engine, EngineConfig, Request
    eng = Engine.local(model, EngineConfig(max_slots=4, max_seq=128))
    h = eng.submit(Request(prompt_tokens=(1, 2, 3), max_new_tokens=8))
    eng.run_until_idle()
    print(h.result(), eng.stats())

Lease-backed (the orchestrator composes capacity + KV budget)::

    lease = pool.lease("svc", 8, tier2_gb=256, kv_gb=64)
    eng = Engine.from_lease(model, lease, EngineConfig(max_slots=8))
"""

from repro.core.tiering import KVBudget, KVBudgetExceeded, PagedKV
from repro.serve.api import (EngineConfig, Request, RequestHandle,
                             RequestStatus, ServeCostModel)
from repro.serve.engine import Engine
from repro.serve.trace import (burst_trace, latency_summary, load_trace,
                               run_trace, synthetic_trace)

__all__ = [
    "Engine", "EngineConfig", "KVBudget", "KVBudgetExceeded", "PagedKV",
    "Request", "RequestHandle", "RequestStatus", "ServeCostModel",
    "burst_trace", "latency_summary", "load_trace", "run_trace",
    "synthetic_trace",
]

"""Multi-tenant fair-share arbitration over ONE physical KV page pool.

The composability claim at serving granularity: several tenant
``Engine``s draw hot KV pages from a single shared device page pool
(and their cold pages from per-tenant slices of one tier-2 grant)
instead of carving the pool into static per-tenant partitions.  The
``PoolArbiter`` owns the shared free-page stack and the device pool
arrays; each tenant engine sees the pool through a ``_TenantKV`` view
whose *allowance* is a revocable *max-min fair share* over the live
tenants, not a fixed quota:

* **work conservation** — shares are demand-weighted (water-filling):
  a tenant wanting less than its equal split donates the surplus, and
  free pages beyond everyone's entitlement are usable by anybody, so a
  lone tenant gets the entire pool;
* **revocation** — when a tenant allocates under its share and the
  pool is dry, the arbiter evicts the coldest *paused* pages of the
  most-over-share tenant into that tenant's tier-2 budget (or drops a
  victim sequence for recompute when the budget is exhausted), and the
  swap seconds are charged to the *victim's* clock at its next step —
  an under-share tenant never pays for a hog's occupancy;
* **sharing incentive** — a tenant can always reclaim up to its share,
  so its latency is never worse than under a 1/N static partition
  (``benchmarks/fig9_multitenant.py`` asserts this end to end);
* **single-tenant transparency** — with one registered tenant the
  share is the whole pool, revocation never fires, and the engine's
  behavior is bit-identical to its private-``PagedKV`` path.

Tenants share only the *memory estate* (tier-1 pages + tier-2 bytes);
each engine keeps its own slots/compute and its own modeled clock —
the paper's disaggregation axis: memory composed across jobs, compute
leased per job.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import tiebreak
from repro.core.tiering import KVBudget, KVBudgetExceeded, PagedKV
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import CAT_ARBITER, resolve


class _TenantKV(PagedKV):
    """One tenant's view of the shared pool: the ``PagedKV`` interface
    the engine already speaks, but the free-page stack is the arbiter's
    (shared), ``allowance()`` is the tenant's live fair share, and a
    ``_take`` shortfall triggers cross-tenant revocation instead of
    failing."""

    def __init__(self, arbiter: "PoolArbiter", tenant: str,
                 tier2_bytes: float):
        # no super().__init__: the free stack belongs to the arbiter
        self.budget = KVBudget(tier1_pages=arbiter.num_pages,
                               tier2_bytes=tier2_bytes,
                               page_size=arbiter.page_size)
        self.page_bytes = float(arbiter.page_bytes)
        self.num_pages = arbiter.num_pages
        self._free = arbiter._free          # SHARED free-page stack
        self._seqs: Dict[Any, list] = {}
        self.spills = 0
        self.fetches = 0
        self._arbiter = arbiter
        self.tenant = tenant

    @property
    def hot_free(self) -> int:
        """Pages this tenant can obtain right now without evicting its
        own sequences: the shared free stack plus whatever its unmet
        share entitles it to revoke from over-share tenants."""
        return len(self._free) + self._arbiter.revocable_for(self.tenant)

    def allowance(self) -> int:
        return self._arbiter.allowance(self.tenant)

    def prepare(self, n_pages: int) -> None:
        if n_pages > len(self._free):
            self._arbiter.reclaim(self.tenant, n_pages)

    def _take(self, n: int, what: str) -> List[int]:
        if n > len(self._free):
            self._arbiter.reclaim(self.tenant, n)
        return super()._take(n, what)

    def residency(self) -> Dict[str, float]:
        r = super().residency()
        r["tier1_pages_used"] = self.hot_used()      # tenant, not pool
        # report the PHYSICAL free stack, not hot_free: the revocable
        # headroom folded into hot_free is resident in other tenants'
        # pages — claiming it as "free" would make free+used exceed the
        # quota on any dashboard
        r["tier1_pages_free"] = self.free_count
        r["tier1_pages_revocable"] = self._arbiter.revocable_for(self.tenant)
        r["tier1_pages_pool_used"] = self.num_pages - self.free_count
        r["tenant"] = self.tenant
        return r


@dataclasses.dataclass
class _Tenant:
    name: str
    engine: Any                     # repro.serve.Engine
    kv: _TenantKV
    charge_s: float = 0.0           # pending revocation swap-seconds
    charged_total_s: float = 0.0


class PoolArbiter:
    """Owns the shared device page pool and arbitrates it max-min
    fairly across tenant engines.  Construct with the pool geometry,
    then build each tenant with ``Engine.local(..., arbiter=arb,
    tenant="a")`` / ``Engine.from_lease(..., arbiter=arb, tenant="a")``
    — registration is implicit and the first tenant's cache shapes fix
    the pool's physical layout."""

    _TRACK = "pool:arbiter"

    def __init__(self, tier1_pages: int, *, page_size: int = 64,
                 tracer=None):
        if tier1_pages <= 0:
            raise ValueError("arbiter needs a positive tier-1 page quota")
        self.tracer = resolve(tracer)
        self.num_pages = int(tier1_pages)
        self.page_size = int(page_size)
        self.page_bytes = 0.0               # fixed at first registration
        # identical discipline to a private PagedKV: low ids pop first
        self._free: List[int] = list(range(self.num_pages - 1, -1, -1))
        self._tenants: Dict[str, _Tenant] = {}
        self.pool = None                    # shared device arrays (+trash)
        self._leaf_sig: Optional[Tuple] = None
        self.revoked_pages = 0              # pages evicted by revocation
        self.revocations = 0                # revocation episodes
        self.recompute_drops = 0            # victims dropped (no headroom)

    # ---- registration ----------------------------------------------------
    def register(self, tenant: str, engine, *, slot_shapes, page_bytes: float,
                 tier2_bytes: float = 0.0) -> _TenantKV:
        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} already registered")
        if engine.cfg.page_size != self.page_size:
            raise ValueError(
                f"tenant {tenant!r}: engine page_size "
                f"{engine.cfg.page_size} != arbiter page_size "
                f"{self.page_size} — one pool, one page geometry")
        sig = tuple(
            ((l.shape[0], self.page_size) + tuple(l.shape[3:]), l.dtype)
            for l in jax.tree.leaves(slot_shapes))
        if self.pool is None:
            self.page_bytes = float(page_bytes)
            self._leaf_sig = sig
            self.pool = jax.tree.map(
                lambda l: jnp.zeros(
                    (l.shape[0], self.num_pages + 1, self.page_size)
                    + l.shape[3:], l.dtype),
                slot_shapes)
        elif sig != self._leaf_sig:
            raise ValueError(
                f"tenant {tenant!r}: KV cache layout {sig} does not match "
                f"the shared pool's {self._leaf_sig} — tenants of one "
                f"physical pool must serve the same cache geometry")
        kv = _TenantKV(self, tenant, tier2_bytes)
        self._tenants[tenant] = _Tenant(tenant, engine, kv)
        if self.tracer.enabled and len(self._tenants) >= 2:
            # pool membership, re-announced per registration past the
            # first: the repro.analysis sanitizer switches its page
            # conservation check from per-engine to pool-wide on this
            # event.  Gated on >= 2 tenants so a lone tenant's traced
            # stream stays bit-identical to the private-pool path.
            # register() runs inside Engine.__init__ BEFORE the engine's
            # clock attribute exists, hence the getattr.
            self.tracer.instant(self._TRACK, "pool_tenants",
                                getattr(engine, "clock", 0.0),
                                cat=CAT_ARBITER, pages=self.num_pages,
                                tenants=sorted(self._tenants))
        return kv

    @property
    def tenants(self) -> Tuple[str, ...]:
        return tuple(self._tenants)

    # ---- fair shares -----------------------------------------------------
    def _shares(self) -> Dict[str, int]:
        """Max-min fair (water-filling) page shares over live tenants:
        equal split, with tenants demanding less than their level
        donating the surplus to the still-unsatisfied."""
        # registration-order enumeration is incidental: every decision
        # below reduces through sorted() or integer arithmetic, and the
        # racecheck seam permutes these builds to prove it
        demands = {n: min(t.engine._page_demand(), self.num_pages)
                   for n, t in tiebreak.order(self._tenants.items())}
        shares = {n: 0 for n in self._tenants}
        pending = {n: d for n, d in tiebreak.order(demands.items())
                   if d > 0}
        remaining = self.num_pages
        while pending:
            level = remaining // len(pending)
            sat = [n for n, d in tiebreak.order(pending.items())
                   if d <= level]
            if not sat:
                # nobody saturates at this level: split evenly, with the
                # integer remainder going one page each to the first
                # tenants in name order (deterministic) — flooring it
                # away would leave up to len(pending)-1 pages outside
                # every share, un-revocable by anyone
                rem = remaining - level * len(pending)
                for i, n in enumerate(sorted(pending)):
                    shares[n] = level + (1 if i < rem else 0)
                break
            for n in sorted(sat):
                shares[n] = pending.pop(n)
                remaining -= shares[n]
        return shares

    def _allowances(self) -> Dict[str, int]:
        """Share plus any free pages nobody else is entitled to — the
        quantity a tenant may keep *scheduled*.  Exceeding it is legal
        only until somebody under-share allocates (revocation)."""
        shares = self._shares()
        used = {n: t.kv.hot_used()
                for n, t in tiebreak.order(self._tenants.items())}
        free = len(self._free)
        out = {}
        for n in self._tenants:
            deficit = sum(max(0, shares[u] - used[u])
                          for u in self._tenants if u != n)
            out[n] = min(self.num_pages,
                         shares[n] + max(0, free - deficit))
        return out

    def allowance(self, tenant: str) -> int:
        return self._allowances()[tenant]

    def _evictable_over(self, allowances: Dict[str, int]) -> Dict[str, int]:
        """Per tenant: hot pages held beyond allowance that are actually
        revocable (pages of *paused* sequences — running rows are never
        yanked mid-decode)."""
        out = {}
        for n, t in tiebreak.order(self._tenants.items()):
            over = t.kv.hot_used() - allowances[n]
            if over <= 0:
                continue
            paused = sum(t.kv.hot_count(s.rid) for s in t.engine._paused
                         if t.kv.holds(s.rid))
            if paused > 0:
                out[n] = min(over, paused)
        return out

    def revocable_for(self, tenant: str) -> int:
        """Pages ``tenant`` could claim by revocation right now: capped
        by its own unmet share (an over-share tenant revokes nobody)."""
        allowances = self._allowances()
        deficit = allowances[tenant] - self._tenants[tenant].kv.hot_used()
        if deficit <= 0:
            return 0
        evictable = sum(v for n, v in
                        self._evictable_over(allowances).items()  # repro: allow(no-unordered-iteration) integer sum — exact and commutative in any order
                        if n != tenant)
        return min(deficit, evictable)

    # ---- revocation ------------------------------------------------------
    def reclaim(self, tenant: str, need: int) -> None:
        """Free pages until the shared stack holds ``need``, by evicting
        the coldest paused pages of the most-over-share tenant into ITS
        tier-2 budget (swap seconds charged to ITS clock), or dropping
        a victim sequence for recompute when it has no tier-2 headroom.
        ``tenant`` (the requester) pays nothing."""
        # deferred import: engine consumes this module (arbiter= arg)
        # but arbiter only needs engine's shared eviction helper —
        # importing here keeps the dependency one-way and lazy
        from repro.serve.engine import evict_pages

        allowances = self._allowances()     # frozen for this pass
        while len(self._free) < need:
            # victim selection is a TOTAL-order reduction — most pages
            # over share, ties to the lexicographically first tenant —
            # so the scan order over the tenant dict is provably
            # irrelevant (the racecheck seam permutes it).  The old
            # form (sorted scan + strict ``>``) encoded the same
            # tie-break implicitly in enumeration order; an unsorted
            # refactor of that scan would have silently changed victims
            cands = []
            for u, t in tiebreak.order(self._tenants.items()):
                if u == tenant:
                    continue
                over = t.kv.hot_used() - allowances[u]
                if over <= 0:
                    continue
                paused = [s for s in t.engine._paused
                          if t.kv.holds(s.rid) and t.kv.hot_count(s.rid) > 0]
                if not paused:
                    continue
                cands.append((over, u, t, paused))
            best = (min(cands, key=lambda c: (-c[0], c[1]))
                    if cands else None)
            if best is None:
                raise KVBudgetExceeded(
                    f"{tenant!r}: revocation cannot free "
                    f"{need - len(self._free)} more pages — no over-share "
                    f"tenant holds evictable (paused) pages")
            over, u, t, paused = best
            victim = min(paused,
                         key=lambda s: (s.last_sched, s.admit_seq))
            hot = t.kv.hot_logicals(victim.rid)
            k = min(need - len(self._free), over, len(hot),
                    t.kv.tier2_free_pages())
            if k <= 0:
                # no tier-2 headroom: page-granular spill impossible and
                # a partial prefix is useless — drop the victim's KV and
                # requeue it on ITS engine for re-prefill
                t.engine._drop_for_recompute(victim)
                self.recompute_drops += 1
                if self.tracer.enabled:
                    self.tracer.instant(self._TRACK, "recompute_drop",
                                        t.engine.clock, cat=CAT_ARBITER,
                                        victim=u, requester=tenant,
                                        rid=victim.rid, pages=len(hot))
                continue
            # the victim's pages ride ITS tier-2 route: register the
            # transfer on the victim engine's transport at its clock
            # (the charge lands on its next step via take_charge), so
            # on a shared fabric even revocation traffic contends
            cost = evict_pages(self.pool, t.kv, victim, hot[:k],
                               t.engine, t.engine.clock)
            t.charge_s += cost
            t.charged_total_s += cost
            self.revoked_pages += k
            self.revocations += 1
            if self.tracer.enabled:
                self.tracer.instant(self._TRACK, "revoke",
                                    t.engine.clock, cat=CAT_ARBITER,
                                    victim=u, requester=tenant, pages=k,
                                    rid=victim.rid, cost_s=cost)
                # counter lanes on the arbiter row: the post-revocation
                # fair shares.  Emitted only on revocation episodes (a
                # lone tenant never revokes), so single-tenant traced
                # runs stay bit-identical to the private-pool path.
                for n, allow in sorted(self._allowances().items()):
                    self.tracer.counter(self._TRACK, f"allowance:{n}",
                                        t.engine.clock, float(allow),
                                        cat=CAT_ARBITER)

    def take_charge(self, tenant: str) -> float:
        """Collect (and clear) the swap seconds revocation charged to
        ``tenant`` since its last step — added to that step's dt so the
        victim's own event clocks absorb the traffic it caused."""
        t = self._tenants[tenant]
        dt, t.charge_s = t.charge_s, 0.0
        if dt > 0.0 and self.tracer.enabled:
            self.tracer.instant(self._TRACK, "charge", t.engine.clock,
                                cat=CAT_ARBITER, tenant=tenant, cost_s=dt)
        return dt

    # ---- observability ---------------------------------------------------
    _STATS_KEYS = ("tier1_pages_quota", "tier1_pages_free", "revoked_pages",
                   "revocations", "recompute_drops")
    _TENANT_KEYS = ("hot_used", "cold_pages", "share", "allowance",
                    "demand", "spills", "fetches", "revocation_charged_s")

    def metrics(self, registry: Optional[MetricsRegistry] = None,
                prefix: str = "arbiter") -> MetricsRegistry:
        """Fill (and return) a ``repro.obs`` metrics registry with the
        pool-wide and per-tenant arbitration state under
        ``arbiter/...``; ``stats()`` is a thin adapter over it."""
        reg = registry if registry is not None else MetricsRegistry()
        allowances = self._allowances()
        shares = self._shares()
        reg.set(f"{prefix}/tier1_pages_quota", self.num_pages)
        reg.set(f"{prefix}/tier1_pages_free", len(self._free))
        reg.set(f"{prefix}/revoked_pages", self.revoked_pages)
        reg.set(f"{prefix}/revocations", self.revocations)
        reg.set(f"{prefix}/recompute_drops", self.recompute_drops)
        for n, t in sorted(self._tenants.items()):
            tp = f"{prefix}/tenant/{n}"
            reg.set(f"{tp}/hot_used", t.kv.hot_used())
            reg.set(f"{tp}/cold_pages", t.kv.cold_pages_used)
            reg.set(f"{tp}/share", shares[n])
            reg.set(f"{tp}/allowance", allowances[n])
            reg.set(f"{tp}/demand", t.engine._page_demand())
            reg.set(f"{tp}/spills", t.kv.spills)
            reg.set(f"{tp}/fetches", t.kv.fetches)
            reg.set(f"{tp}/revocation_charged_s", t.charged_total_s)
        return reg

    def stats(self) -> Dict[str, Any]:
        """Legacy nested dict, adapted off the ``metrics()`` registry."""
        snap = self.metrics().snapshot("arbiter/")
        out: Dict[str, Any] = {k: snap[f"arbiter/{k}"]
                               for k in self._STATS_KEYS}
        out["tenants"] = {
            n: {k: snap[f"arbiter/tenant/{n}/{k}"]
                for k in self._TENANT_KEYS}
            for n in sorted(self._tenants)
        }
        return out

"""Request-level serving API types (paper §6, Fig. 7 at request granularity).

A ``Request`` is what a client submits; a ``RequestHandle`` is the
engine's live view of it (status, generated tokens, latency clocks).
``EngineConfig`` sizes the slot array and page geometry; ``ServeCostModel``
prices engine events in *modeled* seconds from the paper's fabric
constants, so latency sweeps are hardware-derived rather than CPU-smoke
wall-clock noise.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, List, Optional, Sequence, Tuple

from repro.core import fabric as fb

GB = 1e9


class RequestStatus(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    SWAPPED = "swapped"        # descheduled under page pressure; its KV
                               # pages are evictable (coldest-first) to
                               # the tier-2 capacity pool
    DONE = "done"
    FAILED_OOM = "failed_oom"  # can never fit the tier-1 page quota


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request: a prompt plus a decode budget."""

    prompt_tokens: Tuple[int, ...]
    max_new_tokens: int
    arrival_time: float = 0.0          # modeled seconds (trace-driven)

    def __post_init__(self):
        object.__setattr__(self, "prompt_tokens",
                           tuple(int(t) for t in self.prompt_tokens))
        if len(self.prompt_tokens) == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return len(self.prompt_tokens)


@dataclasses.dataclass
class RequestHandle:
    """Live engine-side state of a submitted request."""

    rid: int
    request: Request
    status: RequestStatus = RequestStatus.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    submit_clock: float = 0.0
    first_token_clock: Optional[float] = None
    done_clock: Optional[float] = None
    preempts: int = 0                  # descheduled under page pressure
                                       # (costless until pages actually move)
    swaps: int = 0                     # tier-2 spill episodes: batches of
                                       # this request's pages that really
                                       # rode the capacity fabric
    recomputes: int = 0                # KV dropped + re-prefilled (no
                                       # tier-2 headroom to spill into)
    kv_transit_s: float = 0.0          # modeled seconds this request's KV
                                       # pages spent in flight on the fabric
                                       # (disaggregated prefill->decode
                                       # handoff; 0.0 when colocated)

    @property
    def done(self) -> bool:
        return self.status in (RequestStatus.DONE, RequestStatus.FAILED_OOM)

    @property
    def latency(self) -> Optional[float]:
        return (None if self.done_clock is None
                else self.done_clock - self.submit_clock)

    @property
    def ttft(self) -> Optional[float]:
        return (None if self.first_token_clock is None
                else self.first_token_clock - self.submit_clock)

    def result(self) -> List[int]:
        if self.status is RequestStatus.FAILED_OOM:
            raise RuntimeError(f"request {self.rid} failed: tier-1 KV quota "
                               f"cannot ever hold it")
        if not self.done:
            raise RuntimeError(f"request {self.rid} still {self.status.value}")
        return list(self.tokens)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Slot-array and page geometry of the engine."""

    max_slots: int = 4                 # concurrent decode slots
    max_seq: int = 256                 # per-slot KV capacity (tokens)
    page_size: int = 64                # tokens per KV page
    cache_dtype: Any = "float32"       # jnp dtype name or dtype
    eos_token: Optional[int] = None    # early stop (None = run to budget)
    # classic tier-1-only serving: reserve a request's full-lifetime KV at
    # admission (no growth, no preemption risk).  Safe without a spill
    # target, but concurrency collapses to quota // lifetime_pages — the
    # static alternative optimistic paging + tier-2 swap relieves.
    reserve_lifetime: bool = False

    @property
    def pages_per_slot(self) -> int:
        return -(-self.max_seq // self.page_size)


@dataclasses.dataclass(frozen=True)
class ServeCostModel:
    """Modeled event costs (seconds).  Defaults derive from the paper's
    hardware constants: decode steps are weight-read bound on HBM, swap
    traffic rides the capacity-oriented CXL fabric (§5).

    Transfer pricing note: the tier-2 constants here are a *facade*
    over a degenerate 1-link ``repro.fabric`` route — ``transport()``
    builds the equivalent ``Transport``, and a solo transfer on it
    costs exactly ``swap_s(nbytes)``.  Engines charge spill/fetch
    through a transport, so several consumers of one fabric genuinely
    contend; an engine constructed without an explicit
    ``transport=``/``route=`` gets a private degenerate one from this
    model and reproduces the legacy numbers bit-exactly.
    """

    prefill_s_per_token: float = 2e-5
    decode_s_per_step: float = 2e-3    # batched step, weight-bound floor
    decode_s_per_token: float = 5e-5   # marginal per resident sequence
    tier2_bw: float = 0.0              # bytes/s, 0 = derive from fabric
    tier2_lat: float = 0.0             # per-transfer setup latency

    @staticmethod
    def from_fabric(n_param_bytes: float,
                    hbm_bw: float = 8000.0 * GB,
                    tier2: Optional[fb.FabricSpec] = None) -> "ServeCostModel":
        """DEPRECATED (kept working): collapses the whole tier-2 fabric
        into two scalars, so every consumer prices as if it had the
        fabric to itself.  Migration: keep the compute-side constants,
        but share one ``repro.fabric.Transport`` across consumers —
        build ``Topology.from_inventory(pool_inventory)`` (or any
        explicit graph), take per-consumer ``topology.route(...)``s,
        and pass ``Engine(..., transport=, route=)`` so concurrent
        transfers fair-share the actual links."""
        t2 = tier2 or fb.tier2_memory_fabric(8)
        return ServeCostModel(
            prefill_s_per_token=max(1e-6, n_param_bytes / hbm_bw / 8),
            decode_s_per_step=max(1e-5, n_param_bytes / hbm_bw),
            decode_s_per_token=max(1e-6, n_param_bytes / hbm_bw / 32),
            tier2_bw=t2.bandwidth() * GB,
            tier2_lat=t2.latency())

    def resolved_tier2_bw(self) -> float:
        """The swap bandwidth actually priced (bytes/s)."""
        return self.tier2_bw or fb.tier2_memory_fabric(8).bandwidth() * GB

    def degenerate_topology(self):
        """The 1-link ``repro.fabric.Topology`` equivalent to this
        model's tier-2 scalars (route ``"src" -> "dst"``)."""
        from repro.fabric import Topology
        return Topology.degenerate(self.resolved_tier2_bw(), self.tier2_lat,
                                   name="ServeCostModel[tier2]")

    def transport(self):
        """A private ``Transport`` over ``degenerate_topology()`` — the
        facade engines fall back to when no shared fabric is passed."""
        from repro.fabric import Transport
        return Transport(self.degenerate_topology())

    def swap_s(self, nbytes: float) -> float:
        """Solo transfer seconds on the degenerate route (legacy name).
        A transport-routed transfer with no concurrent flows returns
        this exact float."""
        return self.tier2_lat + nbytes / self.resolved_tier2_bw()

    def prefill_s(self, n_tokens: int) -> float:
        return self.prefill_s_per_token * n_tokens

    def decode_s(self, n_resident: int) -> float:
        return self.decode_s_per_step + self.decode_s_per_token * n_resident

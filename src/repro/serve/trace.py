"""Request-arrival traces and the trace → engine driver.

Traces are deterministic (seeded numpy), expressed in *modeled* seconds
— the same clock the engine's ``ServeCostModel`` advances — so a trace
run is exactly reproducible across hosts and arrival interleavings.
"""

from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.analysis import tiebreak
from repro.serve.api import Request, RequestHandle


def synthetic_trace(n_requests: int, *,
                    mean_interarrival_s: float = 0.05,
                    prompt_lens: Sequence[int] = (16, 32, 64),
                    max_new_tokens: int = 16,
                    vocab: int = 256,
                    seed: int = 0) -> List[Request]:
    """Poisson-ish arrivals, cycling prompt lengths, random token ids."""
    rng = np.random.RandomState(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        t += float(rng.exponential(mean_interarrival_s))
        plen = prompt_lens[i % len(prompt_lens)]
        prompt = rng.randint(1, vocab, size=plen).tolist()
        out.append(Request(prompt_tokens=tuple(prompt),
                           max_new_tokens=max_new_tokens,
                           arrival_time=t))
    return out


def burst_trace(n_requests: int, *, prompt_len: int = 32,
                max_new_tokens: int = 32, vocab: int = 256,
                seed: int = 0) -> List[Request]:
    """Everything arrives at t=0 — the heaviest contention shape."""
    rng = np.random.RandomState(seed)
    return [Request(tuple(rng.randint(1, vocab, size=prompt_len).tolist()),
                    max_new_tokens, arrival_time=0.0)
            for _ in range(n_requests)]


def load_trace(path: str, *, vocab: Optional[int] = None) -> List[Request]:
    """JSONL: {"prompt_tokens": [...], "max_new_tokens": n, "arrival_time": t}.

    Pass ``vocab`` to validate token ids at load time: an id >= vocab
    would be silently *clamped* by JAX's out-of-bounds gather semantics
    (wrong embedding, wrong completion, no error), so a bad trace line
    raises here with its line number instead.  ``Engine.submit``
    re-validates as a backstop.
    """
    out = []
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            toks = tuple(int(t) for t in d["prompt_tokens"])
            if vocab is not None:
                bad = [t for t in toks if not 0 <= t < vocab]
                if bad:
                    raise ValueError(
                        f"{path}:{lineno}: prompt token id {bad[0]} outside "
                        f"the model vocab [0, {vocab})")
            out.append(Request(toks, int(d["max_new_tokens"]),
                               float(d.get("arrival_time", 0.0))))
    return out


def run_trace(engine, trace: Sequence[Request], *,
              max_steps: int = 200_000) -> List[RequestHandle]:
    """Feed arrivals as modeled time passes; step until drained."""
    pending = sorted(trace, key=lambda r: r.arrival_time)
    handles: List[RequestHandle] = []
    i = 0
    for _ in range(max_steps):
        while i < len(pending) and pending[i].arrival_time <= engine.clock:
            handles.append(engine.submit(pending[i]))
            i += 1
        if engine.idle:
            if i >= len(pending):
                return handles
            engine.advance_clock(pending[i].arrival_time)
            continue
        engine.step()
    raise RuntimeError(f"trace not drained after {max_steps} steps")


def run_multi_trace(pairs, *, max_steps: int = 1_000_000
                    ) -> List[List[RequestHandle]]:
    """Drive several engines — typically tenants of one ``PoolArbiter``
    — over per-engine arrival traces, interleaved by modeled clock.

    Each round the engine with the earliest next event (its clock if it
    has work, else its next arrival) steps once; arrivals are fed when
    that engine's clock reaches them.  An engine whose step makes no
    modeled progress (blocked on pages another tenant holds) has its
    clock synced forward to the next other-engine event — waiting costs
    the blocked tenant wall-clock — and is skipped until some tenant
    progresses; if every engine is blocked at once, that is a genuine
    cross-tenant deadlock and we raise rather than spin.

    Returns one handle list per (engine, trace) pair, in order.
    """
    state = [[eng, sorted(tr, key=lambda r: r.arrival_time), 0, []]
             for eng, tr in pairs]
    blocked: set = set()
    for _ in range(max_steps):
        for st in state:
            eng, pend = st[0], st[1]
            while st[2] < len(pend) \
                    and pend[st[2]].arrival_time <= eng.clock:
                st[3].append(eng.submit(pend[st[2]]))
                st[2] += 1
        cands = []
        for j, (eng, pend, i, _) in enumerate(state):
            if not eng.idle:
                cands.append((eng.clock, j))
            elif i < len(pend):
                cands.append((pend[i].arrival_time, j))
        if not cands:
            return [st[3] for st in state]
        live = [c for c in cands if c[1] not in blocked]
        if not live:
            raise RuntimeError(
                "multi-tenant deadlock: every engine is blocked on pages "
                "another tenant holds")
        # candidate-list construction order is incidental: selection is
        # a total-order min over (clock, engine index) — equal clocks
        # break by index (the spec'd interleave), and the racecheck
        # seam permutes the list to prove nothing else leaks in
        t, j = min(tiebreak.order(live))
        eng, pend = state[j][0], state[j][1]
        if eng.idle:
            eng.advance_clock(t)
            while state[j][2] < len(pend) \
                    and pend[state[j][2]].arrival_time <= eng.clock:
                state[j][3].append(eng.submit(pend[state[j][2]]))
                state[j][2] += 1
        before = eng.clock
        dt = eng.step()
        if dt > 0.0 or eng.idle or eng.clock != before:  # repro: allow(no-float-equality) identity test — did step() assign a new clock value at all, not a time comparison
            blocked.clear()
        else:
            others = [c[0] for c in cands if c[1] != j]
            if others:
                eng.advance_clock(min(others))
            blocked.add(j)
    raise RuntimeError(f"multi-tenant traces not drained after "
                       f"{max_steps} steps")


def latency_summary(handles: Sequence[RequestHandle]) -> Dict[str, float]:
    """Nearest-rank percentiles (ceil(p*n) - 1 into the sorted sample):
    the p-th percentile is the smallest observation covering at least a
    p fraction of the sample.  The old ``int(p * n)`` indexing biased a
    rank high — for n = 2 it reported the *max* as the median."""
    lats = sorted(h.latency for h in handles if h.latency is not None
                  and h.status.value == "done")
    if not lats:
        return {"n": 0, "p50_s": float("inf"), "p95_s": float("inf"),
                "mean_s": float("inf")}
    pct = lambda p: lats[max(0, math.ceil(p * len(lats)) - 1)]
    return {"n": len(lats), "p50_s": pct(0.50), "p95_s": pct(0.95),
            "mean_s": sum(lats) / len(lats)}

"""Request-arrival traces and the trace → engine driver.

Traces are deterministic (seeded numpy), expressed in *modeled* seconds
— the same clock the engine's ``ServeCostModel`` advances — so a trace
run is exactly reproducible across hosts and arrival interleavings.
"""

from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.api import Request, RequestHandle


def synthetic_trace(n_requests: int, *,
                    mean_interarrival_s: float = 0.05,
                    prompt_lens: Sequence[int] = (16, 32, 64),
                    max_new_tokens: int = 16,
                    vocab: int = 256,
                    seed: int = 0) -> List[Request]:
    """Poisson-ish arrivals, cycling prompt lengths, random token ids."""
    rng = np.random.RandomState(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        t += float(rng.exponential(mean_interarrival_s))
        plen = prompt_lens[i % len(prompt_lens)]
        prompt = rng.randint(1, vocab, size=plen).tolist()
        out.append(Request(prompt_tokens=tuple(prompt),
                           max_new_tokens=max_new_tokens,
                           arrival_time=t))
    return out


def burst_trace(n_requests: int, *, prompt_len: int = 32,
                max_new_tokens: int = 32, vocab: int = 256,
                seed: int = 0) -> List[Request]:
    """Everything arrives at t=0 — the heaviest contention shape."""
    rng = np.random.RandomState(seed)
    return [Request(tuple(rng.randint(1, vocab, size=prompt_len).tolist()),
                    max_new_tokens, arrival_time=0.0)
            for _ in range(n_requests)]


def load_trace(path: str, *, vocab: Optional[int] = None) -> List[Request]:
    """JSONL: {"prompt_tokens": [...], "max_new_tokens": n, "arrival_time": t}.

    Pass ``vocab`` to validate token ids at load time: an id >= vocab
    would be silently *clamped* by JAX's out-of-bounds gather semantics
    (wrong embedding, wrong completion, no error), so a bad trace line
    raises here with its line number instead.  ``Engine.submit``
    re-validates as a backstop.
    """
    out = []
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            toks = tuple(int(t) for t in d["prompt_tokens"])
            if vocab is not None:
                bad = [t for t in toks if not 0 <= t < vocab]
                if bad:
                    raise ValueError(
                        f"{path}:{lineno}: prompt token id {bad[0]} outside "
                        f"the model vocab [0, {vocab})")
            out.append(Request(toks, int(d["max_new_tokens"]),
                               float(d.get("arrival_time", 0.0))))
    return out


def run_trace(engine, trace: Sequence[Request], *,
              max_steps: int = 200_000) -> List[RequestHandle]:
    """Feed arrivals as modeled time passes; step until drained."""
    pending = sorted(trace, key=lambda r: r.arrival_time)
    handles: List[RequestHandle] = []
    i = 0
    for _ in range(max_steps):
        while i < len(pending) and pending[i].arrival_time <= engine.clock:
            handles.append(engine.submit(pending[i]))
            i += 1
        if engine.idle:
            if i >= len(pending):
                return handles
            engine.advance_clock(pending[i].arrival_time)
            continue
        engine.step()
    raise RuntimeError(f"trace not drained after {max_steps} steps")


def latency_summary(handles: Sequence[RequestHandle]) -> Dict[str, float]:
    """Nearest-rank percentiles (ceil(p*n) - 1 into the sorted sample):
    the p-th percentile is the smallest observation covering at least a
    p fraction of the sample.  The old ``int(p * n)`` indexing biased a
    rank high — for n = 2 it reported the *max* as the median."""
    lats = sorted(h.latency for h in handles if h.latency is not None
                  and h.status.value == "done")
    if not lats:
        return {"n": 0, "p50_s": float("inf"), "p95_s": float("inf"),
                "mean_s": float("inf")}
    pct = lambda p: lats[max(0, math.ceil(p * len(lats)) - 1)]
    return {"n": len(lats), "p50_s": pct(0.50), "p95_s": pct(0.95),
            "mean_s": sum(lats) / len(lats)}

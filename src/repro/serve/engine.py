"""Request-level continuous-batching engine over a *physically paged*,
budgeted KV pool.

The serving counterpart of ``runtime.train``: one ``Engine`` owns a
shared device-side KV **page pool** (``KVBudget.tier1_pages`` physical
pages of ``page_size`` tokens, plus one trash page that absorbs idle
rows' writes), a slot array of decode rows, and a per-row page table
(``int32[max_slots, pages_per_slot]``) mapping each sequence's logical
pages onto arbitrary physical pages.  Decode is ONE batched call into
the model's paged path: the Pallas paged-attention kernel gathers K/V
through the page table, so a sequence needs neither contiguous pages
nor a reserved slab — the PR-2 contiguous-slot residency ceiling is
gone.

Scheduling per ``step()``:

* pressure relief: if the running rows' next-token page demand exceeds
  the pool, the newest-admitted rows are *paused* (descheduled — their
  pages stay hot until somebody needs them: lazy, page-granular
  eviction).  Growth allocations then evict the **coldest pages**
  (least-recently-scheduled paused sequence first; within it the
  oldest-written, lowest-logical pages first) to the tier-2 cold store
  over the capacity fabric — or, with no tier-2 byte headroom, drop the
  victim's KV entirely and requeue it for re-prefill (the recompute
  storm the paper's Fig. 7 tier-2 relief avoids);
* swap-in: paused sequences re-enter in pause order (oldest first —
  insertion-ordered, no re-sorting); only their *cold* pages ride the
  fabric back, into whatever physical pages are free — resuming a
  sequence whose pages were never evicted costs nothing;
* admission: FIFO prefill, padded to a power-of-two page-aligned
  *bucket* (one XLA program per bucket, not per prompt length) with the
  next-token logits read at the last real position;
* decode: every running row advances one token in a single jitted call.

Every event clock is attributed to the event's **modeled completion
time** (``engine.clock`` at step start + modeled seconds elapsed within
the step), so TTFT/latency are consistent across prefill, decode, swap
and OOM paths.

Each row is an independent batch entry of one fused program and the
page table fully determines what it attends to, so output is identical
for any arrival interleaving, any physical page layout, and for
lease-backed vs local construction (the engine's determinism contract,
enforced by tests).

Time is *modeled*: a ``ServeCostModel`` prices prefill/decode events
from the paper's fabric constants, and page-swap traffic is charged
through a ``repro.fabric.Transport`` (pass ``transport=``/``route=``
to put several engines on one shared routed fabric, where concurrent
transfers fair-share each link's bandwidth — the contention the
paper's shared CXL hierarchy implies).  Without an explicit transport
the engine owns a private degenerate 1-link one derived from the cost
model, reproducing the legacy ``swap_s`` scalars bit-exactly.

Multi-tenant: passing ``arbiter=``/``tenant=`` joins a shared
``repro.serve.PoolArbiter`` page pool instead of owning a private one —
``self.kv`` becomes the tenant's fair-share view (same interface), the
pool arrays live on the arbiter, and ``allowance()`` (the live max-min
share) replaces the fixed quota in the pressure/resume decisions.  A
lone tenant's allowance is the whole pool, so single-tenant behavior is
bit-identical to the private path.
"""

from __future__ import annotations

import contextlib
import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiering import KVBudget, KVBudgetExceeded, PagedKV
from repro.models.api import Model
from repro.models.config import ShapeConfig
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import CAT_ENGINE, CAT_KV, CAT_REQUEST, resolve
from repro.serve.api import (EngineConfig, Request, RequestHandle,
                             RequestStatus, ServeCostModel)


def _dtype(d):
    return jnp.dtype(d) if not isinstance(d, str) else {
        "float32": jnp.float32, "bfloat16": jnp.bfloat16,
        "float16": jnp.float16}[d]


def _pow2_buckets(start: int, cap: int) -> List[int]:
    """Doubling sizes from ``start`` up to (and always including) ``cap``."""
    out: List[int] = []
    b = start
    while b < cap:
        out.append(b)
        b *= 2
    out.append(cap)
    return out


def evict_pages(pool, kv, st, logicals, engine, t) -> float:
    """Spill one batch of ``st``'s hot logical pages to ``kv``'s tier-2
    cold store: gather the physical pages from the device pool (one
    bulk copy), evict each, and record one swap episode on the handle.
    The bulk transfer is registered with ``engine``'s transport at
    modeled time ``t`` (so concurrent tenants on a shared fabric
    contend); returns the modeled swap seconds — the caller decides
    whose clock absorbs them (the engine's own step dt, or the victim
    tenant's revocation charge).  Shared by ``Engine._evict_or_drop``
    and ``PoolArbiter.reclaim`` so the two eviction paths cannot
    diverge."""
    table = kv.page_table(st.rid)
    idx = jnp.asarray(np.asarray([table[lp] for lp in logicals], np.int32))
    gathered = jax.tree.map(lambda l: np.asarray(l[:, idx]), pool)
    for i, lp in enumerate(logicals):
        kv.evict(st.rid, lp, jax.tree.map(lambda g, i=i: g[:, i], gathered))
    st.handle.swaps += 1        # one spill episode: len(logicals) pages,
                                # one bulk transfer over the capacity fabric
    cost = engine.charge_tier2(len(logicals) * kv.page_bytes, t)
    if engine.tracer.enabled:
        engine.tracer.span(engine._track, "spill", t, cost, cat=CAT_KV,
                           rid=st.rid, pages=len(logicals),
                           bytes=len(logicals) * kv.page_bytes)
    return cost


def slice_page(cache, i: int, page_size: int):
    """Payload of logical page ``i`` of a dense ``(layers, 1, seq, ...)``
    prefill cache: a tree of ``(layers, page_size, ...)`` leaves — the
    same per-page shape ``PagedKV.evict``/``fetch`` payloads use, so a
    page sliced here can be spilled to tier-2, streamed over the fabric
    (``repro.disagg``) or scattered with ``Engine._write_page``
    interchangeably."""
    def cut(cache_leaf):
        lay = cache_leaf.shape[0]
        tail = tuple(cache_leaf.shape[3:])
        return cache_leaf[:, 0].reshape((lay, -1, page_size) + tail)[:, i]
    return jax.tree.map(cut, cache)


@dataclasses.dataclass(eq=False)        # identity semantics: these live in
class _SlotState:                        # queues/sets and are never "equal"
    """Host-side bookkeeping for one in-flight request."""

    handle: RequestHandle
    index: int = 0                 # next KV write position (= current length)
    cur_tok: int = 0               # last emitted token (decode input)
    slot: Optional[int] = None     # row in the slot array, None when off
    admit_seq: int = -1            # admission order (pressure pauses
                                   # newest-admitted rows first)
    last_sched: int = -1           # step() count of the last decode — the
                                   # page-coldness signal for eviction
    ready_at: float = 0.0          # modeled completion time of the LAST
                                   # in-flight KV page (disaggregated
                                   # handoff); decode never schedules the
                                   # row before it.  0.0 == colocated.
    on_first_decode: Optional[Any] = None   # one-shot callback fired with
                                   # the modeled time of the row's first
                                   # decode (the disagg handoff_use event)

    @property
    def rid(self) -> int:
        return self.handle.rid

    @property
    def request(self) -> Request:
        return self.handle.request

    def effective_prompt(self) -> Tuple[int, ...]:
        """Prompt for (re-)prefill: original prompt plus everything
        already generated (the recompute-preemption continuation)."""
        return self.request.prompt_tokens + tuple(self.handle.tokens)

    @property
    def target_len(self) -> int:
        return self.request.prompt_len + self.request.max_new_tokens


@dataclasses.dataclass(eq=False)
class _Handoff:
    """One externally-prefilled sequence waiting for decode-side
    admission (``Engine.submit_prefilled``): the per-page payloads in
    flight over the fabric plus the modeled arrival gates."""

    state: _SlotState
    pages: List[Any]               # slice_page payloads, logical order
    page_ready: List[float]        # modeled transfer completion per page
    admit_at: float                # gate: first min_ready pages landed
    ready_at: float                # gate: ALL pages landed (decode start)


class Engine:
    """Continuous-batching serving engine.  Build with ``Engine.local``
    (explicit config) or ``Engine.from_lease`` (a ``repro.pool`` lease
    supplies the mesh, sharding rules, and the tier-2 KV byte budget)."""

    def __init__(self, model: Model, params, cfg: EngineConfig, *,
                 budget: Optional[KVBudget] = None,
                 cost_model: Optional[ServeCostModel] = None,
                 mesh=None, rules=None,
                 arbiter=None, tenant: Optional[str] = None,
                 transport=None, route=None, tracer=None):
        if model.cfg.family == "encdec":
            raise NotImplementedError(
                "Engine drives decoder-style models; encdec serving still "
                "goes through runtime.serve step factories")
        if not model.supports_paged_kv:
            raise NotImplementedError(
                f"Engine serves through the paged decode kernel, which "
                f"{model.cfg.family!r} does not implement yet (ssm keeps "
                f"an O(1) recurrent state with nothing to page; hybrid "
                f"interleaves recurrent state with its KV layers) — use "
                f"the runtime.serve step factories for this family")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.mesh, self.rules = mesh, rules
        # tier-2 transfer routing: a shared repro.fabric Transport (+
        # this engine's route on it) makes concurrent tenants contend
        # on the actual links; without one, the engine owns a private
        # degenerate 1-link transport derived from its cost model —
        # pricing identical (bit-exact) to the legacy swap_s scalars
        if (transport is None) != (route is None):
            raise ValueError("pass transport= and route= together")
        self._transport = transport
        self._transport_owned = transport is None
        self.route = route
        # flight recorder: defaults to the shared transport's tracer
        # (one recorder per fabric domain), else the zero-cost null
        self.tracer = resolve(tracer if tracer is not None
                              else getattr(transport, "tracer", None))
        self.cost = cost_model or ServeCostModel.from_fabric(
            2.0 * model.cfg.param_count())

        dt = _dtype(cfg.cache_dtype)
        self._cache_dtype = dt
        slot_shapes = jax.eval_shape(
            lambda: model.init_cache(1, cfg.max_seq, dtype=dt))
        for leaf in jax.tree.leaves(slot_shapes):
            if len(leaf.shape) < 3 or leaf.shape[1] != 1 \
                    or leaf.shape[2] != cfg.max_seq:
                raise NotImplementedError(
                    f"paged serving expects (layers, batch=1, seq, ...) "
                    f"KV cache leaves, got {leaf.shape}")
        slot_bytes = sum(l.size * l.dtype.itemsize
                         for l in jax.tree.leaves(slot_shapes))
        page_bytes = slot_bytes * cfg.page_size / max(1, cfg.max_seq)
        self.slot_bytes = float(slot_bytes)

        full = budget or KVBudget(page_size=cfg.page_size)
        self.arbiter = arbiter
        self.tenant = tenant
        self._pool_store = None
        if arbiter is not None:
            # multi-tenant: the arbiter owns the physical pool; this
            # engine's tier-1 "quota" is the whole pool, but its live
            # allowance is a revocable max-min fair share.
            if self.tenant is None:
                self.tenant = f"tenant-{len(arbiter.tenants)}"
            self.budget = KVBudget(tier1_pages=arbiter.num_pages,
                                   tier2_bytes=full.tier2_bytes,
                                   page_size=cfg.page_size)
            self.kv = arbiter.register(self.tenant, self,
                                       slot_shapes=slot_shapes,
                                       page_bytes=page_bytes,
                                       tier2_bytes=full.tier2_bytes)
        else:
            tier1 = (full.tier1_pages if full.tier1_pages is not None
                     else cfg.max_slots * cfg.pages_per_slot)
            self.budget = KVBudget(tier1_pages=tier1,
                                   tier2_bytes=full.tier2_bytes,
                                   page_size=cfg.page_size)
            self.kv = PagedKV(self.budget, page_bytes)

        # shared physical page pool: leaf (layers, num_pages + 1, page,
        # ...).  The extra page (id == num_pages) is the TRASH page: idle
        # rows' page tables point at it, so their decode writes land
        # somewhere harmless and their gathers stay in bounds.  Under an
        # arbiter the arrays live on the arbiter (ONE pool, N tenants)
        # and ``self._pool`` is a view through the property below.
        self._trash = self.kv.num_pages
        if arbiter is None:
            self._pool = jax.tree.map(
                lambda l: jnp.zeros(
                    (l.shape[0], self.kv.num_pages + 1, cfg.page_size)
                    + l.shape[3:], l.dtype),
                slot_shapes)
        self._table = np.full((cfg.max_slots, cfg.pages_per_slot),
                              self._trash, np.int32)
        self._lengths = np.zeros(cfg.max_slots, np.int32)
        self._slot_tok = np.zeros(cfg.max_slots, np.int32)
        self._slots: List[Optional[_SlotState]] = [None] * cfg.max_slots

        self._queue: deque = deque()     # _SlotState, FIFO (+recompute front)
        self._paused: deque = deque()    # insertion-ordered: pause order IS
                                         # the resume order (oldest first)
        self._handoffs: deque = deque()  # _Handoff, FIFO: externally
                                         # prefilled sequences whose KV is
                                         # still riding the fabric
        self.handles: Dict[int, RequestHandle] = {}
        self._next_rid = 0
        self._admit_seq = 0

        self.clock = 0.0
        self.steps = 0
        self.busy_s = 0.0          # sum of nonzero step() durations: the
                                   # throughput denominator that idle
                                   # inter-arrival gaps cannot dilute
        self._decoded_tokens = 0

        # prefill buckets: page-aligned powers of two capped at the slot
        # capacity — the jit program count is bounded by len(buckets),
        # not by the number of distinct prompt lengths in the trace
        self._buckets = _pow2_buckets(cfg.page_size,
                                      cfg.pages_per_slot * cfg.page_size)
        self._buckets_used: set = set()

        # decode row buckets: live rows are gathered into the smallest
        # power-of-two row count (capped at max_slots) before the paged
        # decode, so a near-empty engine decodes a 1- or 2-row batch
        # instead of all max_slots rows — compiled-program count stays
        # bounded by len(row buckets), not by occupancy histories
        self._row_buckets = _pow2_buckets(1, cfg.max_slots)
        self._row_buckets_used: set = set()

        self._prefill_jit = jax.jit(
            lambda p, batch, cache, last: model.prefill_at(
                p, batch, cache, last))
        self._prefill_fn = self._scoped(self._prefill_jit)

        def paged_decode(params, toks, pool, table, lengths):
            logits, new_pool = model.decode_paged(params, toks, pool,
                                                  table, lengths)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return nxt[:, None], new_pool

        self._decode_jit = jax.jit(paged_decode)
        self._decode_fn = self._scoped(self._decode_jit)

    @property
    def _track(self) -> str:
        """This engine's trace track (one timeline row per tenant)."""
        return f"engine:{self.tenant}" if self.tenant else "engine"

    # the physical page pool: private arrays for a solo engine, the
    # arbiter's shared arrays when multi-tenant (every tenant's prefill
    # scatter / decode write / swap round-trip hits the SAME pool)
    @property
    def _pool(self):
        return (self.arbiter.pool if self.arbiter is not None
                else self._pool_store)

    @_pool.setter
    def _pool(self, value):
        if self.arbiter is not None:
            self.arbiter.pool = value
        else:
            self._pool_store = value

    # ---- transfer pricing --------------------------------------------------
    @property
    def cost(self) -> ServeCostModel:
        return self._cost

    @cost.setter
    def cost(self, cm: ServeCostModel) -> None:
        self._cost = cm
        if self._transport_owned:
            # the private degenerate transport prices from the cost
            # model's tier-2 scalars: rebuild lazily so the benchmark
            # idiom ``eng.cost = replace(cm, tier2_bw=...)`` keeps swap
            # pricing in sync
            self._transport = None
            self.route = None

    @property
    def transport(self):
        """The ``repro.fabric.Transport`` tier-2 traffic is charged
        through.  Shared across engines it makes tenants contend on
        the fabric's links; the lazily-built private fallback is the
        cost model's degenerate 1-link facade."""
        if self._transport is None:
            self._transport = self._cost.transport()
            self.route = self._transport.topology.route("src", "dst")
        return self._transport

    def charge_tier2(self, nbytes: float, t: float) -> float:
        """Modeled seconds for one bulk tier-2 transfer beginning at
        modeled time ``t``, fair-sharing links with every transfer
        already in flight on this engine's transport.  Flows are
        labeled ``serve:<tenant>`` so link occupancy can be attributed
        to the tenant whose paging stalled a request."""
        tx = self.transport            # materializes self.route too
        return tx.transfer_s(self.route, nbytes, t,
                             label=f"serve:{self.tenant or 'engine'}")

    # ---- construction ----------------------------------------------------
    @classmethod
    def local(cls, model: Model, cfg: EngineConfig = EngineConfig(), *,
              params=None, rng=None,
              budget: Optional[KVBudget] = None,
              cost_model: Optional[ServeCostModel] = None,
              arbiter=None, tenant: Optional[str] = None,
              transport=None, route=None, tracer=None) -> "Engine":
        """Engine over local devices, no orchestrator: the KV budget is
        whatever the caller passes (default: unbudgeted tier-1, no
        tier-2).  Pass ``arbiter``/``tenant`` to join a shared
        multi-tenant page pool, and ``transport``/``route`` to charge
        tier-2 traffic on a shared routed fabric instead of a private
        degenerate link."""
        if params is None:
            params = model.init(rng if rng is not None
                                else jax.random.PRNGKey(0))
        return cls(model, params, cfg, budget=budget, cost_model=cost_model,
                   arbiter=arbiter, tenant=tenant,
                   transport=transport, route=route, tracer=tracer)

    @classmethod
    def from_lease(cls, model: Model, lease,
                   cfg: EngineConfig = EngineConfig(), *,
                   params=None, rng=None,
                   budget: Optional[KVBudget] = None,
                   cost_model: Optional[ServeCostModel] = None,
                   arbiter=None, tenant: Optional[str] = None,
                   transport=None, route=None, tracer=None) -> "Engine":
        """Bind a ``repro.pool.Lease``: the lease's mesh shapes the
        sharding rules and its tier-2 KV grant becomes the engine's
        ``KVBudget.tier2_bytes`` — serving capacity is composed by the
        orchestrator, not hard-coded per deployment."""
        from repro.sharding.profiles import make_rules

        mesh, policy = lease.materialize()
        shape = ShapeConfig("engine", "decode", cfg.max_seq, cfg.max_slots)
        rules = make_rules(model.cfg, shape, mesh, fsdp=False)
        if budget is None:
            if getattr(lease, "tenants", ()):
                # multi-tenant lease: this tenant's static slice of the
                # shared cold-store grant (tier-1 pages stay dynamic,
                # arbitrated max-min by the arbiter).  kv_share raises
                # on an unknown tenant — falling back to the FULL grant
                # here would let every mis-named tenant spill N x the
                # pool's cold bytes.
                budget = lease.kv_share(tenant, page_size=cfg.page_size)
            else:
                base = policy.kv_budget or KVBudget(page_size=cfg.page_size)
                budget = KVBudget(tier1_pages=base.tier1_pages,
                                  tier2_bytes=base.tier2_bytes,
                                  page_size=cfg.page_size)
        if params is None:
            params = model.init(rng if rng is not None
                                else jax.random.PRNGKey(0))
        return cls(model, params, cfg, budget=budget, cost_model=cost_model,
                   mesh=mesh, rules=rules, arbiter=arbiter, tenant=tenant,
                   transport=transport, route=route, tracer=tracer)

    def _scoped(self, jitted):
        def call(*args):
            with contextlib.ExitStack() as stack:
                if self.mesh is not None:
                    from repro.core.compat import mesh_context
                    from repro.sharding.partition import use_rules
                    stack.enter_context(use_rules(self.rules, self.mesh))
                    stack.enter_context(mesh_context(self.mesh))
                return jitted(*args)
        return call

    # ---- client API ------------------------------------------------------
    def submit(self, request: Request) -> RequestHandle:
        """Enqueue a request (deterministic FIFO admission order).

        Token ids are validated against the model vocab here: JAX's
        out-of-bounds gather semantics would otherwise *clamp* a bad id
        to the last embedding row and serve a silently-wrong completion.
        """
        if request.prompt_len + request.max_new_tokens > self.cfg.max_seq:
            raise ValueError(
                f"prompt {request.prompt_len} + max_new "
                f"{request.max_new_tokens} exceeds max_seq {self.cfg.max_seq}")
        vocab = self.model.cfg.vocab
        bad = [t for t in request.prompt_tokens if not 0 <= t < vocab]
        if bad:
            raise ValueError(
                f"prompt token id {bad[0]} outside the model vocab "
                f"[0, {vocab}) — JAX would clamp it to a wrong embedding "
                f"instead of failing")
        rid = self._next_rid
        self._next_rid += 1
        handle = RequestHandle(rid=rid, request=request,
                               submit_clock=max(self.clock,
                                                request.arrival_time))
        self.handles[rid] = handle
        self._queue.append(_SlotState(handle))
        if self.tracer.enabled:
            self.tracer.instant(self._track, "submit", handle.submit_clock,
                                cat=CAT_REQUEST, rid=rid,
                                prompt_len=request.prompt_len,
                                max_new=request.max_new_tokens)
        return handle

    # ---- disaggregated prefill/decode seams (repro.disagg) -----------------
    def prefill_export(self, prompt: Sequence[int]) -> Tuple[int, List[Any],
                                                             float]:
        """Prefill-only mode: run ONE bucketed prefill exactly as
        ``_admit`` would (same jit program, same bucket, same modeled
        cost, same last-position argmax) but export the KV page-by-page
        (``slice_page`` payloads) instead of scattering it into this
        engine's pool — the prefill half of the disaggregated handoff.
        Returns ``(first_token, pages, modeled_seconds)``; the caller
        owns clock accounting, transfer pricing, and decode-side
        admission.  Because the compute path is shared with the
        colocated admit, the first token and every page payload are
        bit-identical to what a colocated prefill would have produced."""
        plen = len(prompt)
        bucket = self._bucket_len(plen)
        self._buckets_used.add(bucket)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :plen] = prompt
        slot_cache = self.model.init_cache(1, bucket,
                                           dtype=self._cache_dtype)
        logits, cache = self._prefill_fn(self.params,
                                         {"tokens": jnp.asarray(tokens)},
                                         slot_cache, jnp.int32(plen - 1))
        cost = self.cost.prefill_s(bucket)
        tok = int(np.argmax(np.asarray(logits)[0, -1]))
        ps = self.cfg.page_size
        pages = [slice_page(cache, i, ps) for i in range(-(-plen // ps))]
        return tok, pages, cost

    def submit_prefilled(self, request: Request, *, first_tok: int,
                         prefill_done: float, pages: List[Any],
                         page_ready: Sequence[float],
                         min_ready_pages: Optional[int] = None,
                         kv_transit_s: float = 0.0,
                         submit_clock: Optional[float] = None,
                         on_first_decode=None) -> RequestHandle:
        """Decode-only mode: hand off a request whose prefill ran on
        another engine (``prefill_export``) and whose KV pages are in
        flight on the fabric.  ``page_ready[i]`` is the modeled
        completion time of page ``i``'s transfer; admission waits for
        the first ``min_ready_pages`` pages to land (default: all —
        partial-arrival admission reserves the slot early), and the row
        is never decoded before max(page_ready): transferred-before-use
        is the invariant the ``disagg-handoff`` sanitizer rule checks.
        The first token was already produced by the prefill tier at
        modeled time ``prefill_done``."""
        if request.prompt_len + request.max_new_tokens > self.cfg.max_seq:
            raise ValueError(
                f"prompt {request.prompt_len} + max_new "
                f"{request.max_new_tokens} exceeds max_seq {self.cfg.max_seq}")
        if len(pages) != len(page_ready):
            raise ValueError(f"{len(pages)} pages but {len(page_ready)} "
                             f"ready times")
        if not pages:
            raise ValueError("handoff with no KV pages")
        rid = self._next_rid
        self._next_rid += 1
        handle = RequestHandle(rid=rid, request=request,
                               submit_clock=(submit_clock
                                             if submit_clock is not None
                                             else request.arrival_time))
        handle.kv_transit_s = kv_transit_s
        self.handles[rid] = handle
        st = _SlotState(handle)
        st.index = request.prompt_len
        st.cur_tok = first_tok
        st.on_first_decode = on_first_decode
        # the prefill tier produced the first token at prefill_done;
        # trace events on THIS track must stay monotone, so the finish
        # path (max_new == 1) clamps forward to the local clock
        handle.first_token_clock = prefill_done
        self._emit(st, first_tok, max(self.clock, prefill_done))
        if handle.done:
            return handle
        ready = [float(t) for t in page_ready]
        n_gate = (len(ready) if min_ready_pages is None
                  else max(1, min(min_ready_pages, len(ready))))
        self._handoffs.append(_Handoff(
            state=st, pages=list(pages), page_ready=ready,
            admit_at=max(ready[:n_gate]), ready_at=max(ready)))
        return handle

    @property
    def idle(self) -> bool:
        return (not self._queue and not self._paused and not self._handoffs
                and all(s is None for s in self._slots))

    def advance_clock(self, t: float) -> None:
        """Idle-advance modeled time (trace drivers jump to next arrival)."""
        self.clock = max(self.clock, t)

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.idle:
                return
            self.step()
        raise RuntimeError(f"engine not idle after {max_steps} steps")

    # ---- the engine loop -------------------------------------------------
    def step(self) -> float:
        """One scheduling round: relieve page pressure, swap in, admit,
        decode every running row one token.  Returns modeled seconds.
        Sub-phases receive the seconds already elapsed *within* this
        step so every event clock lands on the event's modeled time."""
        dt = 0.0
        if self.arbiter is not None:
            # swap seconds another tenant's revocation charged to us
            # since our last step: OUR pages rode the fabric, so OUR
            # subsequent event clocks absorb the time
            dt += self.arbiter.take_charge(self.tenant)
        dt += self._relieve_pressure(dt)
        dt += self._swap_in(dt)
        dt += self._admit_handoffs(dt)
        dt += self._admit(dt)
        dt += self._decode_once(dt)
        if (dt == 0.0 and self._queue and not self._paused  # repro: allow(no-float-equality) 0.0 is an exact no-work sentinel (no phase ran), never an accumulated time
                and all(s is None for s in self._slots)):
            # nothing runnable and the FIFO head has not arrived yet:
            # idle-advance to its arrival (the same jump run_trace makes)
            # so directly-submitted future-dated requests make progress
            nxt = self._queue[0].request.arrival_time
            if nxt > self.clock:
                self.advance_clock(nxt)
        if dt == 0.0:  # repro: allow(no-float-equality) same exact no-work sentinel as above
            # every runnable row (or the pending handoff) is still
            # waiting on KV in flight over the fabric: idle-advance to
            # the earliest modeled page arrival so progress is made
            gates = [s.ready_at for s in self._slots
                     if s is not None and s.ready_at > self.clock]
            if self._handoffs:
                gates.append(self._handoffs[0].admit_at)
            if gates:
                nxt = min(gates)
                if nxt > self.clock:
                    self.advance_clock(nxt)
        self.clock += dt
        if dt > 0.0:
            self.busy_s += dt
        self.steps += 1
        if self.tracer.enabled:
            # counter lanes (Perfetto renders these as area charts):
            # physical free stack, pause-queue depth, live allowance.
            # Values are identical between a private pool and a lone
            # tenant under the arbiter (the fig9 transparency contract),
            # so traced event streams stay bit-identical across both.
            if self.steps == 1:
                # pool geometry, once: the conservation baseline the
                # repro.analysis sanitizer checks page counters against
                self.tracer.instant(self._track, "kv_pool", self.clock,
                                    cat=CAT_KV, pages=self.kv.num_pages)
            self.tracer.counter(self._track, "free_pages", self.clock,
                                float(self.kv.free_count), cat=CAT_KV)
            self.tracer.counter(self._track, "paused", self.clock,
                                float(len(self._paused)))
            self.tracer.counter(self._track, "allowance", self.clock,
                                float(self.kv.allowance()), cat=CAT_KV)
            # hot_pages LAST in the step-end block: the sanitizer treats
            # it as the tenant's authoritative residency sample and
            # checks free + sum(hot) == pool against the same block's
            # free_pages value
            self.tracer.counter(self._track, "hot_pages", self.clock,
                                float(self.kv.hot_used()), cat=CAT_KV)
        return dt

    # ---- internals -------------------------------------------------------
    def _running(self) -> List[_SlotState]:
        return sorted((s for s in self._slots if s is not None),
                      key=lambda s: s.admit_seq)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def _pages_next(self, st: _SlotState) -> int:
        # pages needed to write the next token at position st.index; under
        # static reservation the full lifetime is held from admission on
        if self.cfg.reserve_lifetime:
            return self.budget.pages_for(st.target_len)
        return self.budget.pages_for(st.index + 1)

    def _page_demand(self) -> int:
        """This engine's current want for hot pages (running + paused
        next-token demand, plus the queue head's admission need) — the
        demand signal the arbiter's max-min water-filling splits the
        shared pool over."""
        d = sum(self._pages_next(s) for s in self._slots if s is not None)
        d += sum(self._pages_next(s) for s in self._paused)
        if self._queue:
            st = self._queue[0]
            if self.cfg.reserve_lifetime:
                d += self.budget.pages_for(st.target_len)
            else:
                d += self.budget.pages_for(len(st.effective_prompt()) + 1)
        return d

    def _bucket_len(self, plen: int) -> int:
        for b in self._buckets:
            if b >= plen:
                return b
        raise ValueError(f"prompt of {plen} exceeds slot capacity "
                         f"{self._buckets[-1]}")

    def prefill_compiles(self) -> int:
        """Compiled prefill program count (the CI guard asserts this
        stays <= len(buckets) regardless of the trace's prompt lengths).
        Without jit cache introspection this is only a lower bound (the
        buckets actually requested) — the guard test skips rather than
        pass vacuously in that case."""
        if hasattr(self._prefill_jit, "_cache_size"):
            return self._prefill_jit._cache_size()
        return len(self._buckets_used)  # pragma: no cover

    def decode_compiles(self) -> int:
        """Compiled paged-decode program count — bounded by the pow2
        row-bucket list, not by the trace's occupancy history (same
        caveat as ``prefill_compiles`` without cache introspection)."""
        if hasattr(self._decode_jit, "_cache_size"):
            return self._decode_jit._cache_size()
        return len(self._row_buckets_used)  # pragma: no cover

    # ---- pressure relief / paging ----------------------------------------
    def _relieve_pressure(self, elapsed: float) -> float:
        """Deschedule newest-admitted rows until the remaining running
        rows' next-token demand fits the pool, then allocate this step's
        growth pages — evicting the coldest paused pages as needed."""
        dt = 0.0
        running = self._running()
        allow = self.kv.allowance()     # == num_pages for a private pool;
        while running:                  # the live fair share under an arbiter
            demand = sum(self._pages_next(s) for s in running)
            if demand <= allow and self._growth_deliverable(running):
                break
            self._pause(running.pop(),          # newest admission
                        self.clock + elapsed + dt)
        for st in running:
            want = self._pages_next(st)
            have = self.kv.pages_of(st.rid)
            if want > have:
                dt += self._make_room(want - have, t=elapsed + dt)
                new_phys = self.kv.grow(st.rid, want)
                for lp, phys in zip(range(have, want), new_phys):
                    self._table[st.slot, lp] = phys
        return dt

    def _growth_deliverable(self, running: List[_SlotState]) -> bool:
        """Can this step's growth pages actually be freed?  Sources:
        the free stack + revocation headroom (``hot_free``) plus our own
        paused sequences' hot pages (always evictable or droppable).
        For a private pool ``demand <= num_pages`` already implies this
        (growth = demand - held ≤ free + paused-hot), so the check only
        bites under an arbiter — another tenant may sit over its share
        with all rows *running* (nothing revocable until ITS next step
        pauses them), and growing into that gap must wait."""
        growth = sum(max(0, self._pages_next(s) - self.kv.pages_of(s.rid))
                     for s in running if self.kv.holds(s.rid))
        own_evictable = sum(self.kv.hot_count(s.rid) for s in self._paused
                            if self.kv.holds(s.rid))
        return growth <= self.kv.hot_free + own_evictable

    def _pause(self, st: _SlotState, t: Optional[float] = None) -> None:
        """Deschedule a running row at modeled time ``t`` (defaults to
        the clock).  Costless: its pages STAY hot until an allocation
        actually needs them (lazy eviction) — pausing and resuming
        without intervening pressure moves zero bytes."""
        if self.tracer.enabled:
            self.tracer.instant(self._track, "pause",
                                self.clock if t is None else t,
                                cat=CAT_KV, rid=st.rid,
                                hot_pages=self.kv.hot_count(st.rid)
                                if self.kv.holds(st.rid) else 0)
        slot = st.slot
        self._table[slot, :] = self._trash
        self._lengths[slot] = 0
        self._slots[slot] = None
        st.slot = None
        st.handle.status = RequestStatus.SWAPPED
        st.handle.preempts += 1     # swaps counts actual tier-2 traffic,
                                    # charged at eviction time
        self._paused.append(st)     # insertion order == pause order; the
                                    # resume policy pops from the front

    def _make_room(self, n_pages: int, protect: Sequence[_SlotState] = (),
                   t: float = 0.0) -> float:
        """Free physical pages by evicting the coldest paused pages to
        tier-2 (or dropping victims for recompute when the byte budget
        is exhausted).  Coldness: least-recently-scheduled sequence
        first (admission order breaking ties); within a victim, the
        oldest-written (lowest-logical) pages go first.  ``t`` is the
        seconds already elapsed within this step — spill transfers
        begin at ``clock + t`` on the transport."""
        dt = 0.0
        # snapshot the revocation headroom once: under an arbiter,
        # hot_free re-runs the max-min water-filling over every tenant,
        # and this loop would otherwise recompute it per evicted page.
        # Own evictions only grow the free stack, so the cached slack
        # stays a valid (conservative) lower bound.  Private pool: 0.
        slack = self.kv.hot_free - self.kv.free_count
        while self.kv.free_count + slack < n_pages:
            victims = [s for s in self._paused
                       if s not in protect and self.kv.hot_count(s.rid) > 0]
            if not victims:
                break               # nothing evictable; caller re-checks
            victim = min(victims, key=lambda s: (s.last_sched, s.admit_seq))
            dt += self._evict_or_drop(
                victim, n_pages - slack - self.kv.free_count, t + dt)
        return dt

    def _evict_or_drop(self, st: _SlotState, need: int, t: float) -> float:
        hot = self.kv.hot_logicals(st.rid)
        k = min(need, len(hot), self.kv.tier2_free_pages())
        if k <= 0:
            # no tier-2 headroom (or no tier-2 budget at all): page-
            # granular spill is impossible, and a partial prefix is
            # useless for recompute — drop the whole sequence's KV and
            # requeue it for re-prefill
            self._drop_for_recompute(st, self.clock + t)
            return 0.0
        return evict_pages(self._pool, self.kv, st, hot[:k], self,
                           self.clock + t)

    def _drop_for_recompute(self, st: _SlotState,
                            t: Optional[float] = None) -> None:
        if self.tracer.enabled:
            self.tracer.instant(self._track, "recompute_drop",
                                self.clock if t is None else t,
                                cat=CAT_KV, rid=st.rid,
                                generated=len(st.handle.tokens),
                                pages=self.kv.hot_count(st.rid)
                                if self.kv.holds(st.rid) else 0)
        self.kv.free(st.rid)
        st.index = 0
        st.handle.status = RequestStatus.QUEUED
        st.handle.recomputes += 1
        self._paused.remove(st)
        self._queue.appendleft(st)  # ahead of fresh arrivals (it already
                                    # held a slot once; FIFO fairness)

    def _swap_in(self, elapsed: float) -> float:
        """Paused sequences re-enter free rows in pause order (oldest
        paused first — they may hold tier-2 bytes the pool wants back).
        Only their COLD pages ride the fabric; still-hot pages never
        moved.  When nothing is running, liveness demands progress: the
        head of the pause queue may evict newer-paused pages to fit."""
        dt = 0.0
        allow = self.kv.allowance()
        run_demand = sum(self._pages_next(s) for s in self._slots
                         if s is not None)
        while self._paused:
            st = self._paused[0]
            slot = self._free_slot()
            if slot is None:
                break
            want = self._pages_next(st)
            if run_demand + want > allow:
                break       # resuming would overshoot the fair share the
                            # pressure phase just enforced (flap guard —
                            # paused pages must stay revocable)
            missing = (len(self.kv.cold_logicals(st.rid))
                       + max(0, want - self.kv.pages_of(st.rid)))
            if missing > self.kv.hot_free:
                if any(s is not None for s in self._slots):
                    break           # decode will free pages; wait
                dt += self._make_room(missing, protect=(st,),
                                      t=elapsed + dt)
                if missing > self.kv.hot_free:
                    break
            # resume BEFORE popping: mid-resume the sequence must stay
            # visible to the arbiter's demand accounting (its fetches/
            # growth are what the fair share is being claimed for)
            dt += self._resume_into(st, slot, want, elapsed + dt)
            self._paused.popleft()
            run_demand += want
        return dt

    def _resume_into(self, st: _SlotState, slot: int, want: int,
                     elapsed: float) -> float:
        dt = 0.0
        cold = self.kv.cold_logicals(st.rid)
        # reserve all physical pages this resume needs in one go: the
        # per-page fetch loop below would otherwise trigger one
        # revocation episode (and one setup latency on the victim's
        # clock) per cold page instead of one bulk transfer
        self.kv.prepare(len(cold) + max(0, want - self.kv.pages_of(st.rid)))
        if cold:
            fetched = [self.kv.fetch(st.rid, lp) for lp in cold]
            idx = jnp.asarray(np.asarray([p for p, _ in fetched], np.int32))

            def put(pool_leaf, *pages):     # one batched scatter, not one
                stacked = jnp.stack(         # whole-pool copy per page
                    [jnp.asarray(pg, pool_leaf.dtype) for pg in pages],
                    axis=1)
                return pool_leaf.at[:, idx].set(stacked)

            self._pool = jax.tree.map(put, self._pool,
                                      *[pl for _, pl in fetched])
            dt = self.charge_tier2(len(cold) * self.kv.page_bytes,
                                   self.clock + elapsed)
            if self.tracer.enabled:
                self.tracer.span(self._track, "fetch",
                                 self.clock + elapsed, dt, cat=CAT_KV,
                                 rid=st.rid, pages=len(cold),
                                 bytes=len(cold) * self.kv.page_bytes)
        self.kv.grow(st.rid, want)
        for lp, phys in enumerate(self.kv.page_table(st.rid)):
            self._table[slot, lp] = phys
        self._place(st, slot)
        return dt

    # ---- disaggregated handoff admission -----------------------------------
    def _admit_handoffs(self, elapsed: float) -> float:
        """Admit handed-off (externally prefilled) sequences whose
        leading KV pages have arrived: allocate physical pages, scatter
        every page payload (arrived pages now; the rest are gated by
        ``ready_at``, which decode scheduling honors), and place the
        row.  Runs after swap-in and before fresh admission — a handoff
        already spent prefill compute elsewhere, so it outranks a fresh
        arrival for free rows (the recompute-requeue fairness rule) —
        but never past a blocked pause queue, mirroring ``_admit``."""
        dt = 0.0
        while self._handoffs:
            if self._paused:
                break
            ho = self._handoffs[0]
            st = ho.state
            if ho.admit_at > self.clock + elapsed + dt:
                break       # leading pages still in flight on the fabric
            need = (self.budget.pages_for(st.target_len)
                    if self.cfg.reserve_lifetime
                    else self.budget.pages_for(st.index + 1))
            slot = self._free_slot()
            if slot is None or need > self.kv.hot_free:
                break
            phys = self.kv.alloc(st.rid, need)
            for i, payload in enumerate(ho.pages):
                self._write_page(int(phys[i]), payload)
            for lp, p in enumerate(phys):
                self._table[slot, lp] = p
            self._place(st, slot)
            st.ready_at = ho.ready_at
            self._handoffs.popleft()
        return dt

    # ---- admission / prefill ---------------------------------------------
    def _admit(self, elapsed: float) -> float:
        """FIFO prefill admission (head-of-line blocking keeps the order
        deterministic; a request that can never fit fails immediately).
        Admission never runs past a blocked pause queue: a fresh arrival
        must not eat the free rows/pages the oldest paused sequence is
        waiting for (it would starve behind a steady arrival stream) —
        and it never evicts a paused sequence's residency either."""
        dt = 0.0
        while self._queue:
            if self._paused:
                break
            st = self._queue[0]
            if st.request.arrival_time > self.clock + elapsed + dt:
                break   # not arrived yet on the modeled clock: admitting
                        # (and decoding) it now would emit tokens BEFORE
                        # its arrival and drive ttft/latency negative
            if self.budget.pages_for(st.target_len) > self.kv.num_pages:
                self._queue.popleft()
                st.handle.status = RequestStatus.FAILED_OOM
                st.handle.done_clock = self.clock + elapsed + dt
                if self.tracer.enabled:
                    self.tracer.instant(self._track, "failed_oom",
                                        st.handle.done_clock,
                                        cat=CAT_REQUEST, rid=st.rid)
                continue
            slot = self._free_slot()
            eff = st.effective_prompt()
            need = (self.budget.pages_for(st.target_len)
                    if self.cfg.reserve_lifetime
                    else self.budget.pages_for(len(eff) + 1))
            if slot is None or need > self.kv.hot_free:
                break
            # prefill BEFORE popping: while its pages are allocated the
            # request must stay visible (as queue head) to the arbiter's
            # demand accounting, or its fair share evaporates mid-admit
            dt += self._prefill_into(st, slot, eff, elapsed + dt)
            self._queue.popleft()
        return dt

    def _prefill_into(self, st: _SlotState, slot: int,
                      eff: Tuple[int, ...], elapsed: float) -> float:
        plen = len(eff)
        bucket = self._bucket_len(plen)
        self._buckets_used.add(bucket)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :plen] = eff
        slot_cache = self.model.init_cache(1, bucket,
                                           dtype=self._cache_dtype)
        logits, cache = self._prefill_fn(self.params,
                                         {"tokens": jnp.asarray(tokens)},
                                         slot_cache, jnp.int32(plen - 1))
        # the padded tail is real (wasted) compute on hardware: charge it
        cost = self.cost.prefill_s(bucket)
        if self.tracer.enabled:
            self.tracer.span(self._track, "prefill",
                             self.clock + elapsed, cost, cat=CAT_ENGINE,
                             rid=st.rid, bucket=bucket, prompt_len=plen)
        tok = int(np.argmax(np.asarray(logits)[0, -1]))
        self._emit(st, tok, self.clock + elapsed + cost)
        if st.handle.done:
            return cost
        need = (self.budget.pages_for(st.target_len)
                if self.cfg.reserve_lifetime
                else self.budget.pages_for(plen + 1))
        phys = self.kv.alloc(st.rid, need)
        self._write_prefill_pages(cache, phys, plen)
        for lp, p in enumerate(phys):
            self._table[slot, lp] = p
        st.index = plen
        st.cur_tok = tok
        self._place(st, slot)
        return cost

    def _write_page(self, phys: int, payload) -> None:
        """Write ONE page payload (the ``slice_page`` / ``PagedKV``
        per-page format) into physical page ``phys`` of the pool — the
        import half of the page seam.  Prefill scatter, tier-2 fetch
        and the disaggregated handoff all land pages through the same
        dtype-converting ``.at[...].set``, so a page is bit-identical
        in the pool no matter which path carried it."""
        self._pool = jax.tree.map(
            lambda pool_leaf, page_leaf: pool_leaf.at[:, phys].set(
                jnp.asarray(page_leaf, pool_leaf.dtype)),
            self._pool, payload)

    def _write_prefill_pages(self, cache, phys: List[int],
                             plen: int) -> None:
        """Write the dense prefill cache into the allocated physical
        pages one page at a time (``slice_page`` -> ``_write_page``):
        page-granular at prefill time, so a disaggregated prefill tier
        can stream each page the moment it is sliced instead of
        scattering the whole bucket after prefill completes.  Only
        pages holding real tokens are copied: the padded bucket tail
        (and any growth/lifetime pages past the prompt) is garbage the
        kernel's length mask never reads.  The physical pages are
        distinct, so the per-page writes compose to exactly the old
        batched scatter (pinned by a regression test)."""
        ps = self.cfg.page_size
        for i in range(-(-plen // ps)):
            self._write_page(int(phys[i]), slice_page(cache, i, ps))

    def _place(self, st: _SlotState, slot: int) -> None:
        st.slot = slot
        st.admit_seq = self._admit_seq
        self._admit_seq += 1
        self._slots[slot] = st
        self._lengths[slot] = st.index
        self._slot_tok[slot] = st.cur_tok
        st.handle.status = RequestStatus.RUNNING

    # ---- decode ----------------------------------------------------------
    def _emit(self, st: _SlotState, tok: int, at: float) -> None:
        """Record a generated token at its modeled completion time."""
        st.handle.tokens.append(tok)
        if st.handle.first_token_clock is None:
            st.handle.first_token_clock = at
        eos_hit = (self.cfg.eos_token is not None
                   and tok == self.cfg.eos_token)
        if len(st.handle.tokens) >= st.request.max_new_tokens or eos_hit:
            st.handle.status = RequestStatus.DONE
            st.handle.done_clock = at
            if self.tracer.enabled:
                h = st.handle
                ttft = (h.first_token_clock - h.submit_clock
                        if h.first_token_clock is not None else 0.0)
                self.tracer.instant(self._track, "finish", at,
                                    cat=CAT_REQUEST, rid=h.rid,
                                    tokens=len(h.tokens))
                # one span per request lifetime on the tenant's request
                # row: submit -> done, with the latency decomposition
                # downstream reports read straight off the timeline
                extra = ({"kv_transit_s": h.kv_transit_s}
                         if h.kv_transit_s > 0.0 else {})
                self.tracer.span(f"{self._track}/requests", f"req{h.rid}",
                                 h.submit_clock, at - h.submit_clock,
                                 cat=CAT_REQUEST, rid=h.rid, ttft_s=ttft,
                                 tokens=len(h.tokens), swaps=h.swaps,
                                 preempts=h.preempts,
                                 recomputes=h.recomputes, **extra)
            if self.kv.holds(st.rid):
                self.kv.free(st.rid)
            if st.slot is not None:
                self._table[st.slot, :] = self._trash
                self._lengths[st.slot] = 0
                self._slots[st.slot] = None
                st.slot = None

    def _row_bucket(self, n_live: int) -> int:
        for b in self._row_buckets:
            if b >= n_live:
                return b
        raise AssertionError(f"{n_live} live rows > max_slots")

    def _decode_once(self, elapsed: float) -> float:
        # rows whose handed-off KV pages are still in flight on the
        # fabric are placed but not schedulable: decoding one would
        # read pages before their modeled transfer completion (the
        # disagg-handoff sanitizer violation).  Colocated rows have
        # ready_at == 0.0, so the filter is the identity for them.
        running = [st for st in self._running()
                   if st.ready_at <= self.clock + elapsed]
        if not running:
            return 0.0
        for st in running:
            self._lengths[st.slot] = st.index
            self._slot_tok[st.slot] = st.cur_tok
            st.last_sched = self.steps
            if st.on_first_decode is not None:
                # first decode of a handed-off row: report the modeled
                # use time (>= every page's transfer completion — the
                # transferred-before-use fact the sanitizer audits)
                st.on_first_decode(self.clock + elapsed)
                st.on_first_decode = None
        # gather live rows into a pow2 row bucket: pad with idle slots
        # (trash page table, length 0 — exactly what a full-array
        # decode feeds for them), so the decode batch shrinks with
        # occupancy while per-row outputs stay identical
        bucket = self._row_bucket(len(running))
        self._row_buckets_used.add(bucket)
        rows = [st.slot for st in running]
        if bucket < self.cfg.max_slots:
            idle = [i for i, s in enumerate(self._slots) if s is None]
            sel = np.asarray(rows + idle[:bucket - len(rows)], np.int32)
        else:
            sel = np.arange(self.cfg.max_slots, dtype=np.int32)
            rows = list(sel)                # full array: row == slot
        toks = jnp.asarray(self._slot_tok[sel][:, None])
        table = jnp.asarray(self._table[sel])
        lengths = jnp.asarray(self._lengths[sel])
        new_toks, self._pool = self._decode_fn(self.params, toks,
                                               self._pool, table, lengths)
        new_toks = np.asarray(new_toks)
        pos = {slot: i for i, slot in enumerate(rows)}
        cost = self.cost.decode_s(len(running))
        at = self.clock + elapsed + cost
        if self.tracer.enabled:
            self.tracer.span(self._track, "decode",
                             self.clock + elapsed, cost, cat=CAT_ENGINE,
                             rows=len(running), bucket=bucket)
        for st in running:
            tok = int(new_toks[pos[st.slot], 0])
            st.index += 1
            st.cur_tok = tok
            self._decoded_tokens += 1
            self._emit(st, tok, at)
        return cost

    # ---- observability ---------------------------------------------------
    # flat scalar keys of the legacy stats() dict; each maps 1:1 onto
    # the registry path  serve/<tenant>/<key>
    _STATS_KEYS = ("clock_s", "steps", "busy_s", "queue_depth", "running",
                   "swapped", "completed", "failed_oom", "tokens_decoded",
                   "throughput_tok_s", "throughput_busy_tok_s", "preempts",
                   "preempt_swaps", "preempt_recomputes", "prefill_buckets",
                   "prefill_compiles", "decode_row_buckets",
                   "decode_compiles")

    def _metrics_prefix(self) -> str:
        return f"serve/{self.tenant or 'engine'}"

    def metrics(self, registry: Optional[MetricsRegistry] = None,
                prefix: Optional[str] = None) -> MetricsRegistry:
        """Fill (and return) a ``repro.obs`` metrics registry with this
        engine's state under ``serve/<tenant>/...`` — the ONE schema
        downstream reporting reads; ``stats()`` is a thin adapter."""
        reg = registry if registry is not None else MetricsRegistry()
        p = prefix if prefix is not None else self._metrics_prefix()
        statuses = [h.status for h in self.handles.values()]
        pairs = (
            ("clock_s", self.clock),
            ("steps", self.steps),
            ("busy_s", self.busy_s),
            ("queue_depth", len(self._queue)),
            ("running", sum(s is not None for s in self._slots)),
            ("swapped", len(self._paused)),
            ("completed", sum(s is RequestStatus.DONE for s in statuses)),
            ("failed_oom",
             sum(s is RequestStatus.FAILED_OOM for s in statuses)),
            ("tokens_decoded", self._decoded_tokens),
            # clock_s includes idle inter-arrival gaps (advance_clock),
            # so this number is arbitrarily diluted on sparse traces —
            # it is the *offered-load* rate, kept for trace comparisons
            ("throughput_tok_s", (self._decoded_tokens / self.clock
                                  if self.clock > 0 else 0.0)),
            # decode rate while the engine is actually working: the
            # hardware-capability number benchmarks should quote
            ("throughput_busy_tok_s", (self._decoded_tokens / self.busy_s
                                       if self.busy_s > 0 else 0.0)),
            ("preempts",
             sum(h.preempts for h in self.handles.values())),
            ("preempt_swaps",
             sum(h.swaps for h in self.handles.values())),
            ("preempt_recomputes",
             sum(h.recomputes for h in self.handles.values())),
            ("prefill_buckets", list(self._buckets)),
            ("prefill_compiles", self.prefill_compiles()),
            ("decode_row_buckets", list(self._row_buckets)),
            ("decode_compiles", self.decode_compiles()),
        )
        for key, value in pairs:
            reg.set(f"{p}/{key}", value)
        for key, value in self.kv.residency().items():
            reg.set(f"{p}/kv/{key}", value)
        # the property materializes the lazy private transport so the
        # subtree is schema-stable whether or not a swap ever happened
        self.transport.metrics(reg, prefix=f"{p}/transport")
        if self.arbiter is not None:
            reg.set(f"{p}/tenant", self.tenant)
            reg.set(f"{p}/allowance", self.kv.allowance())
        return reg

    def stats(self) -> Dict[str, Any]:
        """Throughput, queue depth, page-pool residency, compile counts
        — the legacy dict, adapted off the ``metrics()`` registry."""
        p = self._metrics_prefix()
        snap = self.metrics().snapshot(p + "/")
        out: Dict[str, Any] = {k: snap[f"{p}/{k}"]
                               for k in self._STATS_KEYS}
        out["kv"] = self.kv.residency()
        out["transport"] = self.transport.stats()
        if self.arbiter is not None:
            out["tenant"] = snap[f"{p}/tenant"]
            out["allowance"] = snap[f"{p}/allowance"]
        return out

"""Request-level continuous-batching engine over a budgeted paged KV pool.

The serving counterpart of ``runtime.train``: one ``Engine`` owns a
fixed array of decode slots (a stacked per-slot KV cache), admits queued
requests FIFO into free slots (prefill), advances every running slot one
token per ``step()`` (a single vmapped, jitted decode over the slot
axis), recycles slots on completion, and enforces a ``KVBudget``:

* every running slot's pages live in tier-1 (HBM) — decode attends the
  whole prefix, so residency is a hard requirement;
* when decode growth overruns the tier-1 page quota, the newest-admitted
  slot is preempted: with a tier-2 byte budget its cache region is
  *swapped* to the capacity pool (bit-exact, bulk CXL.io traffic) and
  swapped back when pages free up; with no tier-2 budget its KV is
  dropped and the request re-queued for full re-prefill (the recompute
  storm the paper's Fig. 7 tier-2 relief avoids);
* a request whose lifetime page demand can never fit the quota fails
  deterministically at admission (``FAILED_OOM``).

Each slot is an independent batch=1 program under ``jax.vmap``, so a
request's tokens depend only on its own prompt — output is identical
for any arrival interleaving and for lease-backed vs local construction
(the engine's determinism contract, enforced by tests).

Time is *modeled*: a ``ServeCostModel`` prices prefill/decode/swap
events from the paper's fabric constants, so latency distributions are
hardware-derived even when the host is a CPU smoke run.
"""

from __future__ import annotations

import contextlib
import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiering import KVBudget, KVBudgetExceeded, PagedKV
from repro.models.api import Model
from repro.models.config import ShapeConfig
from repro.serve.api import (EngineConfig, Request, RequestHandle,
                             RequestStatus, ServeCostModel)


def _dtype(d):
    return jnp.dtype(d) if not isinstance(d, str) else {
        "float32": jnp.float32, "bfloat16": jnp.bfloat16,
        "float16": jnp.float16}[d]


@dataclasses.dataclass
class _SlotState:
    """Host-side bookkeeping for one in-flight request."""

    handle: RequestHandle
    index: int = 0                 # next KV write position
    cur_tok: int = 0               # last emitted token (decode input)
    slot: Optional[int] = None
    admit_seq: int = -1            # admission order (preemption victims
                                   # are chosen newest-first)

    @property
    def rid(self) -> int:
        return self.handle.rid

    @property
    def request(self) -> Request:
        return self.handle.request

    def effective_prompt(self) -> Tuple[int, ...]:
        """Prompt for (re-)prefill: original prompt plus everything
        already generated (the recompute-preemption continuation)."""
        return self.request.prompt_tokens + tuple(self.handle.tokens)

    @property
    def target_len(self) -> int:
        return self.request.prompt_len + self.request.max_new_tokens


class Engine:
    """Continuous-batching serving engine.  Build with ``Engine.local``
    (explicit config) or ``Engine.from_lease`` (a ``repro.pool`` lease
    supplies the mesh, sharding rules, and the tier-2 KV byte budget)."""

    def __init__(self, model: Model, params, cfg: EngineConfig, *,
                 budget: Optional[KVBudget] = None,
                 cost_model: Optional[ServeCostModel] = None,
                 mesh=None, rules=None):
        if model.cfg.family == "encdec":
            raise NotImplementedError(
                "Engine drives decoder-style models; encdec serving still "
                "goes through runtime.serve step factories")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.mesh, self.rules = mesh, rules
        self.cost = cost_model or ServeCostModel.from_fabric(
            2.0 * model.cfg.param_count())

        dt = _dtype(cfg.cache_dtype)
        self._cache_dtype = dt
        slot_shapes = jax.eval_shape(
            lambda: model.init_cache(1, cfg.max_seq, dtype=dt))
        slot_bytes = sum(l.size * l.dtype.itemsize
                         for l in jax.tree.leaves(slot_shapes))
        page_bytes = slot_bytes * cfg.page_size / max(1, cfg.max_seq)
        self.slot_bytes = float(slot_bytes)

        full = budget or KVBudget(page_size=cfg.page_size)
        tier1 = (full.tier1_pages if full.tier1_pages is not None
                 else cfg.max_slots * cfg.pages_per_slot)
        self.budget = KVBudget(tier1_pages=tier1,
                               tier2_bytes=full.tier2_bytes,
                               page_size=cfg.page_size)
        self.kv = PagedKV(self.budget, page_bytes)

        # stacked per-slot cache: leading axis = slot, each slot batch=1
        self._cache = jax.tree.map(
            lambda l: jnp.zeros((cfg.max_slots,) + l.shape, l.dtype),
            slot_shapes)
        self._slots: List[Optional[_SlotState]] = [None] * cfg.max_slots
        self._slot_index = [0] * cfg.max_slots   # stale values are harmless
        self._slot_tok = [0] * cfg.max_slots     # (masked / overwritten)

        self._queue: deque = deque()     # _SlotState, FIFO (+preempted front)
        self._swapped: List[_SlotState] = []
        self.handles: Dict[int, RequestHandle] = {}
        self._next_rid = 0
        self._admit_seq = 0

        self.clock = 0.0
        self.steps = 0
        self._decoded_tokens = 0
        self._prefill_fn = self._scoped(model.prefill)

        def slot_decode(params, tok, cache, index):
            logits, new_cache = model.decode(params, tok, cache, index)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return nxt[:, None], new_cache

        self._decode_fn = self._scoped(
            jax.vmap(slot_decode, in_axes=(None, 0, 0, 0)))

    # ---- construction ----------------------------------------------------
    @classmethod
    def local(cls, model: Model, cfg: EngineConfig = EngineConfig(), *,
              params=None, rng=None,
              budget: Optional[KVBudget] = None,
              cost_model: Optional[ServeCostModel] = None) -> "Engine":
        """Engine over local devices, no orchestrator: the KV budget is
        whatever the caller passes (default: unbudgeted tier-1, no tier-2)."""
        if params is None:
            params = model.init(rng if rng is not None
                                else jax.random.PRNGKey(0))
        return cls(model, params, cfg, budget=budget, cost_model=cost_model)

    @classmethod
    def from_lease(cls, model: Model, lease,
                   cfg: EngineConfig = EngineConfig(), *,
                   params=None, rng=None,
                   budget: Optional[KVBudget] = None,
                   cost_model: Optional[ServeCostModel] = None) -> "Engine":
        """Bind a ``repro.pool.Lease``: the lease's mesh shapes the
        sharding rules and its tier-2 KV grant becomes the engine's
        ``KVBudget.tier2_bytes`` — serving capacity is composed by the
        orchestrator, not hard-coded per deployment."""
        from repro.sharding.profiles import make_rules

        mesh, policy = lease.materialize()
        shape = ShapeConfig("engine", "decode", cfg.max_seq, cfg.max_slots)
        rules = make_rules(model.cfg, shape, mesh, fsdp=False)
        if budget is None:
            base = policy.kv_budget or KVBudget(page_size=cfg.page_size)
            budget = KVBudget(tier1_pages=base.tier1_pages,
                              tier2_bytes=base.tier2_bytes,
                              page_size=cfg.page_size)
        if params is None:
            params = model.init(rng if rng is not None
                                else jax.random.PRNGKey(0))
        return cls(model, params, cfg, budget=budget, cost_model=cost_model,
                   mesh=mesh, rules=rules)

    def _scoped(self, fn):
        jitted = jax.jit(fn)

        def call(*args):
            with contextlib.ExitStack() as stack:
                if self.mesh is not None:
                    from repro.core.compat import mesh_context
                    from repro.sharding.partition import use_rules
                    stack.enter_context(use_rules(self.rules, self.mesh))
                    stack.enter_context(mesh_context(self.mesh))
                return jitted(*args)
        return call

    # ---- client API ------------------------------------------------------
    def submit(self, request: Request) -> RequestHandle:
        """Enqueue a request (deterministic FIFO admission order)."""
        if request.prompt_len + request.max_new_tokens > self.cfg.max_seq:
            raise ValueError(
                f"prompt {request.prompt_len} + max_new "
                f"{request.max_new_tokens} exceeds max_seq {self.cfg.max_seq}")
        rid = self._next_rid
        self._next_rid += 1
        handle = RequestHandle(rid=rid, request=request,
                               submit_clock=max(self.clock,
                                                request.arrival_time))
        self.handles[rid] = handle
        self._queue.append(_SlotState(handle))
        return handle

    @property
    def idle(self) -> bool:
        return (not self._queue and not self._swapped
                and all(s is None for s in self._slots))

    def advance_clock(self, t: float) -> None:
        """Idle-advance modeled time (trace drivers jump to next arrival)."""
        self.clock = max(self.clock, t)

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.idle:
                return
            self.step()
        raise RuntimeError(f"engine not idle after {max_steps} steps")

    # ---- the engine loop -------------------------------------------------
    def step(self) -> float:
        """One scheduling round: relieve KV pressure, swap in, admit,
        decode every running slot one token.  Returns modeled seconds."""
        dt = 0.0
        dt += self._relieve_pressure()
        dt += self._swap_in()
        dt += self._admit()
        dt += self._decode_once()
        self.clock += dt
        self.steps += 1
        return dt

    # ---- internals -------------------------------------------------------
    def _running(self) -> List[_SlotState]:
        return sorted((s for s in self._slots if s is not None),
                      key=lambda s: s.admit_seq)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def _pages_next(self, st: _SlotState) -> int:
        # pages needed to write the next token at position st.index; under
        # static reservation the full lifetime is held from admission on
        if self.cfg.reserve_lifetime:
            return self.budget.pages_for(st.target_len)
        return self.budget.pages_for(st.index + 1)

    def _relieve_pressure(self) -> float:
        """Preempt newest-admitted slots until every remaining running
        slot can write its next token within the tier-1 quota."""
        dt = 0.0
        running = self._running()
        while running:
            demand = sum(self._pages_next(s) for s in running)
            if demand <= self.budget.tier1_pages:
                break
            victim = running.pop()          # newest admission
            dt += self._preempt(victim)
        for st in running:
            self.kv.grow(st.rid, self._pages_next(st))
        return dt

    def _preempt(self, st: _SlotState) -> float:
        """Swap to tier-2 when the byte budget allows, else drop + requeue
        for recompute (the tier-1-only failure mode)."""
        slot = st.slot
        pages = self.kv.pages_of(st.rid)
        dt = 0.0
        spilled = False
        if self.budget.tier2_bytes > 0:     # skip the copy when spill-less
            payload = jax.tree.map(lambda l: np.asarray(l[slot]), self._cache)
            try:
                self.kv.spill(st.rid, payload)
                spilled = True
            except KVBudgetExceeded:
                pass                        # tier-2 full: fall back to drop
        if spilled:
            st.handle.status = RequestStatus.SWAPPED
            st.handle.swaps += 1
            self._swapped.append(st)
            self._swapped.sort(key=lambda s: s.rid)
            dt = self.cost.swap_s(pages * self.kv.page_bytes)
        else:
            self.kv.free(st.rid)
            st.handle.status = RequestStatus.QUEUED
            st.handle.recomputes += 1
            st.index = 0
            self._queue.appendleft(st)
        # zero the region so any bookkeeping bug is observable, not silent
        self._cache = jax.tree.map(lambda l: l.at[slot].set(0), self._cache)
        self._slots[slot] = None
        st.slot = None
        return dt

    def _swap_in(self) -> float:
        """Oldest swapped requests re-enter free slots before any fresh
        admission (they hold tier-2 bytes the pool wants back)."""
        dt = 0.0
        while self._swapped:
            st = self._swapped[0]
            slot = self._free_slot()
            if slot is None or self._pages_next(st) > self.kv.hot_free:
                break
            self._swapped.pop(0)
            payload = self.kv.fetch(st.rid)
            # reserve the next-token page now (the admission check above
            # sized against it) so a same-step admission can't steal it
            self.kv.grow(st.rid, self._pages_next(st))
            self._cache = jax.tree.map(
                lambda l, h: l.at[slot].set(jnp.asarray(h, l.dtype)),
                self._cache, payload)
            self._place(st, slot)
            dt += self.cost.swap_s(self.kv.pages_of(st.rid)
                                   * self.kv.page_bytes)
        return dt

    def _admit(self) -> float:
        """FIFO prefill admission (head-of-line blocking keeps the order
        deterministic; a request that can never fit fails immediately)."""
        dt = 0.0
        while self._queue:
            st = self._queue[0]
            if self.budget.pages_for(st.target_len) > self.budget.tier1_pages:
                self._queue.popleft()
                st.handle.status = RequestStatus.FAILED_OOM
                st.handle.done_clock = self.clock + dt
                continue
            slot = self._free_slot()
            eff = st.effective_prompt()
            need = (self.budget.pages_for(st.target_len)
                    if self.cfg.reserve_lifetime
                    else self.budget.pages_for(len(eff) + 1))
            if slot is None or need > self.kv.hot_free:
                break
            self._queue.popleft()
            dt += self._prefill_into(st, slot, eff)
        return dt

    def _prefill_into(self, st: _SlotState, slot: int,
                      eff: Tuple[int, ...]) -> float:
        # exact-length prefill: jit caches one program per distinct prompt
        # length (prefill returns last-position logits only, so padding
        # would discard the true next-token distribution)
        plen = len(eff)
        tokens = np.asarray(eff, np.int32)[None, :]
        slot_cache = self.model.init_cache(1, self.cfg.max_seq,
                                           dtype=self._cache_dtype)
        logits, cache = self._prefill_fn(self.params,
                                         {"tokens": jnp.asarray(tokens)},
                                         slot_cache)
        tok = int(np.argmax(np.asarray(logits)[0, -1]))
        self._emit(st, tok)
        if st.handle.done:
            return self.cost.prefill_s(plen)
        self.kv.alloc(st.rid,
                      self.budget.pages_for(st.target_len)
                      if self.cfg.reserve_lifetime
                      else self.budget.pages_for(plen + 1))
        self._cache = jax.tree.map(lambda l, s: l.at[slot].set(s),
                                   self._cache, cache)
        st.index = plen
        st.cur_tok = tok
        self._place(st, slot)
        return self.cost.prefill_s(plen)

    def _place(self, st: _SlotState, slot: int) -> None:
        st.slot = slot
        st.admit_seq = self._admit_seq
        self._admit_seq += 1
        self._slots[slot] = st
        self._slot_index[slot] = st.index
        self._slot_tok[slot] = st.cur_tok
        st.handle.status = RequestStatus.RUNNING

    def _emit(self, st: _SlotState, tok: int) -> None:
        st.handle.tokens.append(tok)
        if st.handle.first_token_clock is None:
            st.handle.first_token_clock = self.clock
        eos_hit = (self.cfg.eos_token is not None
                   and tok == self.cfg.eos_token)
        if len(st.handle.tokens) >= st.request.max_new_tokens or eos_hit:
            st.handle.status = RequestStatus.DONE
            st.handle.done_clock = self.clock
            if self.kv.holds(st.rid):
                self.kv.free(st.rid)
            if st.slot is not None:
                self._slots[st.slot] = None
                st.slot = None

    def _decode_once(self) -> float:
        running = self._running()
        if not running:
            return 0.0
        for st in running:
            self._slot_index[st.slot] = st.index
            self._slot_tok[st.slot] = st.cur_tok
        toks = jnp.asarray(self._slot_tok, jnp.int32).reshape(
            self.cfg.max_slots, 1, 1)
        idx = jnp.asarray(self._slot_index, jnp.int32)
        new_toks, self._cache = self._decode_fn(self.params, toks,
                                                self._cache, idx)
        new_toks = np.asarray(new_toks)
        for st in running:
            tok = int(new_toks[st.slot, 0, 0])
            st.index += 1
            st.cur_tok = tok
            self._decoded_tokens += 1
            self._emit(st, tok)
        return self.cost.decode_s(len(running))

    # ---- observability ---------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Throughput, queue depth, and KV tier residency."""
        n_running = sum(s is not None for s in self._slots)
        done = [h for h in self.handles.values()
                if h.status is RequestStatus.DONE]
        failed = [h for h in self.handles.values()
                  if h.status is RequestStatus.FAILED_OOM]
        recomputes = sum(h.recomputes for h in self.handles.values())
        swaps = sum(h.swaps for h in self.handles.values())
        return {
            "clock_s": self.clock,
            "steps": self.steps,
            "queue_depth": len(self._queue),
            "running": n_running,
            "swapped": len(self._swapped),
            "completed": len(done),
            "failed_oom": len(failed),
            "tokens_decoded": self._decoded_tokens,
            "throughput_tok_s": (self._decoded_tokens / self.clock
                                 if self.clock > 0 else 0.0),
            "preempt_swaps": swaps,
            "preempt_recomputes": recomputes,
            "kv": self.kv.residency(),
        }

"""repro.disagg — disaggregated prefill/decode serving over the routed
XLink-CXL fabric (paper §6: composable resource disaggregation).

The package binds one multi-pod lease into two tiers:

- ``prefill`` (``PrefillWorker``): bucketed prefill on prefill-pod
  engines, exporting KV page-by-page at modeled prefill-progress times
  via the colocated engine's own jitted path — bit-identical first
  tokens and page payloads.
- ``decode``: the receive side is the existing ``serve.Engine`` through
  its ``submit_prefilled`` seam — admission gated on KV arrival,
  partial-arrival slot occupancy, first decode gated on the last page.
- ``router`` (``DisaggCluster``, ``DisaggConfig``): per-request
  dispatch (prefill-queue depth + predicted transit vs a colocated
  fallback) on one modeled clock, streaming pages over the shared
  ``fabric.Transport`` as ``kv:<tenant>`` flows, either direct
  pod-to-pod or staged through a tier-2 memory node.

A degenerate cluster (``route=None``) replays the plain colocated
``Engine`` bit-for-bit — tokens *and* trace events — which is the
subsystem's correctness anchor: disaggregation moves *when* decode may
start, never *what* it computes.
"""

from repro.disagg.decode import decode_load, pick_decode_engine
from repro.disagg.prefill import PrefillRecord, PrefillWorker
from repro.disagg.router import DisaggCluster, DisaggConfig

__all__ = [
    "DisaggCluster",
    "DisaggConfig",
    "PrefillRecord",
    "PrefillWorker",
    "decode_load",
    "pick_decode_engine",
]

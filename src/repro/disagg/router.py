"""Per-request dispatch between a prefill tier and a decode tier on one
modeled clock.

``DisaggCluster`` glues the two tiers together: a router decides per
arriving request whether it takes the disaggregated path (prefill on a
prefill pod, KV pages streamed over allocator-placed fabric routes,
decode admitted on a decode pod as pages land) or the colocated
fallback (the decode engine prefills locally, exactly the plain
``Engine`` path).  All units — ``PrefillWorker``\\ s and decode
``Engine``\\ s — interleave with the verbatim ``run_multi_trace``
candidate rules, plus one extra candidate kind: the earliest *unrouted*
arrival, which when selected is only dispatched (bound to a unit's
pending queue), never stepped — so routing itself spends no modeled
time and adds no engine steps, and the degenerate single-pod cluster
(``route=None``) replays the plain ``run_trace(Engine)`` schedule
bit-for-bit, tokens and trace events alike.

KV handoff pricing happens here: every exported page enters the shared
``fabric.Transport`` at its prefill-progress departure time under the
``kv:<tenant>`` label, either directly over the pod-to-pod XLink/CXL
route (``staging="direct"``) or staged through a tier-2 memory node —
a write leg then a read leg, two separately-priced transfers
(``staging="tier2"``), which wins when the direct trunk is saturated.
The resulting per-page completion times gate decode-side admission and
first decode; the ``disagg-handoff`` sanitizer rule audits
transferred-before-use from the trace.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis import tiebreak
from repro.disagg.decode import decode_load
from repro.disagg.prefill import PrefillRecord, PrefillWorker
from repro.obs.trace import CAT_KV
from repro.serve.api import Request, RequestHandle

_STAGINGS = ("direct", "tier2")


@dataclasses.dataclass(frozen=True)
class DisaggConfig:
    """Routing and handoff policy knobs.

    staging          -- "direct": pages travel the pod-to-pod route in
                        one priced transfer each; "tier2": each page is
                        written to a staging memory node then read out,
                        two priced legs (``stage_in`` / ``stage_out``).
    min_ready_pages  -- decode-side admission gate: a handed-off
                        request may occupy a slot once this many pages
                        have landed (None: all pages — no partial
                        admission).  First decode always waits for the
                        last page regardless.
    max_transit_s    -- colocated fallback: route a request to the
                        decode tier directly when the solo-predicted
                        KV transit time exceeds this (None: never).
    max_prefill_depth -- colocated fallback: bypass the prefill tier
                        when every prefill queue is at least this deep
                        (None: never).
    """

    staging: str = "direct"
    min_ready_pages: Optional[int] = None
    max_transit_s: Optional[float] = None
    max_prefill_depth: Optional[int] = None

    def __post_init__(self):
        if self.staging not in _STAGINGS:
            raise ValueError(f"staging {self.staging!r} not in {_STAGINGS}")
        if self.min_ready_pages is not None and self.min_ready_pages < 1:
            raise ValueError("min_ready_pages must be >= 1")


class DisaggCluster:
    """One multi-pod lease split into a prefill tier and a decode tier.

    ``run(trace)`` drives a single arrival trace through the router and
    both tiers on one modeled clock and returns one ``RequestHandle``
    per request, in trace order — the same contract as
    ``serve.run_trace``.  With ``route=None`` (prefill and decode share
    a pod) every request takes the colocated path and the cluster is
    bit-identical — tokens *and* trace events — to the plain engine.
    """

    def __init__(self, prefill_workers: Sequence[PrefillWorker],
                 decode_engines: Sequence, *, transport=None,
                 route=None, stage_in=None, stage_out=None,
                 config: Optional[DisaggConfig] = None,
                 tenant: Optional[str] = None, tracer=None):
        if not decode_engines:
            raise ValueError("need at least one decode engine")
        self.prefill_workers = list(prefill_workers)
        self.decode_engines = list(decode_engines)
        self.cfg = config or DisaggConfig()
        self.transport = transport
        self.route = route
        self.stage_in = stage_in
        self.stage_out = stage_out
        self.tenant = tenant or "disagg"
        self.tracer = tracer if tracer is not None \
            else self.decode_engines[0].tracer
        if self.cfg.staging == "tier2":
            if stage_in is None or stage_out is None:
                raise ValueError(
                    "staging='tier2' needs stage_in and stage_out routes "
                    "(allocator handoff legs through the staging memory "
                    "node)")
        # degenerate: no fabric between the tiers — prefill and decode
        # share a pod, so every request takes the colocated path and
        # the prefill workers (if any) sit idle
        self.degenerate = route is None and self.cfg.staging == "direct"
        if not self.degenerate and transport is None:
            raise ValueError("a routed cluster needs the shared transport")
        self.handoffs = 0
        self.colocated = 0
        self._results: List[Optional[RequestHandle]] = []
        self._pend: List[deque] = []

    # ---- routing --------------------------------------------------

    def predict_transit(self, request: Request) -> float:
        """Solo (non-registering) prediction of this request's KV
        transit time — the router's fallback signal.  Uses the decode
        tier's page geometry; all decode engines share one config."""
        if self.degenerate:
            return 0.0
        eng = self.decode_engines[0]
        ps = eng.cfg.page_size
        n_pages = -(-request.prompt_len // ps)
        nbytes = n_pages * eng.kv.page_bytes
        if self.cfg.staging == "tier2":
            return (self.stage_in.transfer_time(nbytes)
                    + self.stage_out.transfer_time(nbytes))
        return self.route.transfer_time(nbytes)

    def _dispatch(self, request: Request, t: float) -> int:
        """Pick the unit index for an arriving request.  Keys are pure
        (load, index) total orders through the tiebreak seam."""
        n_pre = len(self.prefill_workers)
        colocate = self.degenerate or not self.prefill_workers
        if not colocate and self.cfg.max_prefill_depth is not None:
            depths = [w.depth + len(self._pend[j])
                      for j, w in enumerate(self.prefill_workers)]
            if min(depths) >= self.cfg.max_prefill_depth:
                colocate = True
        if not colocate and self.cfg.max_transit_s is not None:
            if self.predict_transit(request) > self.cfg.max_transit_s:
                colocate = True
        if colocate:
            self.colocated += 1
            cands = [(decode_load(e) + len(self._pend[n_pre + k]), k)
                     for k, e in enumerate(self.decode_engines)]
            return n_pre + min(tiebreak.order(cands))[1]
        cands = [(w.depth + len(self._pend[j]), j)
                 for j, w in enumerate(self.prefill_workers)]
        return min(tiebreak.order(cands))[1]

    # ---- handoff --------------------------------------------------

    def _handoff(self, rec: PrefillRecord) -> None:
        """Stream a finished prefill's pages over the fabric and plant
        the request on the least-loaded decode engine."""
        n_pre = len(self.prefill_workers)
        cands = [(decode_load(e) + len(self._pend[n_pre + k]), k)
                 for k, e in enumerate(self.decode_engines)]
        eng = self.decode_engines[min(tiebreak.order(cands))[1]]
        req = rec.request
        pages, deps = rec.pages, rec.departures
        on_use = None
        if req.max_new_tokens <= 1:
            # the first (and only) token was computed by the prefill
            # pod: nothing decodes, so no KV moves and no handoff
            # events are emitted
            ready = [rec.prefill_done] * len(pages)
            transit = 0.0
        else:
            pb = eng.kv.page_bytes
            label = f"kv:{self.tenant}"
            tx = self.transport
            ready = []
            for i, dep in enumerate(deps):
                if self.cfg.staging == "tier2":
                    # write leg into the staging memory node, then a
                    # read leg out of it -- two separately priced
                    # transfers, the read departing when the write lands
                    mid = tx.begin_transfer(self.stage_in, pb, dep,
                                            label=label)
                    ready.append(tx.begin_transfer(self.stage_out, pb, mid,
                                                   label=label))
                else:
                    ready.append(tx.begin_transfer(self.route, pb, dep,
                                                   label=label))
            transit = max(0.0, max(ready) - rec.prefill_done)
            if self.tracer.enabled:
                rid = rec.meta if isinstance(rec.meta, int) else -1
                track = f"disagg:req{rid}"
                # pages first, then the stream span: the span ends at
                # the last page's landing, so this order keeps the
                # per-request track's event ends monotone
                for i, dep in enumerate(deps):
                    self.tracer.instant(track, "handoff_page", dep,
                                        cat=CAT_KV, rid=rid, page=i,
                                        bytes=pb, ready_ts=ready[i])
                self.tracer.span(track, "handoff", deps[0],
                                 max(ready) - deps[0], cat=CAT_KV,
                                 rid=rid, pages=len(pages),
                                 bytes=pb * len(pages),
                                 staging=self.cfg.staging)
                tracer, n, last = self.tracer, len(pages), max(ready)

                def on_use(t: float, *, _tr=tracer, _track=track, _rid=rid,
                           _n=n, _last=last, _transit=transit) -> None:
                    _tr.instant(_track, "handoff_use", t, cat=CAT_KV,
                                rid=_rid, pages=_n, ready_ts=_last)
                    _tr.counter(_track, "kv_transit_s", t, _transit,
                                cat=CAT_KV)

        handle = eng.submit_prefilled(
            req, first_tok=rec.first_tok, prefill_done=rec.prefill_done,
            pages=pages, page_ready=ready,
            min_ready_pages=self.cfg.min_ready_pages,
            kv_transit_s=transit, submit_clock=rec.submit_clock,
            on_first_decode=on_use)
        self.handoffs += 1
        self._results[rec.meta] = handle

    def _drain_outboxes(self) -> None:
        for w in self.prefill_workers:
            while w.outbox:
                self._handoff(w.outbox.popleft())

    # ---- the clock ------------------------------------------------

    def run(self, trace: Sequence[Request], *,
            max_steps: int = 1_000_000) -> List[RequestHandle]:
        """Drive an arrival trace to completion; one handle per request
        in trace order."""
        order = sorted(range(len(trace)),
                       key=lambda i: (trace[i].arrival_time, i))
        units: List[Any] = list(self.prefill_workers) \
            + list(self.decode_engines)
        n_pre = len(self.prefill_workers)
        self._results = [None] * len(trace)
        self._pend = [deque() for _ in units]
        pend = self._pend
        nxt = 0                       # next unrouted request (order index)
        blocked: set = set()

        def feed(j: int) -> None:
            u = units[j]
            while pend[j] and trace[pend[j][0]].arrival_time <= u.clock:
                i = pend[j].popleft()
                if j < n_pre:
                    u.submit(trace[i], meta=i)
                else:
                    self._results[i] = u.submit(trace[i])

        for _ in range(max_steps):
            for j in range(len(units)):
                feed(j)
            cands: List[Tuple[float, int]] = []
            for j, u in enumerate(units):
                if not u.idle:
                    cands.append((u.clock, j))
                elif pend[j]:
                    cands.append((trace[pend[j][0]].arrival_time, j))
            if nxt < len(order):
                cands.append((trace[order[nxt]].arrival_time, -1))
            if not cands:
                missing = [i for i, h in enumerate(self._results)
                           if h is None]
                if missing:
                    raise RuntimeError(
                        f"cluster drained with unfinished requests "
                        f"{missing}")
                return list(self._results)
            live = [c for c in cands if c[1] not in blocked]
            if not live:
                raise RuntimeError(
                    "disagg deadlock: every unit is blocked and no "
                    "arrival can unblock them")
            # same selection rule as run_multi_trace: total-order min
            # over (event time, unit index); the routing pseudo-unit is
            # index -1 so at equal times a request is routed before any
            # real unit steps, and the racecheck seam permutes the list
            t, j = min(tiebreak.order(live))
            if j == -1:
                i = order[nxt]
                nxt += 1
                # routing binds the request to a unit's pending queue;
                # nothing steps and no modeled time passes
                pend[self._dispatch(trace[i], t)].append(i)
                blocked.clear()
                continue
            u = units[j]
            if u.idle:
                u.advance_clock(t)
                feed(j)
            before = u.clock
            dt = u.step()
            self._drain_outboxes()
            if dt > 0.0 or u.idle or u.clock != before:  # repro: allow(no-float-equality) identity test — did step() assign a new clock value at all, not a time comparison
                blocked.clear()
            else:
                others = [c[0] for c in cands if c[1] != j]
                if others:
                    u.advance_clock(min(others))
                blocked.add(j)
        raise RuntimeError(f"disagg trace not drained after "
                           f"{max_steps} steps")

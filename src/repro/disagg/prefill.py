"""The prefill tier: bucketed prefill on prefill-pod engines, exporting
KV page-by-page as the prefill progresses.

A ``PrefillWorker`` owns its own modeled clock and FIFO over a wrapped
``repro.serve.Engine`` used in *prefill-only* mode
(``Engine.prefill_export``): the compute path — bucket rounding, the
jitted ``prefill_at`` program, the modeled ``prefill_s(bucket)`` cost,
the last-position argmax — is byte-for-byte the colocated admission
path, so the first token and every exported page payload are
bit-identical to what a colocated prefill would have produced.  What
the worker adds is *time*: page ``i`` of the prompt is modeled as
complete (ready to enter the fabric) once the prefill has processed its
tokens, at ``start + cost * min((i+1)*page_size, prompt_len) / bucket``
— linear progress through the fused prefill program — so the router can
stream pages while the tail of the prompt is still prefilling.

The worker speaks the same unit protocol as ``Engine`` (``clock`` /
``idle`` / ``step() -> dt`` / ``advance_clock``), so ``DisaggCluster``
interleaves prefill and decode tiers on one modeled clock with the
exact ``run_multi_trace`` candidate rules.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, List

from repro.obs.trace import CAT_ENGINE, CAT_REQUEST
from repro.serve.api import Request


@dataclasses.dataclass(eq=False)
class PrefillRecord:
    """One request moving through (or out of) the prefill tier."""

    request: Request
    meta: Any = None               # router cookie (cluster request index)
    submit_clock: float = 0.0
    # filled at prefill completion:
    first_tok: int = 0
    pages: List[Any] = dataclasses.field(default_factory=list)
    departures: List[float] = dataclasses.field(default_factory=list)
    prefill_done: float = 0.0


class PrefillWorker:
    """FIFO prefill executor over one prefill-pod engine.

    ``step()`` prefills the queue head (one request per step, mirroring
    the engine's one-admission granularity) and moves the finished
    record — first token, per-page payloads, per-page fabric-entry
    times — to ``outbox`` for the router to stream and hand off."""

    def __init__(self, engine, *, name: str = "prefill"):
        self.engine = engine
        self.name = name
        self.clock = 0.0
        self.steps = 0
        self.busy_s = 0.0
        self.prefilled = 0
        self._queue: deque = deque()
        self.outbox: deque = deque()
        self._seq = 0

    @property
    def tracer(self):
        return self.engine.tracer

    @property
    def _track(self) -> str:
        return f"prefill:{self.name}"

    @property
    def depth(self) -> int:
        """Queue depth — the router's dispatch-pressure signal."""
        return len(self._queue)

    @property
    def idle(self) -> bool:
        return not self._queue

    def advance_clock(self, t: float) -> None:
        self.clock = max(self.clock, t)

    def submit(self, request: Request, meta: Any = None) -> PrefillRecord:
        """Enqueue a request for prefill (deterministic FIFO).  Token
        ids are validated here, exactly as ``Engine.submit`` would —
        the prefill tier is this request's admission edge."""
        cfg = self.engine.cfg
        if request.prompt_len + request.max_new_tokens > cfg.max_seq:
            raise ValueError(
                f"prompt {request.prompt_len} + max_new "
                f"{request.max_new_tokens} exceeds max_seq {cfg.max_seq}")
        vocab = self.engine.model.cfg.vocab
        bad = [t for t in request.prompt_tokens if not 0 <= t < vocab]
        if bad:
            raise ValueError(
                f"prompt token id {bad[0]} outside the model vocab "
                f"[0, {vocab}) — JAX would clamp it to a wrong embedding "
                f"instead of failing")
        rec = PrefillRecord(request, meta,
                            submit_clock=max(self.clock,
                                             request.arrival_time))
        rid = meta if isinstance(meta, int) else self._seq
        self._seq += 1
        self._queue.append(rec)
        if self.tracer.enabled:
            self.tracer.instant(self._track, "submit", rec.submit_clock,
                                cat=CAT_REQUEST, rid=rid,
                                prompt_len=request.prompt_len,
                                max_new=request.max_new_tokens)
        return rec

    def step(self) -> float:
        """Prefill the queue head if it has arrived; else idle-advance
        to its arrival (the same jump ``Engine.step`` makes).  Returns
        modeled seconds."""
        dt = 0.0
        if self._queue:
            rec = self._queue[0]
            if rec.request.arrival_time > self.clock:
                self.advance_clock(rec.request.arrival_time)
            else:
                dt = self._prefill(rec)
                self._queue.popleft()
                self.outbox.append(rec)
                self.prefilled += 1
        self.clock += dt
        if dt > 0.0:
            self.busy_s += dt
        self.steps += 1
        return dt

    def _prefill(self, rec: PrefillRecord) -> float:
        eng = self.engine
        prompt = rec.request.prompt_tokens
        plen = len(prompt)
        tok, pages, cost = eng.prefill_export(prompt)
        bucket = eng._bucket_len(plen)
        ps = eng.cfg.page_size
        start = self.clock
        # page i is fabric-ready once its last real token is prefilled:
        # linear progress through the fused bucket program, so early
        # pages stream while the prompt tail is still computing
        rec.departures = [start + cost * (min((i + 1) * ps, plen) / bucket)
                          for i in range(len(pages))]
        rec.prefill_done = start + cost
        rec.first_tok = tok
        rec.pages = pages
        if self.tracer.enabled:
            rid = rec.meta if isinstance(rec.meta, int) else -1
            self.tracer.span(self._track, "prefill", start, cost,
                             cat=CAT_ENGINE, rid=rid, bucket=bucket,
                             prompt_len=plen)
        return cost

"""The decode tier: receiving streamed KV into the decode-side paged
pool and running the existing ONE-jitted decode loop.

There is deliberately almost no machinery here — the receive side *is*
the colocated ``repro.serve.Engine``, entered through its
``submit_prefilled`` seam: a handed-off request carries its first token
(computed on the prefill pod), its page payloads, and the modeled
fabric completion time of every page.  The engine gates admission on
the first ``min_ready_pages`` arrivals (pages are written into
``PagedKV`` the moment a slot frees), and gates the request's *first
decode step* on the final page's arrival — partial-arrival admission
with transferred-before-use decode, which the ``disagg-handoff``
sanitizer rule checks from the trace.

Because the engine decodes a handed-off row with exactly the same
jitted program, page layout, and arbiter state transitions it would use
for a locally-prefilled row, the decoded tokens are bit-identical to
the colocated run — the fabric only moves *when* decode may start,
never *what* it computes.

What does live here is tier placement: ``decode_load`` /
``pick_decode_engine`` define the deterministic least-loaded choice the
router uses to spread handoffs across the decode tier.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.analysis import tiebreak


def decode_load(engine) -> int:
    """Outstanding work on a decode engine: occupied slots + queued
    requests + handoffs still waiting for pages/slots.  Pure integers —
    the router's placement key must be a total order."""
    occupied = sum(1 for s in engine._slots if s is not None)
    return occupied + len(engine._queue) + len(engine._handoffs) + len(
        engine._paused)


def pick_decode_engine(engines: Sequence) -> int:
    """Index of the least-loaded decode engine, lowest index winning
    ties.  Routed through ``tiebreak.order`` so ``--racecheck`` can
    perturb the choice and prove outcomes don't depend on it beyond the
    documented (load, index) key."""
    cands: List[Tuple[int, int]] = [(decode_load(e), j)
                                    for j, e in enumerate(engines)]
    return min(tiebreak.order(cands))[1]

"""Per-(architecture x shape) sharding profiles.

Derives the logical-axis → mesh-axis rule table from the model config and
the mesh, honoring divisibility (GSPMD pads non-divisible shardings, which
wastes compute — we avoid it structurally):

* attention: head-sharded over ``model`` when heads divide the axis,
  otherwise context-parallel (q sharded on sequence, K/V gathered — exact
  for GQA since KV is small);
* MLP: Megatron column→row on d_ff over ``model``;
* MoE: expert-parallel over ``model`` when n_experts divides it (olmoe),
  else per-expert d_ff tensor parallel (mixtral);
* parameters: FSDP over the ``data`` axes on the ``embed`` dim (ZeRO-3
  analogue; GSPMD inserts per-layer all-gathers inside the layer scan);
* decode: KV cache head-sharded when divisible, else sequence-sharded
  (flash-decode style partial-softmax reductions are GSPMD-native);
* ``long_500k`` (batch=1): batch unsharded, cache sequence spread over
  all axes.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

from jax.sharding import Mesh

from repro.core.compat import IS_OLD_JAX
from repro.models.config import ModelConfig, ShapeConfig
from repro.sharding.partition import Rules


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def hierarchical_unsafe(cfg: ModelConfig) -> Optional[str]:
    """Detect archs that hard-crash jax 0.4.x XLA under hierarchical dp.

    The partially-manual ('pod') shard_map trips a partitioner CHECK
    (``IsManualSubgroup``, hlo_sharding_util.cc) on the backward of the
    per-layer norm-scale broadcast inside the layer scan — reproduced
    minimally as scan + parametric-norm multiply + grad under a manual
    subgroup; no rule table avoids it.  Every parametric-norm arch is
    affected (the tied-embedding qwen family is the motivating case from
    the ROADMAP); OLMo's non-parametric LN is safe, as is new-XLA jax.
    Returns the reason string, or None when hierarchical dp is safe.
    """
    if not IS_OLD_JAX:
        return None
    if cfg.norm_type != "nonparam_ln":
        tied = " tied-embedding" if cfg.tie_embeddings else ""
        return (f"{cfg.name}:{tied} arch with parametric {cfg.norm_type} "
                f"scales in the layer scan trips the jax 0.4.x XLA "
                f"IsManualSubgroup CHECK under hierarchical dp")
    return None


def make_rules(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
               fsdp: bool = True, dp_mode: str = "auto") -> Rules:
    ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = ax.get("model", 1)
    data_axes = tuple(a for a in ("pod", "data") if a in ax)
    n_data = _prod(ax[a] for a in data_axes)

    B = shape.global_batch
    if shape.kind == "train" and shape.microbatches > 1:
        B = B // shape.microbatches

    # ---- batch placement ----
    if B % n_data == 0:
        batch_axes: Optional[Tuple[str, ...]] = data_axes
    elif "data" in ax and B % ax["data"] == 0:
        batch_axes = ("data",)
    else:
        batch_axes = None  # e.g. long_500k batch=1

    heads_div = cfg.n_heads > 0 and cfg.n_heads % model_n == 0
    kv_div = cfg.n_kv_heads > 0 and cfg.n_kv_heads % model_n == 0

    t: Dict[str, object] = {
        "batch": batch_axes,
        "layers": None,
        "seq_q": None,
        "embed": "data" if (fsdp and "data" in ax) else None,
        "embed_norm": None,
        "vocab": "model",
        "ff": "model",
        "qkv_out": "model",
        "kv_out": "model" if kv_div else None,
        "head_dim": None,
        "heads": "model" if heads_div else None,
        "kv_heads": "model" if kv_div else None,
        # context-parallel fallback when heads don't divide the axis
        "seq_attn": None if heads_div else "model",
        "seq_kv": None,
        # MoE
        "moe_groups": batch_axes,
        "expert_router": None,
        "expert": ("model" if (cfg.n_experts and cfg.n_experts % model_n == 0)
                   else None),
        "expert_ff": ("model" if not (cfg.n_experts and cfg.n_experts % model_n == 0)
                      else None),
        # SSM
        "ssm_inner_proj": "model",
        "ssm_conv_ch": "model",
        "ssm_heads": ("model" if (cfg.family in ("ssm", "hybrid")
                                  and cfg.ssm_heads % model_n == 0) else None),
        "ssm_inner": "model",
        "ssm_inner_norm": None,
    }

    # jax 0.4.x XLA landmine: refuse rule sets that would hard-crash the
    # process (SIGABRT, not an exception) at compile time.  Callers catch
    # the ValueError and fall back to flat dp (launch/train.py does this
    # automatically).
    if dp_mode == "hierarchical" and "pod" in ax:
        reason = hierarchical_unsafe(cfg)
        if reason:
            raise ValueError(
                f"refusing hierarchical sharding rules: {reason}; use "
                f"dp_mode='auto' (flat GSPMD) for this arch on jax 0.4.x")

    if shape.kind == "decode":
        # one-token queries: context parallelism is meaningless; spread the
        # KV cache instead.
        t["seq_attn"] = None
        if not kv_div:
            t["seq_kv"] = "model"
        if batch_axes is None:
            # long_500k: single sequence — put the cache sequence (and ssm
            # heads) across everything available.
            t["seq_kv"] = (("data", "model") if kv_div
                           else tuple(a for a in ("data", "model") if a in ax))
            if kv_div:
                t["kv_heads"] = None  # seq takes both axes
    return Rules(t)


def describe(rules: Rules) -> str:
    return ", ".join(f"{k}={v}" for k, v in sorted(rules.table.items())
                     if v is not None)

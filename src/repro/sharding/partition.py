"""Logical-axis sharding: named activation/parameter axes → mesh axes.

Model code annotates tensors with *logical* axis names ("batch", "embed",
"ff", "heads", ...).  A per-(arch, mesh) rule table maps logical names to
mesh axes.  This keeps model code mesh-agnostic (the ScalePool
composability requirement: any cluster shape, same model code).

Usage:
    rules = Rules({"batch": ("pod", "data"), "ff": "model", ...})
    with use_rules(rules):
        x = constrain(x, "batch", "seq", "embed")
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


class Rules:
    """Mapping from logical axis name → mesh axis (or tuple, or None)."""

    def __init__(self, table: Dict[str, MeshAxes]):
        self.table = dict(table)

    def spec(self, *logical_axes: Optional[str]) -> P:
        out = []
        used: set = set()
        for name in logical_axes:
            if name is None:
                out.append(None)
                continue
            axes = self.table.get(name)
            # a mesh axis may appear at most once in a PartitionSpec
            if axes is None:
                out.append(None)
            elif isinstance(axes, str):
                if axes in used:
                    out.append(None)
                else:
                    used.add(axes)
                    out.append(axes)
            else:
                free = tuple(a for a in axes if a not in used)
                used.update(free)
                out.append(free if free else None)
        return P(*out)

    def override(self, **kw: MeshAxes) -> "Rules":
        t = dict(self.table)
        t.update(kw)
        return Rules(t)

    def strip_axis(self, axis: str) -> "Rules":
        """Remove one mesh axis from every rule (used inside shard_map
        bodies where that axis is manual)."""
        t: Dict[str, MeshAxes] = {}
        for k, v in self.table.items():
            if v is None or v == axis:
                t[k] = None if v == axis else v
            elif isinstance(v, tuple):
                kept = tuple(a for a in v if a != axis)
                t[k] = kept if kept else None
            else:
                t[k] = v
        return Rules(t)


class _Ctx(threading.local):
    def __init__(self):
        self.rules: Optional[Rules] = None
        self.mesh: Optional[Mesh] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_rules(rules: Optional[Rules], mesh: Optional[Mesh] = None):
    prev = (_CTX.rules, _CTX.mesh)
    _CTX.rules, _CTX.mesh = rules, mesh
    try:
        yield
    finally:
        _CTX.rules, _CTX.mesh = prev


def current_rules() -> Optional[Rules]:
    return _CTX.rules


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint if rules are active; identity otherwise.

    Model code calls this unconditionally — on a single CPU device (smoke
    tests) it is a no-op, under the dry-run mesh it pins GSPMD decisions.
    """
    rules = _CTX.rules
    if rules is None:
        return x
    spec = rules.spec(*logical_axes)
    if _CTX.mesh is not None:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(_CTX.mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def logical_to_sharding(mesh: Mesh, rules: Rules, logical_axes) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(*logical_axes))


def is_axes_leaf(x) -> bool:
    """A logical-axes leaf is a plain tuple of axis names / None (possibly
    empty) — NOT a NamedTuple (those are pytree nodes, e.g. optimizer
    states)."""
    return (type(x) is tuple
            and all(e is None or isinstance(e, str) for e in x))


def tree_shardings(mesh: Mesh, rules: Rules, logical_tree):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: logical_to_sharding(mesh, rules, axes),
        logical_tree,
        is_leaf=is_axes_leaf,
    )

"""Lint: no bare ``print(`` in the library (``src/repro/``).

Library code must report through ``repro.obs`` — metrics via the
registry, timelines via the tracer, and any human-facing console
output through the one sanctioned site, ``repro.obs.console``.  A bare
``print`` in ``src/repro`` is either debug residue or a report that
belongs in the registry, so CI fails on it.

AST-based (not grep): only actual ``print(...)`` *calls* of the
builtin count — the word appearing in a docstring, comment, or as an
attribute (``obj.print(...)``) does not.  ``benchmarks/``, ``scripts/``
and ``examples/`` are CLI surfaces and stay free to print.

    python scripts/lint_no_print.py            # lints src/repro
    python scripts/lint_no_print.py PATH...    # lint specific trees
"""
import ast
import sys
from pathlib import Path

ALLOWED = {Path("src/repro/obs/console.py")}


def print_calls(path: Path):
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "print"):
            yield node.lineno


def main(argv=None) -> int:
    roots = [Path(p) for p in (argv or sys.argv[1:])] or [Path("src/repro")]
    bad = []
    for root in roots:
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            if f in ALLOWED:
                continue
            for line in print_calls(f):
                bad.append(f"{f}:{line}")
    if bad:
        sys.stderr.write(
            "bare print() in library code (use repro.obs.console or the "
            "metrics registry):\n  " + "\n  ".join(bad) + "\n")
        return 1
    sys.stderr.write(f"lint_no_print: clean "
                     f"({', '.join(str(r) for r in roots)})\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""DEPRECATED shim — the bare-print lint moved into the framework.

This entry point is kept so existing CI invocations and docs don't
break; it now delegates to ``repro.analysis.lints`` running ONLY the
``no-bare-print`` rule.  Prefer the full rule set:

    PYTHONPATH=src python -m repro.analysis.lints [PATH...]

which adds ``no-wallclock``, ``compat-imports`` and
``no-mutable-default`` on top, with per-line
``# repro: allow(<rule>)`` suppressions.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "src"))

from repro.analysis.lints import main                   # noqa: E402

if __name__ == "__main__":
    sys.stderr.write("lint_no_print.py is a deprecation shim: running "
                     "repro.analysis.lints --rule no-bare-print\n")
    argv = sys.argv[1:] or ["src/repro"]
    raise SystemExit(main(["--rule", "no-bare-print"] + argv))

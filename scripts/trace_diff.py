#!/usr/bin/env python
"""A/B trace differ: structurally compare two trace recordings and
report the first divergent event per track.

    PYTHONPATH=src python scripts/trace_diff.py A.json B.json
        [--json REPORT.json] [--expect-identical]

Inputs may be exported Perfetto/Chrome JSON documents or lossless
``obs.JsonlSink`` streams (``*.jsonl``, from ``--trace-stream``) — the
two sides need not use the same format.  Exit status 1 if the traces
differ (the first divergent event per track is named, with clock and
by-label byte drift summaries); ``--json`` writes the diff document
for CI artifacts.  ``--expect-identical`` is implied — the flag exists
for self-documenting CI invocations.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "src"))

from repro.analysis import diff_trace_files             # noqa: E402
from repro.obs.console import emit                      # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="compare two trace recordings event by event; "
                    "nonzero exit on the first divergence")
    ap.add_argument("trace_a", metavar="A.json|A.jsonl")
    ap.add_argument("trace_b", metavar="B.json|B.jsonl")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the diff report as JSON")
    ap.add_argument("--expect-identical", action="store_true",
                    help="(default behavior; for readable CI steps)")
    args = ap.parse_args(argv)
    diff = diff_trace_files(args.trace_a, args.trace_b)
    emit(f"== {args.trace_a} vs {args.trace_b}")
    emit(diff.format())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(diff.to_doc(), f, indent=2)
            f.write("\n")
    return 0 if diff.identical else 1


if __name__ == "__main__":
    raise SystemExit(main())

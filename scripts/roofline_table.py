"""Render EXPERIMENTS.md §Roofline markdown tables from dry-run artifacts."""

import glob
import json
import sys
from collections import defaultdict

ART = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"


def fmt_t(s):
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.1f}ms"
    return f"{s*1e6:.0f}us"


def main():
    recs = []
    for fp in sorted(glob.glob(f"{ART}/*.json")):
        if any(t in fp for t in ("-smoke", "-xval", "-pytest", "-perf")):
            continue
        recs.append(json.loads(open(fp).read()))

    for mesh in ("single", "multi"):
        print(f"\n### {'Single-pod 16x16 (256 chips)' if mesh == 'single' else 'Multi-pod 2x16x16 (512 chips)'}\n")
        print("| arch | shape | compute | memory | collective | dominant | "
              "useful-flops | roofline-frac | bottleneck note |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in recs:
            if r.get("mesh") != mesh:
                continue
            tag = f"| {r['arch']} | {r['shape']} "
            if r["status"] == "SKIP":
                print(tag + "| — | — | — | SKIP | — | — | "
                      "full-attention arch at 500k ctx (per spec) |")
                continue
            if r["status"] != "OK":
                print(tag + f"| — | — | — | FAIL | — | — | {r.get('error','')[:40]} |")
                continue
            ro = r["roofline"]
            dom_t = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
            frac = ro["compute_s"] / dom_t if dom_t else 0
            note = {
                "compute": "at compute roofline",
                "memory": "HLO byte traffic exceeds HBM-normalized compute",
                "collective": "ICI traffic dominates (sharding-induced)",
            }[ro["dominant"]]
            print(tag +
                  f"| {fmt_t(ro['compute_s'])} | {fmt_t(ro['memory_s'])} "
                  f"| {fmt_t(ro['collective_s'])} | {ro['dominant']} "
                  f"| {ro.get('useful_flops_ratio', 0):.2f} "
                  f"| {frac:.2f} | {note} |")


if __name__ == "__main__":
    main()

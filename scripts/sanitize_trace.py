#!/usr/bin/env python
"""Offline modeled-time sanitizer: replay trace files through
``repro.analysis`` and fail on causality / conservation violations.

    PYTHONPATH=src python scripts/sanitize_trace.py TRACE.json [...]
        [--json REPORT.json]

Inputs may be exported Perfetto/Chrome JSON documents or lossless
``obs.JsonlSink`` streams (``*.jsonl``, from ``--trace-stream``).
Exit status 1 if any trace violates an invariant (the report names
rule, track, and modeled timestamp per violation).  ``--json`` writes
the report document(s) for CI artifacts; with several inputs the file
holds ``{path: report}``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "src"))

from repro.analysis import sanitize_events, sanitize_trace_file  # noqa: E402
from repro.obs.console import emit                      # noqa: E402


def _sanitize(path: str):
    if path.endswith(".jsonl"):
        from repro.obs import events_from_jsonl
        # a JSONL stream is lossless by construction — never truncated
        return sanitize_events(events_from_jsonl(path))
    return sanitize_trace_file(path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="check exported traces against the modeled-time "
                    "causality and conservation invariants")
    ap.add_argument("traces", nargs="+", metavar="TRACE.json|TRACE.jsonl")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the sanitizer report(s) as JSON")
    args = ap.parse_args(argv)
    reports = {}
    ok = True
    for path in args.traces:
        report = _sanitize(path)
        reports[path] = report.to_doc()
        emit(f"== {path}")
        emit(report.format())
        ok &= report.ok
    if args.json:
        doc = (next(iter(reports.values())) if len(reports) == 1
               else reports)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Print the top collectives (by per-device moved bytes) of one cell's
compiled HLO — the §Perf 'profile' on a dry-run-only platform."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys
from collections import defaultdict

import jax
jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")

sys.path.insert(0, "src")
from repro.configs import get_config
from repro.launch import hlo_analysis as H
from repro.launch.dryrun_cell import (TRAIN_MICROBATCHES, _lower_and_compile,
                                      _attach)
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model
from repro.models.config import SHAPES, ShapeConfig
from repro.models.unroll import unroll_mode
from repro.optim.adamw import AdamW
from repro.runtime import train as train_rt
from repro.sharding.partition import use_rules
from repro.sharding.profiles import make_rules

arch = sys.argv[1] if len(sys.argv) > 1 else "olmoe-1b-7b"
fsdp = "--no-fsdp" not in sys.argv

cfg = get_config(arch)
shape0 = SHAPES["train_4k"]
micro = TRAIN_MICROBATCHES.get(arch, 1)
shape = ShapeConfig("train_4k", "train", shape0.seq_len,
                    shape0.global_batch // micro, microbatches=1)
mesh = make_production_mesh(multi_pod=False)
rules = make_rules(cfg, shape, mesh, fsdp=fsdp)
model = build_model(cfg, moe_groups=16)

with use_rules(rules, mesh), unroll_mode(1):
    lowered = _lower_and_compile(cfg, shape, mesh, rules, model, AdamW(),
                                 dp_mode="auto", donate=True)
    compiled = lowered.compile()

txt = compiled.as_text()
ops = H.parse_collectives(txt, pod_size=256)
# aggregate by (kind, result_bytes) signature
agg = defaultdict(lambda: [0, 0.0])
for op in ops:
    key = (op.kind, op.result_bytes, op.group_size)
    agg[key][0] += 1
    agg[key][1] += op.moved_bytes

rows = sorted(agg.items(), key=lambda kv: -kv[1][1])[:15]
total = sum(v[1] for v in agg.values())
print(f"{arch} fsdp={fsdp}: total per-device collective bytes "
      f"(k=1 lowering, x{micro} micro x{cfg.n_layers} layers at runtime): "
      f"{total/1e9:.2f} GB")
for (kind, rb, gs), (count, moved) in rows:
    print(f"  {kind:20s} result={rb/1e6:9.2f}MB group={gs:4d} x{count:3d} "
          f"-> {moved/1e9:8.3f} GB ({moved/total*100:4.1f}%)")

"""Two-level calibration: per-workload ib_load bisection to a target per-model
speedup profile, then global knobs for the inter-comm average."""
import sys, dataclasses, itertools
sys.path.insert(0, "src")
from repro.core import simulator as sim

PROFILE = {"GPT-3": 1.05, "Gopher": 1.12, "Llama-3": 1.10, "PaLM": 1.84, "Megatron": 1.04}

def speedup_for(w, ib_load, calib):
    c = dataclasses.replace(calib, ib_load=ib_load, cxl_load=w.cxl_load)
    base = sim.simulate_step(w.model, w.par, sim.make_system("baseline", w.par.n_gpus, c))
    sp = sim.simulate_step(w.model, w.par, sim.make_system("scalepool", w.par.n_gpus, c))
    return sim.Fig6Row(w.model.name, base, sp)

def bisect_load(w, target, calib):
    lo, hi = 0.0, 0.95
    r_lo, r_hi = speedup_for(w, lo, calib).speedup, speedup_for(w, hi, calib).speedup
    if r_hi < target:   # cannot reach even at max load
        return hi, r_hi
    if r_lo > target:
        return lo, r_lo
    for _ in range(40):
        mid = 0.5 * (lo + hi)
        r = speedup_for(w, mid, calib).speedup
        if r < target: lo = mid
        else: hi = mid
    return hi, speedup_for(w, hi, calib).speedup

results = []
for mfu, oversub, ports, ov, cxl_load in itertools.product(
        [0.40, 0.45, 0.50], [1.0, 1.5, 2.0], [1, 2], [0.5, 0.75, 1.0], [0.2, 0.3, 0.5]):
    calib = sim.Calibration(mfu=mfu, ib_oversubscription=oversub,
                            cxl_ports_per_accel=ports, dp_overlap=ov)
    loads, rows = {}, []
    for w in sim.FIG6_WORKLOADS:
        w2 = dataclasses.replace(w, cxl_load=cxl_load)
        load, sp = bisect_load(w2, PROFILE[w.model.name], calib)
        loads[w.model.name] = round(load, 3)
        rows.append(speedup_for(w2, load, calib))
    s = sim.fig6_summary(rows)
    err = (2*abs(s["avg_speedup"]-1.22)/1.22 + 2*abs(s["max_speedup"]-1.84)/1.84
           + abs(s["avg_comm_inter_speedup"]-3.79)/3.79)
    results.append((err, dict(mfu=mfu, o=oversub, p=ports, ov=ov, cl=cxl_load), loads, s,
                    [(r.model, round(r.speedup, 3)) for r in rows]))

results.sort(key=lambda t: t[0])
for err, knobs, loads, s, per in results[:6]:
    print(f"err={err:.4f} {knobs} loads={loads}")
    print(f"   avg={s['avg_speedup']:.3f} max={s['max_speedup']:.3f} comm={s['avg_comm_speedup']:.3f} inter={s['avg_comm_inter_speedup']:.2f} {per}")

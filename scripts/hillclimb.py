"""§Perf hillclimbing driver: run named variants of the three selected
cells, write tagged artifacts, and print before/after roofline deltas.

    PYTHONPATH=src python scripts/hillclimb.py [variant ...]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import sys
from pathlib import Path

import jax
jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")

sys.path.insert(0, "src")
from repro.launch.dryrun_cell import lower_cell  # noqa: E402

OUT = Path("artifacts/perf")
OUT.mkdir(parents=True, exist_ok=True)

# (name, cell-args, lower_cell kwargs)
VARIANTS = {
    # ---- Cell A: olmoe-1b-7b / train_4k / single (worst roofline frac,
    #      most collective-bound: coll 12.4s vs compute 0.28s) ----
    "olmoe-A1-nofsdp": (
        ("olmoe-1b-7b", "train_4k", False),
        dict(fsdp=False),
    ),
    "olmoe-A2-nofsdp-cap1": (
        ("olmoe-1b-7b", "train_4k", False),
        dict(fsdp=False, cfg_patch={"capacity_factor": 1.0}),
    ),
    "olmoe-A3-nofsdp-micro1": (
        ("olmoe-1b-7b", "train_4k", False),
        dict(fsdp=False, micro_override=1),
    ),
    # A4: bf16 combine accumulation (code change in repro.models.moe) —
    # measured against the fp32-combine baseline artifact.
    "olmoe-A4-bf16combine": (
        ("olmoe-1b-7b", "train_4k", False),
        dict(),
    ),
    "olmoe-A5-bf16-cap1": (
        ("olmoe-1b-7b", "train_4k", False),
        dict(cfg_patch={"capacity_factor": 1.0}),
    ),
    # A6: gather-based dispatch (code change in repro.models.moe)
    "olmoe-A6-gather-dispatch": (
        ("olmoe-1b-7b", "train_4k", False),
        dict(),
    ),
    "olmoe-A7-gather-cap1": (
        ("olmoe-1b-7b", "train_4k", False),
        dict(cfg_patch={"capacity_factor": 1.0}),
    ),
    "mixtral-C4-gather-dispatch": (
        ("mixtral-8x7b", "train_4k", False),
        dict(),
    ),
    "mixtral-C5-gather-micro4": (
        ("mixtral-8x7b", "train_4k", False),
        dict(micro_override=4),
    ),
    # ---- Cell B: command-r-plus-104b / train_4k / multi (the paper's
    #      technique cell: cross-pod fabric traffic) ----
    # NOTE: dp_mode=hierarchical with FSDP(data)-sharded grads trips an
    # XLA SPMD-partitioner CHECK at 512 devices (replica-group
    # factorization); the hierarchical phase therefore runs with the
    # non-FSDP parameter layout (documented in EXPERIMENTS.md §Perf).
    "commandr-B1-hier": (
        ("command-r-plus-104b", "train_4k", True),
        dict(dp_mode="hierarchical", fsdp=False),
    ),
    "commandr-B2-hier-int8": (
        ("command-r-plus-104b", "train_4k", True),
        dict(dp_mode="hierarchical", fsdp=False, compress_pod=True),
    ),
    "commandr-B0-nofsdp": (
        ("command-r-plus-104b", "train_4k", True),
        dict(fsdp=False),
    ),
    "commandr-B1f-hier-fsdp": (
        ("command-r-plus-104b", "train_4k", True),
        dict(dp_mode="hierarchical", donate=False),
    ),
    "commandr-B2f-hier-fsdp-int8": (
        ("command-r-plus-104b", "train_4k", True),
        dict(dp_mode="hierarchical", compress_pod=True, donate=False),
    ),
    "commandr-B3-micro4": (
        ("command-r-plus-104b", "train_4k", True),
        dict(micro_override=4),
    ),
    "commandr-B5-micro2": (
        ("command-r-plus-104b", "train_4k", True),
        dict(micro_override=2),
    ),
    # ---- Cell C: mixtral-8x7b / train_4k / single (MoE FFN-sharded
    #      dispatch + FSDP gather traffic) ----
    "mixtral-C1-2dexpert": (
        ("mixtral-8x7b", "train_4k", False),
        dict(rules_patch={"expert_ff": ("data", "model"), "embed": None}),
    ),
    "mixtral-C2-2dexpert-micro4": (
        ("mixtral-8x7b", "train_4k", False),
        dict(rules_patch={"expert_ff": ("data", "model"), "embed": None},
             micro_override=4),
    ),
    "mixtral-C3-cap1": (
        ("mixtral-8x7b", "train_4k", False),
        dict(rules_patch={"expert_ff": ("data", "model"), "embed": None},
             cfg_patch={"capacity_factor": 1.0}),
    ),
}


def baseline_path(arch, shape, multi):
    mesh = "multi" if multi else "single"
    return Path(f"artifacts/dryrun/{arch}__{shape}__{mesh}.json")


def main():
    names = sys.argv[1:] or list(VARIANTS)
    for name in names:
        (arch, shape, multi), kw = VARIANTS[name]
        fp = OUT / f"{name}.json"
        try:
            rec = lower_cell(arch, shape, multi, **kw)
        except Exception as e:  # noqa: BLE001
            import traceback
            rec = {"status": "FAIL", "error": str(e),
                   "traceback": traceback.format_exc()[-1500:]}
        rec["variant"] = name
        rec["variant_kwargs"] = {k: str(v) for k, v in kw.items()}
        fp.write_text(json.dumps(rec, indent=2))
        if rec["status"] != "OK":
            print(f"[FAIL] {name}: {rec.get('error', '')[:160]}", flush=True)
            continue
        base = json.loads(baseline_path(arch, shape, multi).read_text())
        br, vr = base["roofline"], rec["roofline"]
        print(f"[OK] {name}", flush=True)
        for term in ("compute_s", "memory_s", "collective_s"):
            print(f"     {term:13s} {br[term]:10.3f} -> {vr[term]:10.3f}  "
                  f"({vr[term]/max(br[term],1e-12):5.2f}x)", flush=True)
        print(f"     cross_pod_GB  {br['cross_pod_bytes']/1e9:10.2f} -> "
              f"{vr['cross_pod_bytes']/1e9:10.2f}", flush=True)
        print(f"     useful_flops  {br.get('useful_flops_ratio',0):10.3f} -> "
              f"{vr.get('useful_flops_ratio',0):10.3f}", flush=True)
        bdom = max(br['compute_s'], br['memory_s'], br['collective_s'])
        vdom = max(vr['compute_s'], vr['memory_s'], vr['collective_s'])
        print(f"     step_bound_s  {bdom:10.3f} -> {vdom:10.3f}  "
              f"roofline_frac {br['compute_s']/bdom:.3f} -> "
              f"{vr['compute_s']/vdom:.3f}", flush=True)


if __name__ == "__main__":
    main()

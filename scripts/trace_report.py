"""Inspect an exported Perfetto/Chrome trace_event JSON offline.

Reads a trace file written by ``--trace-out`` (``repro.launch.serve``,
``benchmarks/fig10_contention.py``) or ``repro.obs.write_chrome_trace``
and prints, without needing the live ``Transport``:

* the track inventory (events per pid/tid row),
* the per-link utilization / queueing-delay report reconstructed from
  the link-occupancy spans (busy seconds = interval union, stretch =
  span duration beyond solo serialization), folded by fabric tier,
* schema validation problems, if any (exit 1 when the file would not
  load cleanly in ui.perfetto.dev).

    PYTHONPATH=src python scripts/trace_report.py run.json
    PYTHONPATH=src python scripts/trace_report.py run.json --links-only
"""
import argparse
import json
import sys
from collections import Counter

sys.path.insert(0, "src")
from repro.obs import (format_link_report, link_report_from_trace,  # noqa: E402
                       tier_report, validate_trace_events)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("trace", help="trace_event JSON written by --trace-out")
    p.add_argument("--links-only", action="store_true",
                   help="print only the per-link report")
    args = p.parse_args(argv)

    with open(args.trace) as f:
        doc = json.load(f)
    problems = validate_trace_events(doc)
    events = doc.get("traceEvents", [])

    if not args.links_only:
        names = {}
        for e in events:
            if e.get("ph") == "M" and e.get("name") == "thread_name":
                names[(e["pid"], e["tid"])] = e["args"]["name"]
        per_track = Counter(
            names.get((e.get("pid"), e.get("tid")), "?")
            for e in events if e.get("ph") in ("X", "i", "C"))
        print(f"{args.trace}: {len(events)} events, "
              f"{len(per_track)} tracks "
              f"(recorded={doc.get('otherData', {}).get('events_recorded')}, "
              f"dropped={doc.get('otherData', {}).get('recorder_dropped')})")
        for track, n in sorted(per_track.items()):
            print(f"  {track:40s} {n:6d} events")
        print()

    links = link_report_from_trace(doc)
    if links:
        print(format_link_report(links))
        tiers = tier_report(links)
        total = sum(r["busy_s"] for r in tiers.values())
        if total > 0:
            print("\nmodeled link-busy seconds by tier:")
            for tier, r in sorted(tiers.items(),
                                  key=lambda kv: -kv[1]["busy_s"]):
                print(f"  {tier:12s} {r['busy_s']:10.4f}s "
                      f"({r['busy_s'] / total:6.1%})")
        # who occupied each contended link: fold the per-span flow
        # labels ("serve:<tenant>", "train:<job>") so a stalled request
        # can be attributed to the tenant/job whose traffic held the
        # trunk — the co-residency question fig11 asks
        labeled = {n: r for n, r in links.items() if r.get("by_label")}
        if labeled:
            print("\nlink occupancy by flow label (payload bytes):")
            for name, r in sorted(labeled.items(),
                                  key=lambda kv: -kv[1]["bytes"]):
                tot = sum(r["by_label"].values())
                shares = ", ".join(
                    f"{lbl}={b / 1e9:.3f}GB ({b / tot:5.1%})"
                    for lbl, b in sorted(r["by_label"].items(),
                                         key=lambda kv: -kv[1]))
                print(f"  {name:34s} {r['tier']:12s} {shares}")
    else:
        print("no link-occupancy spans in this trace "
              "(tracing ran without fabric transfers)")

    if problems:
        print(f"\nSCHEMA PROBLEMS ({len(problems)}):")
        for pr in problems[:20]:
            print(f"  {pr}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""repro.colo validation: the co-residency contracts the fig11 claims
rest on.

* **routed solo bit-exactness** (the satellite bugfix): a training step
  whose collectives are priced through a quiet ``fabric.Transport`` is
  bit-identical to the legacy closed-form ``simulate_step`` total, for
  every fig6 workload on both systems — pinned together with the
  float-rounding trap (``(x * bw) / bw != x``) the pricing must avoid;
* **driver degeneracy**: ``run_colo`` with no training actors is
  bit-identical to ``serve.run_multi_trace``, and a lone training actor
  under the driver is bit-identical to closed-form step accumulation;
* **determinism**: interleaved co-residency runs are bit-deterministic,
  and tracing never perturbs tokens or modeled clocks;
* **contention-aware placement** (``pool.allocator``): reduces exactly
  to hop-minimal placement on an empty estate, avoids live jobs' route
  links when hop-equivalent alternatives exist, and survives
  release/snapshot/restore; the pool scheduler prices a contention
  estate identically to scalepool (placement changes WHERE, not the
  fabric cost model);
* **flow labels**: per-label link attribution agrees between live
  transport gauges and the trace-derived report, and unlabeled flows
  keep label-free spans.
"""

import dataclasses

import jax
import pytest

from repro.colo import TrainActor, job_routes, plan_phases, run_colo
from repro.configs import SMOKE_ARCHS
from repro.core import costmodel as cmod
from repro.core import fabric as fb
from repro.core import simulator as sim
from repro.core.tiering import KVBudget
from repro.fabric import Topology, Transport
from repro.models.api import build_model
from repro.obs import (Tracer, link_report, link_report_from_trace,
                       to_chrome_trace)
from repro.pool import PoolJob, Scheduler, build_inventory
from repro.pool.allocator import Allocator, JobRequest
from repro.serve import (Engine, EngineConfig, ServeCostModel, burst_trace,
                         run_multi_trace)

VOCAB = SMOKE_ARCHS["qwen1.5-0.5b"].vocab


@pytest.fixture(scope="module")
def model():
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"].__class__(**{
        **SMOKE_ARCHS["qwen1.5-0.5b"].__dict__, "compute_dtype": "float32"})
    return build_model(cfg)


@pytest.fixture(scope="module")
def params(model):
    return model.init(jax.random.PRNGKey(0))


def _estate_topo() -> Topology:
    return build_inventory(
        n_pods=4, pod_size=4, hbm_per_accel_gb=64.0, n_memory_nodes=2,
        memory_node_gb=64.0, interconnect="scalepool").topology()


def _actor(name, bd, tx, topo, n_steps):
    return TrainActor(name, bd, tx, job_routes(topo, [0, 1, 2, 3], [0]),
                      n_steps=n_steps)


# ---------------------------------------------------------------------------
# satellite bugfix: routed solo pricing is bit-identical to the legacy path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["baseline", "scalepool"])
def test_routed_solo_step_bit_identical_to_simulate_step(kind):
    """A training job alone on the fabric must price EXACTLY as the
    closed-form simulator — not approximately: schedulers compare step
    times across systems and a rounding-dust divergence would smear
    every fig6-derived claim."""
    topo = _estate_topo()
    for w in sim.FIG6_WORKLOADS:
        c = dataclasses.replace(sim.Calibration(), ib_load=w.ib_load,
                                cxl_load=w.cxl_load)
        bd = sim.simulate_step(w.model, w.par,
                               sim.make_system(kind, w.par.n_gpus, c))
        actor = _actor("solo", bd, Transport(topo), topo, n_steps=3)
        for _ in range(3):
            assert actor.step() == bd.total     # bit-exact, per step
        assert actor.clock == 3 * bd.total
        assert actor.stretch_s == 0.0


def test_routed_pricing_sidesteps_volume_roundtrip_rounding():
    """The trap the bugfix removes: re-deriving the solo duration from
    the registered volume leaks ``(x * bw) / bw != x`` float dust.  The
    pre-fix implementation computed exactly that round trip; pin the
    combo where it visibly diverges AND that routed_phase_time is
    immune to it."""
    base, lat, bw = 0.3, 0.03, 3.0
    topo = Topology("t")
    topo.add_node("a", "pod")
    topo.add_node("m", "memory")
    topo.connect("a", "m", fb.CXL_CAPACITY, capacity=bw, latency=lat)
    route = topo.route("a", "m")
    vol = cmod.phase_volume(base, route)
    assert vol > 0
    rederived = route.latency() + vol / route.bottleneck_bw
    assert rederived != base            # the rounding the fix avoids...
    t = 0.0
    for _ in range(4):                  # ...and back-to-back phases stay exact
        got = cmod.routed_phase_time(Transport(topo), route, base, t)
        assert got == base
        t += got


def test_phase_volume_degenerate_cases():
    topo = Topology("t")
    topo.add_node("a", "pod")
    topo.add_node("m", "memory")
    topo.connect("a", "m", fb.CXL_CAPACITY, capacity=8.0, latency=0.5)
    route = topo.route("a", "m")
    # base inside the route latency: nothing to serialize, nothing priced
    assert cmod.phase_volume(0.25, route) == 0.0
    assert cmod.routed_phase_time(Transport(topo), route, 0.25, 0.0) == 0.25
    # plan_phases drops zero-base and zero-volume phases entirely
    bd = sim.StepBreakdown()
    assert plan_phases(bd, {"offload": route}) == ()


# ---------------------------------------------------------------------------
# driver degeneracy + determinism
# ---------------------------------------------------------------------------

def _serve_setup(model, params, tracer=None):
    """Two tenants spilling KV over one shared trunk (fig10-shaped)."""
    cm = ServeCostModel.from_fabric(2.0 * 1e9)
    topo = Topology("t")
    topo.add_node("sw", "switch")
    topo.add_node("mem", "memory")
    bw = 1e5     # slow trunk: spill flows live long enough to overlap
                 # the training offload phases (coupling is observable)
    for t in ("a", "b"):
        topo.add_node(t, "endpoint")
        topo.connect(t, "sw", fb.CXL3, capacity=8 * bw, latency=1e-4)
    topo.connect("sw", "mem", fb.CXL_CAPACITY, capacity=bw, latency=1e-4)
    tx = Transport(topo, tracer=tracer)
    engines = {t: Engine.local(model, EngineConfig(max_slots=3, max_seq=64,
                                                   page_size=8),
                               params=params,
                               budget=KVBudget(6, 1e9, 8),
                               cost_model=cm, transport=tx,
                               route=topo.route(t, "mem"), tenant=t,
                               tracer=tracer)
               for t in ("a", "b")}
    traces = {t: burst_trace(4, prompt_len=12, max_new_tokens=10,
                             vocab=VOCAB, seed=i)
              for i, t in enumerate(("a", "b"))}
    return engines, traces, tx, topo


def _fingerprint(engines, handle_lists):
    return ([[h.tokens for h in hs] for hs in handle_lists],
            [[h.latency for h in hs] for hs in handle_lists],
            [e.clock for e in engines.values()])


def test_run_colo_without_training_is_run_multi_trace(model, params):
    e1, tr1, _, _ = _serve_setup(model, params)
    ref = run_multi_trace([(e1[t], tr1[t]) for t in ("a", "b")])
    e2, tr2, _, _ = _serve_setup(model, params)
    res = run_colo([(e2[t], tr2[t]) for t in ("a", "b")])
    assert res.train == []
    assert _fingerprint(e1, ref) == _fingerprint(e2, res.serve_handles)


def test_train_only_under_driver_matches_closed_form(model, params):
    topo = _estate_topo()
    c = dataclasses.replace(sim.Calibration(), cluster_size=4)
    bd = sim.simulate_step(sim.MEGATRON,
                           sim.ParallelismConfig(tp=2, pp=1, dp=4,
                                                 global_batch_seqs=64),
                           sim.make_system("scalepool", 8, c))
    actor = _actor("t", bd, Transport(topo), topo, n_steps=5)
    res = run_colo([], [actor])
    assert res.train_stats()["t"]["steps"] == 5
    assert actor.step_times == [bd.total] * 5
    assert actor.clock == 5 * bd.total


def _colo_run(model, params, tracer=None):
    engines, traces, tx, topo = _serve_setup(model, params, tracer=tracer)
    c = dataclasses.replace(sim.Calibration(), cluster_size=4)
    bd = sim.simulate_step(sim.MEGATRON,
                           sim.ParallelismConfig(tp=2, pp=1, dp=4,
                                                 global_batch_seqs=64),
                           sim.make_system("scalepool", 8, c))
    # collectives ride the serving trunk so the interleaving contends
    routes = {"offload": topo.route("a", "mem")}
    actor = TrainActor("job", bd, tx, routes, n_steps=4)
    res = run_colo([(engines[t], traces[t]) for t in ("a", "b")], [actor])
    return engines, actor, res


def test_interleaved_colo_run_bit_deterministic(model, params):
    e1, a1, r1 = _colo_run(model, params)
    e2, a2, r2 = _colo_run(model, params)
    assert _fingerprint(e1, r1.serve_handles) == \
        _fingerprint(e2, r2.serve_handles)
    assert a1.step_times == a2.step_times
    assert a1.clock == a2.clock
    # co-residency actually coupled the workloads (stretch observed)
    assert a1.stretch_s > 0.0


def test_traced_colo_run_identical_to_untraced(model, params):
    e1, a1, r1 = _colo_run(model, params)
    e2, a2, r2 = _colo_run(model, params, tracer=Tracer())
    assert _fingerprint(e1, r1.serve_handles) == \
        _fingerprint(e2, r2.serve_handles)
    assert a1.step_times == a2.step_times


# ---------------------------------------------------------------------------
# contention-aware placement
# ---------------------------------------------------------------------------

def _fig11_inventory():
    """6 pods over 3 leaves (radix-4 switch), 2 tier-2 nodes: the
    smallest estate where hop-equivalent placements differ in overlap."""
    inv = build_inventory(n_pods=6, pod_size=5, hbm_per_accel_gb=64.0,
                          n_memory_nodes=2, memory_node_gb=64.0,
                          interconnect="scalepool")
    inter = inv.inter_fabric
    inter = dataclasses.replace(
        inter, topology=dataclasses.replace(
            inter.topology, switch=dataclasses.replace(
                inter.topology.switch, radix=4)))
    return dataclasses.replace(inv, inter_fabric=inter)


def test_contention_reduces_to_min_hops_on_empty_estate():
    for req in (JobRequest("j", 3), JobRequest("k", 8, tier2_bytes=8e9)):
        pods = {}
        for policy in ("scalepool", "contention"):
            a = Allocator(_fig11_inventory(), policy)
            alloc = a.allocate(req)
            assert alloc is not None
            pods[policy] = alloc.pod_ids
        assert pods["scalepool"] == pods["contention"]


def test_contention_placement_avoids_live_routes():
    """With a serving job live on pod 0 / mem 0, a hop-only allocator
    lands the training gang next to it on leaf 0; the contention policy
    takes the hop-equivalent leaf that shares only the trunk."""
    got = {}
    for policy in ("scalepool", "contention"):
        a = Allocator(_fig11_inventory(), policy)
        svc = a.allocate(JobRequest("svc", 1, tier2_bytes=8e9,
                                    kv_bytes=1e9))
        trn = a.allocate(JobRequest("train", 8, tier2_bytes=16e9))
        assert svc is not None and trn is not None
        got[policy] = (svc.pod_ids, trn.pod_ids)
        a.check_conservation()
    assert got["scalepool"] == ((0,), (0, 1))
    assert got["contention"][0] == (0,)
    assert got["contention"][1] == (2, 3)      # own leaf, trunk-only overlap


def test_route_links_survive_release_and_snapshot_restore():
    a = Allocator(_fig11_inventory(), "contention")
    a.allocate(JobRequest("svc", 1, tier2_bytes=8e9, kv_bytes=1e9))
    assert "svc" in a._job_route_links
    snap = a.snapshot()
    a.allocate(JobRequest("train", 8, tier2_bytes=16e9))
    assert set(a._job_route_links) == {"svc", "train"}
    a.restore(snap)
    assert set(a._job_route_links) == {"svc"}
    links_before = a._job_route_links["svc"]
    a.allocate(JobRequest("train", 8, tier2_bytes=16e9))
    assert a._job_route_links["svc"] == links_before
    a.release("train")
    a.release("svc")
    assert a._job_route_links == {}
    a.check_conservation()


def test_scheduler_prices_contention_estate_as_scalepool():
    """Placement policy changes WHERE a gang lands, never the fabric
    cost model: one job's schedule is identical on both policies."""
    par = sim.ParallelismConfig(tp=2, pp=1, dp=2, global_batch_seqs=64)

    def finish(policy):
        inv = build_inventory(n_pods=4, pod_size=8, hbm_per_accel_gb=192.0,
                              n_memory_nodes=2, memory_node_gb=1024.0,
                              interconnect=policy)
        s = Scheduler(inv, policy)
        s.submit(PoolJob("j", sim.MEGATRON, par, n_steps=20,
                         tier2_bytes=64e9))
        return s.run().records["j"].finish_t

    assert finish("contention") == finish("scalepool")


# ---------------------------------------------------------------------------
# flow labels
# ---------------------------------------------------------------------------

def test_link_label_attribution_live_vs_trace():
    topo = Topology("t")
    for n in ("a", "b"):
        topo.add_node(n, "pod")
    topo.add_node("m", "memory")
    topo.connect("a", "m", fb.CXL_CAPACITY, capacity=10.0, latency=0.0)
    topo.connect("b", "m", fb.CXL_CAPACITY, capacity=10.0, latency=0.0)
    tracer = Tracer()
    tx = Transport(topo, tracer=tracer)
    tx.begin_transfer(topo.route("a", "m"), 40.0, 0.0, label="serve:a")
    tx.begin_transfer(topo.route("b", "m"), 40.0, 1.0, label="train:j")
    tx.begin_transfer(topo.route("a", "m"), 40.0, 2.0)          # unlabeled
    tx.quiesce()
    live = link_report(tx)
    from_trace = link_report_from_trace(to_chrome_trace(tracer))
    for name in live:
        if name in from_trace:      # live lists every link, trace only
            assert live[name]["by_label"] == \
                pytest.approx(from_trace[name]["by_label"])
        else:                       # the traversed ones
            assert live[name]["by_label"] == {}
    assert live["a->m"]["by_label"] == pytest.approx({"serve:a": 40.0})
    assert live["b->m"]["by_label"] == pytest.approx({"train:j": 40.0})
    # labeled bytes never exceed total link bytes (unlabeled keep legacy
    # accounting and label-free spans)
    for name, row in live.items():
        assert sum(row["by_label"].values()) <= row["bytes"] + 1e-6
    unlabeled = [e for e in tracer.events()
                 if e.ph == "X" and "label" not in e.args]
    assert unlabeled, "unlabeled flow must emit label-free spans"


def test_engine_emits_kv_counters_when_traced(model, params):
    tracer = Tracer()
    eng = Engine.local(model, EngineConfig(max_slots=3, max_seq=64,
                                           page_size=8),
                       params=params, budget=KVBudget(6, 1e9, 8),
                       tenant="a", tracer=tracer)
    from repro.serve import run_trace
    run_trace(eng, burst_trace(3, prompt_len=12, max_new_tokens=8,
                               vocab=VOCAB, seed=0))
    names = {e.name for e in tracer.events() if e.ph == "C"}
    assert {"free_pages", "paused", "allowance"} <= names

"""repro.pool validation: allocator invariants (no double allocation,
capacity conservation, hop minimality), deterministic scheduler traces,
and the lease → JAX mesh + TieringPolicy runtime binding."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import simulator as sim
from repro.core.tiering import TieringPolicy
from repro.pool import (JobRequest, PoolJob, ResourcePool, Scheduler,
                        build_inventory, offload_bytes, smoke_pool)
from repro.pool.allocator import AllocationError, Allocator

GB = 1e9


def small_inventory(policy="scalepool", n_pods=4, pod_size=8):
    return build_inventory(
        n_pods=n_pods, pod_size=pod_size, hbm_per_accel_gb=192.0,
        n_memory_nodes=(2 if policy == "scalepool" else 0),
        memory_node_gb=1024.0, interconnect=policy)


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------

def test_no_double_allocation():
    a = Allocator(small_inventory())
    allocs = [a.allocate(JobRequest(f"j{i}", 6)) for i in range(5)]
    assert all(x is not None for x in allocs)
    seen = set()
    for alloc in allocs:
        for pod, ids in alloc.accels.items():
            for i in ids:
                assert (pod, i) not in seen
                seen.add((pod, i))
    a.check_conservation()
    assert a.free_accels() == 32 - 30


def test_capacity_conservation_through_churn():
    a = Allocator(small_inventory())
    total = a.inv.total_accels
    t2_total = a.inv.total_tier2
    a.allocate(JobRequest("a", 8, 512 * GB))
    a.allocate(JobRequest("b", 12, 1024 * GB))
    a.check_conservation()
    assert a.free_accels() + 20 == total
    assert a.free_tier2() + 1536 * GB == pytest.approx(t2_total)
    a.release("a")
    a.allocate(JobRequest("c", 3, 256 * GB))
    a.check_conservation()
    a.release("b")
    a.release("c")
    a.check_conservation()
    assert a.free_accels() == total
    assert a.free_tier2() == pytest.approx(t2_total)


def test_release_unknown_job_raises():
    a = Allocator(small_inventory())
    with pytest.raises(AllocationError):
        a.release("ghost")
    a.allocate(JobRequest("x", 4))
    with pytest.raises(AllocationError):
        a.allocate(JobRequest("x", 4))


def test_overcommit_returns_none_and_leaves_state():
    a = Allocator(small_inventory())
    assert a.allocate(JobRequest("big", 33)) is None          # > 32 accels
    assert a.allocate(JobRequest("mem", 4, 3000 * GB)) is None  # > 2TB tier-2
    a.check_conservation()
    assert a.free_accels() == 32
    assert len(a.live) == 0


def test_hop_minimality_on_small_topology():
    """A job that fits one pod must land in one pod (0 inter-pod hops);
    a 1.5-pod job must span exactly ceil(n/pod) pods."""
    a = Allocator(small_inventory())
    one_pod = a.allocate(JobRequest("fits", 8))
    assert one_pod.n_pods == 1
    assert a.inv.span_hops(one_pod.pod_ids) == 0
    spanning = a.allocate(JobRequest("spans", 12))
    assert spanning.n_pods == 2        # minimal pod count, not 3
    # both pods on one leaf switch of the CXL fabric -> 1 hop
    assert a.inv.span_hops(spanning.pod_ids) == 1


def test_best_fit_prefers_tight_pod():
    """After a partial allocation, a job that exactly fits the remainder
    of a pod should take it rather than fragment a fresh pod."""
    a = Allocator(small_inventory())
    a.allocate(JobRequest("partial", 5))      # pod 0 now has 3 free
    tight = a.allocate(JobRequest("tight", 3))
    assert tight.pod_ids == (0,)
    a.check_conservation()


def test_baseline_whole_pod_granularity_and_hbm_scavenging():
    a = Allocator(small_inventory("baseline"))
    alloc = a.allocate(JobRequest("j", 5))
    assert alloc.whole_pods and alloc.n_granted == 8 and alloc.n_stranded == 3
    # 600GB of capacity demand: 3 idle accels (576GB) are not enough ->
    # a second pod is consumed purely for its HBM.
    mem = a.allocate(JobRequest("m", 5, 600 * GB))
    assert mem.n_granted == 16 and mem.n_stranded == 11
    # scalepool satisfies the same request with 5 accels + a reservation
    s = Allocator(small_inventory("scalepool"))
    sp = s.allocate(JobRequest("m", 5, 600 * GB))
    assert sp.n_granted == 5 and sp.tier2_bytes == 600 * GB


def test_fragmentation_metric():
    a = Allocator(small_inventory())
    assert a.metrics().fragmentation == 0.0
    for i, n in enumerate([6, 6, 6, 6]):     # 2 free in each pod
        a.allocate(JobRequest(f"j{i}", n))
    m = a.metrics()
    assert m.fragmentation == pytest.approx(1.0 - 2 / 8)
    assert m.utilization == pytest.approx(24 / 32)


# ---------------------------------------------------------------------------
# scheduler: determinism + end-to-end trace
# ---------------------------------------------------------------------------

def _jobs():
    par = lambda dp: sim.ParallelismConfig(tp=2, pp=1, dp=dp,
                                           global_batch_seqs=64)
    calib = dataclasses.replace(sim.Calibration(), cluster_size=8)
    t2 = offload_bytes(sim.MEGATRON, calib)
    return [
        PoolJob("a", sim.MEGATRON, par(4), n_steps=50, tier2_bytes=t2,
                submit_t=0.0),
        PoolJob("b", sim.MEGATRON, par(2), n_steps=50, submit_t=0.0),
        PoolJob("c", sim.MEGATRON, par(8), n_steps=80, tier2_bytes=t2,
                submit_t=1.0, elastic=True, min_dp=2),
        PoolJob("hi", sim.MEGATRON, par(4), n_steps=30, submit_t=2.0,
                priority=1),
    ]


def _run(policy):
    sched = Scheduler(small_inventory(policy), policy)
    for j in _jobs():
        sched.submit(j)
    return sched.run()


@pytest.mark.parametrize("policy", ["baseline", "scalepool"])
def test_scheduler_trace_deterministic(policy):
    r1, r2 = _run(policy), _run(policy)
    assert r1.trace == r2.trace
    assert r1.summary() == r2.summary()


def test_scheduler_end_to_end_semantics():
    res = _run("scalepool")
    recs = res.records
    # every job finished, and the schedule respects submission times
    for r in recs.values():
        assert r.finish_t is not None
        assert r.start_t >= r.submit_t
    # the high-priority job preempted someone and started on arrival
    assert recs["hi"].queue_delay == pytest.approx(0.0)
    assert any("preempt" in line for line in res.trace)
    # the elastic job was admitted shrunk, then grew back to full width
    assert any("grow c" in line for line in res.trace)
    assert recs["c"].dp_granted == 8
    assert recs["c"].resizes >= 1
    # accounting sanity
    assert 0.0 < res.utilization <= 1.0
    assert res.util_area <= res.granted_area + 1e-9
    s = res.summary()
    assert s["n_finished"] == 4


def test_scheduler_partial_horizon_accounts_tail_window():
    """run(until=...) with a job straddling the horizon must accrue
    util/granted areas and makespan up to ``until`` — pre-fix the
    accounting stopped at the last *processed* event (admission at t=0)
    and partial-horizon utilization was wildly overstated."""
    par = sim.ParallelismConfig(tp=2, pp=1, dp=2, global_batch_seqs=64)

    def fresh():
        s = Scheduler(small_inventory("scalepool"))
        s.submit(PoolJob("j", sim.MEGATRON, par, n_steps=50))
        return s

    full = fresh().run()
    T = full.records["j"].finish_t
    assert T > 0

    sched = fresh()
    half = sched.run(until=T / 2)
    assert half.records["j"].finish_t is None          # straddles ``until``
    assert half.makespan == pytest.approx(T / 2)
    assert half.util_area == pytest.approx(4 * T / 2)  # 4 accels, busy
    assert half.utilization == pytest.approx(full.utilization)
    # resuming past the horizon completes the job with no double counting
    rest = sched.run()
    assert rest.records["j"].finish_t == pytest.approx(T)
    assert rest.util_area == pytest.approx(full.util_area)
    # a drained schedule keeps its natural makespan even for finite until
    done = fresh().run(until=10 * T)
    assert done.makespan == pytest.approx(T)


def test_scalepool_beats_baseline_on_burst():
    """The tentpole claim at test scale: composable pooling admits a
    memory-heavy burst with less stranding and shorter completion."""

    def burst(policy):
        calib = dataclasses.replace(sim.Calibration(), cluster_size=8)
        sched = Scheduler(small_inventory(policy), policy, calib=calib)
        par = sim.ParallelismConfig(tp=2, pp=1, dp=3, global_batch_seqs=66)
        # 450GB per job: more than one pod's idle HBM (2 accels x 192GB)
        # under baseline -> 2 pods per job; comfortably within the 2TB
        # tier-2 pool for all four jobs under scalepool.
        t2 = 450 * GB
        for i in range(4):
            sched.submit(PoolJob(f"j{i}", sim.MEGATRON, par, n_steps=40,
                                 tier2_bytes=t2, submit_t=0.0))
        return sched.run()

    base, sp = burst("baseline"), burst("scalepool")
    assert sp.utilization > base.utilization
    assert sp.mean_jct < base.mean_jct
    assert sp.stranded_frac == pytest.approx(0.0)
    assert base.stranded_frac > 0.0


# ---------------------------------------------------------------------------
# lease → runtime binding
# ---------------------------------------------------------------------------

def test_lease_tiering_policy_follows_reservation():
    pool = smoke_pool()
    with_t2 = pool.lease("t2", 4, tier2_gb=128)
    without = pool.lease("no-t2", 4)
    assert with_t2.tiering_policy().offload_optimizer
    assert not without.tiering_policy().offload_optimizer


def test_lease_kv_grant_becomes_budget():
    """kv_gb earmarks a slice of the tier-2 reservation; the lease turns
    it into a KVBudget with the engine-side page quota left open."""
    pool = smoke_pool()
    lease = pool.lease("svc", 4, tier2_gb=64, kv_gb=16)
    assert lease.kv_bytes == pytest.approx(16 * GB)
    budget = lease.kv_budget(page_size=32)
    assert budget.tier2_bytes == pytest.approx(16 * GB)
    assert budget.tier1_pages is None and budget.page_size == 32
    policy = lease.tiering_policy()
    assert policy.kv_budget is not None and policy.kv_spill
    assert pool.metrics().tier2_kv_reserved == pytest.approx(16 * GB)
    # no grant -> no budget
    assert pool.lease("plain", 4, tier2_gb=8).kv_budget() is None
    with pytest.raises(ValueError, match="kv_bytes"):
        pool.lease("bad", 2, tier2_gb=4, kv_gb=8)   # kv > reservation


def test_tier2_bandwidth_is_schedulable():
    """Bandwidth is admission-controlled per memory node and conserved
    through churn (ROADMAP: concurrent offload-heavy leases contend)."""
    inv = build_inventory(n_pods=4, pod_size=8, n_memory_nodes=2,
                          memory_node_gb=1024.0, memory_node_gbps=50.0,
                          interconnect="scalepool")
    a = Allocator(inv)
    assert a.free_tier2_bw() == pytest.approx(100 * GB)
    big = a.allocate(JobRequest("bw-hog", 4, 64 * GB, tier2_bw=80 * GB))
    assert big is not None and big.tier2_bw_total == pytest.approx(80 * GB)
    # the fabric has only 20GB/s left: an offload-heavy peer is refused
    assert a.allocate(JobRequest("late", 4, 64 * GB, tier2_bw=40 * GB)) is None
    ok = a.allocate(JobRequest("light", 4, 64 * GB, tier2_bw=10 * GB))
    assert ok is not None
    m = a.metrics()
    assert m.tier2_bw_reserved == pytest.approx(90 * GB)
    assert 0.89 < m.tier2_bw_frac < 0.91
    a.check_conservation()
    a.release("bw-hog")
    a.release("light")
    assert a.free_tier2_bw() == pytest.approx(100 * GB)
    a.check_conservation()


def test_tier2_trunk_link_admission():
    """Bandwidth admission runs against the routed estate graph: an
    oversubscribed spine->t2sw trunk refuses an aggregate demand that
    per-node scalars alone would accept."""
    inv = build_inventory(n_pods=4, pod_size=8, n_memory_nodes=2,
                          memory_node_gb=1024.0, memory_node_gbps=40.0,
                          tier2_trunk_gbps=50.0, interconnect="scalepool")
    a = Allocator(inv)
    assert a.free_link_bw("spine->t2sw") == pytest.approx(50 * GB)
    # 60GB/s fits the nodes (40 + 20) but not the 50GB/s shared trunk
    assert a.allocate(JobRequest("wide", 4, 64 * GB, tier2_bw=60 * GB)) is None
    a.check_conservation()
    assert a.free_tier2_bw() == pytest.approx(80 * GB)   # nothing leaked
    ok = a.allocate(JobRequest("fits", 4, 64 * GB, tier2_bw=30 * GB))
    assert ok is not None
    assert a.free_link_bw("spine->t2sw") == pytest.approx(20 * GB)
    # a second job under the node caps still bounces off the trunk
    assert a.allocate(JobRequest("late", 4, 64 * GB, tier2_bw=30 * GB)) is None
    a.check_conservation()
    a.release("fits")
    assert a.free_link_bw("spine->t2sw") == pytest.approx(50 * GB)
    a.check_conservation()


def test_gang_members_submitted_at_different_times_admit_atomically():
    """ROADMAP PR 4 caveat (fails pre-fix): gang members submitted at
    different timestamps admitted independently — the first member
    started alone at t=0 while its peer was still in flight.  With the
    pending-gang buffer, a declared gang (gang_size) is held until
    complete and admitted all-or-nothing."""
    par = sim.ParallelismConfig(tp=2, pp=1, dp=3, global_batch_seqs=66)
    sched = Scheduler(small_inventory("scalepool"), queueing="drf")
    for i, t in enumerate([0.0, 1.0]):          # staggered submission
        sched.submit(PoolJob(f"g{i}", sim.MEGATRON, par, n_steps=10,
                             submit_t=t, user="u", gang="pair",
                             gang_size=2))
    res = sched.run()
    recs = res.records
    assert all(r.finish_t is not None for r in recs.values())
    # neither member may start before the gang is complete at t=1.0 —
    # pre-fix g0 admitted alone at t=0
    starts = [recs["g0"].start_t, recs["g1"].start_t]
    assert min(starts) == pytest.approx(1.0)
    assert starts[0] == pytest.approx(starts[1])
    assert any("hold g0" in line for line in res.trace)
    assert any("admit gang 'pair'" in line for line in res.trace)


def test_gang_without_explicit_user_still_assembles():
    """gang_key must use the RAW user: the drf fallback (user or name)
    would scatter a no-user gang's members across per-job pending
    buffers and hold each forever (run() returning with the jobs never
    started, silently)."""
    par = sim.ParallelismConfig(tp=2, pp=1, dp=3, global_batch_seqs=66)
    sched = Scheduler(small_inventory("scalepool"), queueing="drf")
    for i, t in enumerate([0.0, 1.0]):
        sched.submit(PoolJob(f"g{i}", sim.MEGATRON, par, n_steps=10,
                             submit_t=t, gang="pair", gang_size=2))
    res = sched.run()
    assert all(r.finish_t is not None for r in res.records.values())
    assert res.records["g0"].start_t == pytest.approx(1.0)
    assert not sched._pending_gangs
    # an incomplete gang is surfaced in the trace, not dropped silently
    sched2 = Scheduler(small_inventory("scalepool"), queueing="drf")
    sched2.submit(PoolJob("lone", sim.MEGATRON, par, n_steps=10,
                          gang="pair", gang_size=2))
    res2 = sched2.run()
    assert res2.records["lone"].start_t is None
    assert any("WARNING gang 'pair' incomplete" in l for l in res2.trace)
    # mixed gang_size declarations are an error, not a silent split/hold
    sched3 = Scheduler(small_inventory("scalepool"), queueing="drf")
    sched3.submit(PoolJob("m1", sim.MEGATRON, par, n_steps=10,
                          gang="pair", gang_size=2))
    sched3.submit(PoolJob("m2", sim.MEGATRON, par, n_steps=10,
                          gang="pair", gang_size=3))
    with pytest.raises(ValueError, match="gang_size"):
        sched3.run()


def test_priority_preemption_never_splits_a_declared_gang():
    """FIFO priority preemption must not yank one member of a declared
    gang while its peers keep running — gang members are not
    preemptable (all-or-nothing placement holds for their lifetime)."""
    par = lambda dp: sim.ParallelismConfig(tp=2, pp=1, dp=dp,
                                           global_batch_seqs=64)
    sched = Scheduler(small_inventory("scalepool"))
    for i in range(2):      # gang fills 24 of 32 accels
        sched.submit(PoolJob(f"g{i}", sim.MEGATRON, par(6), n_steps=30,
                             submit_t=0.0, user="u", gang="pair",
                             gang_size=2))
    # head-of-line high-priority job that cannot fit without preemption
    sched.submit(PoolJob("hi", sim.MEGATRON, par(8), n_steps=5,
                         submit_t=1.0, priority=1))
    res = sched.run()
    recs = res.records
    assert recs["g0"].preemptions == 0 and recs["g1"].preemptions == 0
    assert all(r.finish_t is not None for n, r in recs.items() if n != "hi")
    # the priority job waits for the gang instead of splitting it
    assert recs["hi"].start_t >= min(recs["g0"].finish_t,
                                     recs["g1"].finish_t)


def test_gang_buffer_applies_to_fifo_queueing_too():
    """A declared gang is one FIFO queue unit: held until complete,
    then placed atomically (or skipped whole)."""
    par = sim.ParallelismConfig(tp=2, pp=1, dp=3, global_batch_seqs=66)
    sched = Scheduler(small_inventory("scalepool"))     # fifo
    sched.submit(PoolJob("g0", sim.MEGATRON, par, n_steps=10, submit_t=0.0,
                         gang="pair", gang_size=2, user="u"))
    sched.submit(PoolJob("g1", sim.MEGATRON, par, n_steps=10, submit_t=2.0,
                         gang="pair", gang_size=2, user="u"))
    res = sched.run()
    recs = res.records
    assert all(r.finish_t is not None for r in recs.values())
    assert recs["g0"].start_t == pytest.approx(2.0)
    assert recs["g0"].start_t == pytest.approx(recs["g1"].start_t)


def test_scheduler_threads_tier2_bandwidth():
    """Two offload-heavy jobs that together oversubscribe the capacity
    fabric must run serially, not concurrently."""
    inv = build_inventory(n_pods=4, pod_size=8, n_memory_nodes=2,
                          memory_node_gb=4096.0, memory_node_gbps=40.0,
                          interconnect="scalepool")
    sched = Scheduler(inv)
    par = sim.ParallelismConfig(tp=2, pp=1, dp=2, global_batch_seqs=64)
    for i in range(2):
        sched.submit(PoolJob(f"offl-{i}", sim.MEGATRON, par, n_steps=5,
                             tier2_bytes=256 * GB, tier2_bw=60 * GB))
    res = sched.run()
    recs = list(res.records.values())
    assert all(r.finish_t is not None for r in recs)
    # second job cannot start until the first releases its bandwidth
    starts = sorted(r.start_t for r in recs)
    finishes = sorted(r.finish_t for r in recs)
    assert starts[1] >= finishes[0]


def test_freelist_heap_semantics():
    from repro.pool import FreeList
    fl = FreeList(range(8))
    assert fl.take(3) == (0, 1, 2)
    fl.put((1,))
    assert fl.take(2) == (1, 3)
    assert len(fl) == 4 and fl.ids() == [4, 5, 6, 7]
    with pytest.raises(AssertionError):
        fl.put((4,))                     # double free
    with pytest.raises(AssertionError):
        fl.take(99)                      # over-take
    clone = fl.clone()
    clone.take(4)
    assert fl.ids() == [4, 5, 6, 7]      # clone is independent


def test_lease_mesh_shape_mirrors_topology():
    pool = smoke_pool()
    wide = pool.lease("wide", 12, model_parallel=2)   # spans 2 pods
    assert wide.spans_pods
    shape, axes = wide.mesh_shape(8)
    assert axes == ("pod", "data", "model") and shape == (2, 2, 2)
    shape, axes = wide.mesh_shape(1)                  # 1 CPU device
    assert axes == ("data", "model") and shape == (1, 1)


def test_lease_resize_produces_consistent_plan():
    pool = smoke_pool()
    lease = pool.lease("job", 8, model_parallel=2)
    grown, plan = pool.resize("job", 16)
    assert grown.n_accels == 16
    assert plan["pods"] * plan["data"] * plan["model"] == 16
    assert plan["model"] == 2
    shrunk, plan2 = pool.resize("job", 4)
    assert shrunk.n_accels == 4
    assert plan2["pods"] * plan2["data"] * plan2["model"] == 4
    pool.alloc.check_conservation()


def test_lease_drives_real_train_step(rng):
    """Acceptance: a pool lease materializes as a concrete jax mesh +
    TieringPolicy and drives an actual sharded train step on CPU."""
    from repro.configs import SMOKE_ARCHS
    from repro.models.api import build_model
    from repro.models.config import ShapeConfig
    from repro.optim.adamw import AdamW
    from repro.runtime import train as train_rt
    from repro.sharding.partition import use_rules
    from repro.sharding.profiles import make_rules
    from repro.core.compat import mesh_context
    from repro.core.tiering import offload_state_shardings
    from conftest import make_batch

    pool = smoke_pool()
    lease = pool.lease("train", 8, tier2_gb=64, model_parallel=2)
    mesh, policy = lease.materialize()
    assert isinstance(policy, TieringPolicy) and policy.offload_optimizer

    cfg = SMOKE_ARCHS["qwen1.5-0.5b"]
    model = build_model(cfg)
    opt = AdamW(lr=1e-3)
    shape = ShapeConfig("pool_smoke", "train", 16, 2)
    rules = make_rules(cfg, shape, mesh, fsdp=False)
    state = train_rt.init_state(model, opt, rng)
    step, state_sh = train_rt.make_train_step(model, opt, shape, mesh=mesh,
                                              rules=rules)
    state_sh = offload_state_shardings(state_sh, policy)
    batch = make_batch(rng, cfg, B=2, S=16)
    with use_rules(rules, mesh), mesh_context(mesh):
        new_state, metrics = jax.jit(step)(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert metrics["loss"].shape == ()


def test_lease_serve_session(rng):
    """The serving path: a lease with kv_spill binds to a decode session."""
    from repro.configs import SMOKE_ARCHS
    from repro.models.api import build_model
    from repro.models.config import ShapeConfig
    from repro.runtime import serve as serve_rt

    pool = smoke_pool()
    lease = pool.lease("serve", 4, tier2_gb=64, kv_gb=8)
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"]
    model = build_model(cfg)
    shape = ShapeConfig("serve_smoke", "decode", 32, 2)
    sess = serve_rt.make_lease_session(model, shape, lease)
    assert sess.kv_spill
    params = model.init(rng)
    B, prompt = 2, 8
    tokens = jax.random.randint(rng, (B, prompt), 1, cfg.vocab)
    cache = model.init_cache(B, 32, dtype=jnp.float32)
    logits, cache = sess.prefill_step(params, {"tokens": tokens}, cache)
    carry = {"tokens": jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32),
             "cache": cache, "index": jnp.int32(prompt)}
    logits2, carry = sess.decode_step(params, carry)
    assert logits2.shape[0] == B
    assert jnp.isfinite(logits2).all()


def test_failed_resize_leaves_pool_intact():
    """An impossible re-sharding plan must not half-commit the resize."""
    pool = smoke_pool()
    pool.lease("j", 8, model_parallel=4)
    with pytest.raises(ValueError, match="model parallelism"):
        pool.resize("j", 6)       # 6 accels can't host mp=4
    assert pool.leases["j"].n_accels == 8
    assert pool.alloc.live["j"].n_requested == 8
    pool.alloc.check_conservation()


def test_pool_exhaustion_raises_informatively():
    pool = smoke_pool()
    pool.lease("hog", 30)
    with pytest.raises(RuntimeError, match="cannot satisfy"):
        pool.lease("late", 8)


# ---------------------------------------------------------------------------
# demand-weighted KV shares + gang placement (the repro.disagg estate)
# ---------------------------------------------------------------------------

def test_kv_shares_water_filling_sharing_incentive():
    pool = ResourcePool(small_inventory())
    lease = pool.lease("shared", 4, tier2_gb=64, kv_gb=3.0,
                       tenants=("a", "b", "c"))
    kv = lease.kv_bytes
    even = kv / 3
    # no demands: the legacy static split, bit-compatible
    assert lease.kv_shares() == pytest.approx(
        {"a": even, "b": even, "c": even})
    # a light demander saturates and donates; the surplus flows to the
    # heavy demander, and the leftover returns as an equal bonus
    shares = lease.kv_shares({"a": 0.2 * even, "b": 2.5 * even})
    assert sum(shares.values()) == pytest.approx(kv)
    assert shares["a"] >= 0.2 * even
    assert shares["b"] > even
    assert shares["c"] > 0.0           # quiet tenant keeps spill headroom
    # sharing incentive (pinned): a tenant demanding at least the even
    # split never receives less than the even split
    for demands in ({"a": even}, {"a": 5 * even},
                    {"a": even, "b": 9 * even, "c": 9 * even}):
        assert lease.kv_shares(demands)["a"] >= even * (1 - 1e-12)
    with pytest.raises(KeyError, match="intruder"):
        lease.kv_shares({"intruder": 1.0})


def test_gang_lease_roles_and_handoff_route():
    pool = ResourcePool(small_inventory(), policy="contention")
    gang = pool.lease_gang("serve", {
        "prefill": dict(n_accels=8),
        "decode": dict(n_accels=8, tier2_gb=8, kv_gb=1.0,
                       tenants=("d0",)),
    })
    assert set(gang) == {"prefill", "decode"}
    assert gang["prefill"].role == "prefill"
    assert gang["decode"].role == "decode"
    assert gang["prefill"].job == "serve/prefill"
    # pod_size=8: each tier fills one pod, so the tiers cannot share a
    # gateway and the KV handoff rides a real estate route
    route = pool.handoff_route(gang["prefill"], gang["decode"])
    assert route is not None and len(route.links) >= 1
    pool.release_gang("serve")
    assert pool.alloc.free_accels() == 32
    pool.alloc.check_conservation()
    with pytest.raises(AllocationError, match="no gang"):
        pool.release_gang("serve")


def test_gang_all_or_nothing_rollback():
    a = Allocator(small_inventory())
    a.allocate(JobRequest("hog", 28))
    free_before = a.free_accels()
    out = a.allocate_gang([JobRequest("g/p", 2, role="prefill"),
                           JobRequest("g/d", 6, role="decode")])
    assert out is None                 # the decode member cannot fit
    assert a.free_accels() == free_before
    assert "g/p" not in a.live and "g/d" not in a.live
    a.check_conservation()


def test_gang_colocated_tiers_degenerate_handoff():
    """Both tiers fitting one pod share a gateway: the handoff route is
    None — the signal DisaggCluster uses to run degenerate."""
    pool = ResourcePool(small_inventory())
    gang = pool.lease_gang("tiny", {"prefill": dict(n_accels=2),
                                    "decode": dict(n_accels=2)})
    assert pool.handoff_route(gang["prefill"], gang["decode"]) is None
    pool.release_gang("tiny")

import os

# keep the default device count at 1 for smoke tests/benches; dry-run
# sets XLA_FLAGS itself in a subprocess (see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_batch(rng, cfg, B=2, S=16):
    """Build a smoke batch for any family."""
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab),
    }
    if cfg.frontend == "vision":
        batch["embeds"] = jax.random.normal(rng, (B, S, cfg.d_model), jnp.bfloat16)
        del batch["tokens"]
    if cfg.family == "encdec":
        batch["frame_embeds"] = jax.random.normal(
            rng, (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return batch

"""Multi-tenant fair-share serving over ONE physical page pool: the
``PoolArbiter`` fairness invariants (work conservation, sharing
incentive, single-tenant transparency, revocation charged to the
over-share tenant), the multi-tenant lease surface (``tenants=`` /
``kv_share``), and the scheduler's gang-aware DRF queueing mode."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.core import simulator as sim
from repro.core.tiering import KVBudget
from repro.models.api import build_model
from repro.pool import PoolJob, Scheduler, build_inventory, smoke_pool
from repro.serve import (Engine, EngineConfig, PoolArbiter, Request,
                         RequestStatus, burst_trace, latency_summary,
                         run_multi_trace, run_trace, synthetic_trace)

GB = 1e9
VOCAB = SMOKE_ARCHS["qwen1.5-0.5b"].vocab


@pytest.fixture(scope="module")
def model():
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"].__class__(**{
        **SMOKE_ARCHS["qwen1.5-0.5b"].__dict__, "compute_dtype": "float32"})
    return build_model(cfg)


@pytest.fixture(scope="module")
def params(model):
    return model.init(jax.random.PRNGKey(0))


def _cfg(**kw):
    base = dict(max_slots=3, max_seq=64, page_size=8)
    base.update(kw)
    return EngineConfig(**base)


POOL_PAGES = 6          # tight: forces paging under a heavy trace


def _heavy(n=5, seed=0):
    return burst_trace(n, prompt_len=12, max_new_tokens=10, vocab=VOCAB,
                       seed=seed)


# ---------------------------------------------------------------------------
# single-tenant transparency + work conservation
# ---------------------------------------------------------------------------

def test_lone_tenant_bit_identical_to_private_pool(model, params):
    """A single tenant under the arbiter is indistinguishable from
    today's private-PagedKV engine: same tokens, same swap/recompute
    counters, same event clocks — the arbiter is pure overheadless
    routing until a second tenant shows up."""
    trace = _heavy()
    priv = Engine.local(model, _cfg(), params=params,
                        budget=KVBudget(tier1_pages=POOL_PAGES,
                                        tier2_bytes=1e9, page_size=8))
    h_priv = run_trace(priv, trace)

    arb = PoolArbiter(POOL_PAGES, page_size=8)
    solo = Engine.local(model, _cfg(), params=params,
                        budget=KVBudget(tier2_bytes=1e9, page_size=8),
                        arbiter=arb, tenant="solo")
    h_solo = run_trace(solo, trace)

    assert priv.stats()["preempt_swaps"] > 0, "pressure not exercised"
    assert [h.tokens for h in h_priv] == [h.tokens for h in h_solo]
    assert [h.ttft for h in h_priv] == [h.ttft for h in h_solo]
    assert [h.latency for h in h_priv] == [h.latency for h in h_solo]
    for key in ("preempt_swaps", "preempt_recomputes", "steps", "clock_s"):
        assert priv.stats()[key] == solo.stats()[key], key


def test_work_conservation_lone_tenant_gets_whole_pool(model, params):
    """With no other live tenant, the fair share IS the pool: the lone
    tenant's allowance equals the quota and it can hold every page."""
    arb = PoolArbiter(POOL_PAGES, page_size=8)
    solo = Engine.local(model, _cfg(), params=params,
                        budget=KVBudget(tier2_bytes=1e9, page_size=8),
                        arbiter=arb, tenant="solo")
    # idle engine: still entitled to everything (demand-aware shares
    # donate only to *other live* tenants, of which there are none)
    assert solo.kv.allowance() == POOL_PAGES
    h = solo.submit(Request(tuple(range(1, 13)), 10))
    while not solo.idle:
        solo.step()
        assert solo.kv.allowance() == POOL_PAGES
    assert h.status is RequestStatus.DONE
    # a registered-but-idle second tenant donates its (zero) demand
    Engine.local(model, _cfg(), params=params,
                 budget=KVBudget(page_size=8), arbiter=arb, tenant="idle")
    h2 = solo.submit(Request(tuple(range(1, 13)), 10))
    run_trace(solo, [])  # no-op driver; step manually
    while not solo.idle:
        solo.step()
    assert h2.status is RequestStatus.DONE
    assert solo.kv.allowance() == POOL_PAGES


# ---------------------------------------------------------------------------
# revocation: demand-driven, charged to the over-share tenant
# ---------------------------------------------------------------------------

def test_revocation_evicts_over_share_tenant_and_charges_it(model, params):
    """Tenant A saturates the pool while B is idle (work conservation);
    when B's burst arrives, the arbiter claws pages back from A's
    paused sequences — A's handles record the swaps, A's clock absorbs
    the swap seconds, B pays nothing."""
    arb = PoolArbiter(POOL_PAGES, page_size=8)
    kw = dict(params=params, budget=KVBudget(tier2_bytes=1e9, page_size=8),
              arbiter=arb)
    a = Engine.local(model, _cfg(), tenant="a", **kw)
    b = Engine.local(model, _cfg(), tenant="b", **kw)

    trace_a = burst_trace(8, prompt_len=12, max_new_tokens=16,
                          vocab=VOCAB, seed=1)          # burst at t=0
    # B arrives mid-flight of A's burst (the modeled drain of trace_a
    # under this pool is ~1e-3 s), while A still saturates the pool
    trace_b = [dataclasses.replace(r, arrival_time=1e-4)
               for r in burst_trace(2, prompt_len=12, max_new_tokens=4,
                                    vocab=VOCAB, seed=2)]
    ha, hb = run_multi_trace([(a, trace_a), (b, trace_b)])
    assert all(h.status is RequestStatus.DONE for h in ha + hb)

    s = arb.stats()
    assert arb.revoked_pages > 0, "B's arrival never forced revocation"
    charged_a = s["tenants"]["a"]["revocation_charged_s"]
    charged_b = s["tenants"]["b"]["revocation_charged_s"]
    # charges land on whoever was over-share when the pool ran dry: the
    # hog carries (essentially all of) them, never the under-share
    # requester — B may pick up a stray page late in the drain when the
    # roles briefly flip, but A must dominate
    assert charged_a > 0.0
    assert charged_a > 4 * charged_b
    # the victim's handles carry the swap episodes revocation caused
    assert sum(h.swaps for h in ha) > 0


def test_tenants_page_tables_never_alias(model, params):
    """Two tenants decoding concurrently over one physical pool never
    hold the same physical page: their tokens match single-tenant runs
    of the same traces (content isolation through the shared arrays)."""
    arb = PoolArbiter(16, page_size=8)
    kw = dict(params=params, budget=KVBudget(tier2_bytes=1e9, page_size=8),
              arbiter=arb)
    a = Engine.local(model, _cfg(), tenant="a", **kw)
    b = Engine.local(model, _cfg(), tenant="b", **kw)
    ta, tb = _heavy(n=4, seed=3), _heavy(n=4, seed=4)

    # reference: each trace alone on an unbudgeted private engine
    ra = run_trace(Engine.local(model, _cfg(), params=params), ta)
    rb = run_trace(Engine.local(model, _cfg(), params=params), tb)

    ha, hb = run_multi_trace([(a, ta), (b, tb)])
    assert [h.tokens for h in ha] == [h.tokens for h in ra]
    assert [h.tokens for h in hb] == [h.tokens for h in rb]


# ---------------------------------------------------------------------------
# sharing incentive (the fig9 claim at test scale)
# ---------------------------------------------------------------------------

def test_sharing_incentive_and_pooling_beats_static(model, params):
    """Skewed two-tenant traffic: fair-share pooling must beat static
    1/N partitioning on aggregate p95, and the light tenant must do no
    worse than under its private static half."""
    pool_pages, t2 = 12, 1e9
    heavy = burst_trace(6, prompt_len=12, max_new_tokens=12, vocab=VOCAB,
                        seed=5)
    light = [dataclasses.replace(r, arrival_time=1e-4)
             for r in burst_trace(2, prompt_len=12, max_new_tokens=6,
                                  vocab=VOCAB, seed=6)]

    def static_run(trace):
        eng = Engine.local(model, _cfg(), params=params,
                           budget=KVBudget(tier1_pages=pool_pages // 2,
                                           tier2_bytes=t2 / 2, page_size=8))
        return run_trace(eng, trace)

    s_heavy, s_light = static_run(heavy), static_run(light)

    arb = PoolArbiter(pool_pages, page_size=8)
    kw = dict(params=params,
              budget=KVBudget(tier2_bytes=t2 / 2, page_size=8), arbiter=arb)
    a = Engine.local(model, _cfg(), tenant="heavy", **kw)
    b = Engine.local(model, _cfg(), tenant="light", **kw)
    f_heavy, f_light = run_multi_trace([(a, heavy), (b, light)])

    agg_static = latency_summary(s_heavy + s_light)["p95_s"]
    agg_fair = latency_summary(f_heavy + f_light)["p95_s"]
    assert agg_fair < agg_static, \
        f"pooling p95 {agg_fair} not better than static {agg_static}"
    # sharing incentive: the light tenant is not worse off than under
    # its guaranteed static half (small tolerance for step quantization)
    p_light_static = latency_summary(s_light)["p95_s"]
    p_light_fair = latency_summary(f_light)["p95_s"]
    assert p_light_fair <= p_light_static * 1.05, \
        f"light tenant p95 {p_light_fair} vs static {p_light_static}"


# ---------------------------------------------------------------------------
# multi-tenant lease surface
# ---------------------------------------------------------------------------

def test_lease_kv_share_splits_grant():
    pool = smoke_pool()
    lease = pool.lease("svc", 4, tier2_gb=64, kv_gb=16,
                       tenants=("a", "b"))
    assert lease.tenants == ("a", "b")
    share = lease.kv_share("a", page_size=32)
    assert share.tier2_bytes == pytest.approx(8 * GB)
    assert share.tier1_pages is None and share.page_size == 32
    with pytest.raises(KeyError, match="ghost"):
        lease.kv_share("ghost")
    plain = pool.lease("plain", 4, tier2_gb=8, kv_gb=2)
    with pytest.raises(ValueError, match="tenants"):
        plain.kv_share("a")
    with pytest.raises(ValueError, match="kv_bytes"):
        pool.lease("bad", 4, tier2_gb=8, tenants=("x",))  # tenants, no grant
    with pytest.raises(ValueError, match="duplicate"):
        pool.lease("dup", 4, tier2_gb=8, kv_gb=2, tenants=("x", "x"))


def test_engines_from_one_lease_share_arbiter_pool(model):
    """Two engines built from ONE lease + one arbiter serve from one
    physical pool with per-tenant cold budgets from kv_share."""
    pool = smoke_pool()
    lease = pool.lease("mt", 4, tier2_gb=64, kv_gb=4, tenants=("a", "b"))
    arb = PoolArbiter(16, page_size=8)
    a = Engine.from_lease(model, lease, _cfg(), arbiter=arb, tenant="a")
    b = Engine.from_lease(model, lease, _cfg(), arbiter=arb, tenant="b")
    assert a.budget.tier2_bytes == pytest.approx(2 * GB)
    assert b.budget.tier2_bytes == pytest.approx(2 * GB)
    assert arb.tenants == ("a", "b")
    ha, hb = run_multi_trace([(a, _heavy(n=2, seed=7)),
                              (b, _heavy(n=2, seed=8))])
    assert all(h.status is RequestStatus.DONE for h in ha + hb)


def test_shares_cover_indivisible_pool(model, params):
    """Water-filling must hand out EVERY page when the pool size does
    not divide by the live-tenant count — flooring the remainder away
    would leave pages outside every share, permanently retained by
    whichever hog grabbed them first."""
    def saturated_arbiter(pages):
        arb = PoolArbiter(pages, page_size=8)
        for t in ("a", "b", "c"):
            eng = Engine.local(model, _cfg(), params=params,
                               budget=KVBudget(page_size=8),
                               arbiter=arb, tenant=t)
            # a queued 20-token prompt demands 3 pages without stepping
            eng.submit(Request(tuple(range(1, 21)), 8))
        return arb

    shares = saturated_arbiter(8)._shares()
    assert sum(shares.values()) == 8          # nothing stranded
    assert sorted(shares.values()) == [2, 3, 3]
    tiny = saturated_arbiter(2)._shares()
    assert sum(tiny.values()) == 2            # not all-zero
    assert sorted(tiny.values()) == [0, 1, 1]


def test_arbiter_rejects_mismatched_geometry(model, params):
    arb = PoolArbiter(8, page_size=8)
    Engine.local(model, _cfg(), params=params, arbiter=arb, tenant="a")
    with pytest.raises(ValueError, match="page_size"):
        Engine.local(model, _cfg(page_size=16), params=params,
                     arbiter=arb, tenant="b")
    with pytest.raises(ValueError, match="already registered"):
        Engine.local(model, _cfg(), params=params, arbiter=arb, tenant="a")


# ---------------------------------------------------------------------------
# DRF queueing: gang all-or-nothing + dominant-resource fairness
# ---------------------------------------------------------------------------

def _inv(policy="scalepool"):
    return build_inventory(
        n_pods=4, pod_size=8, hbm_per_accel_gb=192.0,
        n_memory_nodes=2, memory_node_gb=1024.0, interconnect=policy)


def _par(dp):
    return sim.ParallelismConfig(tp=2, pp=1, dp=dp, global_batch_seqs=64)


def test_drf_gang_admits_all_or_nothing():
    """A gang larger than the current free estate must not admit
    partially, even when one member alone would fit; once resources
    free up the whole gang starts together."""
    sched = Scheduler(_inv(), queueing="drf")
    sched.submit(PoolJob("solo", sim.MEGATRON, _par(4), n_steps=20,
                         submit_t=0.0, user="u1"))               # 8 accels
    for i in range(2):                                           # 2 x 16
        sched.submit(PoolJob(f"g{i}", sim.MEGATRON, _par(8), n_steps=10,
                             submit_t=1.0, user="u2", gang="pair"))
    res = sched.run()
    recs = res.records
    assert all(r.finish_t is not None for r in recs.values())
    # while solo ran (24 free: one 16-accel member fits, two do not),
    # neither gang member started — they start together afterwards
    assert recs["g0"].start_t == recs["g1"].start_t
    assert recs["g0"].start_t >= recs["solo"].finish_t
    assert any("all-or-nothing" in line for line in res.trace)


def test_drf_favors_low_dominant_share_user():
    """User A floods the pool; user B's later job runs as soon as
    capacity frees, ahead of A's backlog (B's dominant share is 0,
    A's is ~1/2) — FIFO order would have run A's backlog first."""
    def run(queueing):
        sched = Scheduler(_inv(), queueing=queueing, backfill=False)
        for i in range(3):
            # staggered durations so capacity frees one job at a time
            sched.submit(PoolJob(f"a{i}", sim.MEGATRON, _par(8),
                                 n_steps=20 + 10 * i,
                                 submit_t=0.0, user="A"))        # 16 each
        sched.submit(PoolJob("b0", sim.MEGATRON, _par(8), n_steps=20,
                             submit_t=0.5, user="B"))
        return sched.run().records

    drf = run("drf")
    assert drf["b0"].start_t < drf["a2"].start_t, \
        "DRF should admit the idle user's job before the hog's backlog"
    fifo = run("fifo")
    assert fifo["b0"].start_t >= fifo["a2"].start_t, \
        "FIFO control: submission order should win without DRF"


def test_drf_gang_weighs_all_three_resources():
    """Dominant share is the max over ⟨accels, tier-2 bytes, tier-2
    bandwidth⟩: a byte-hungry user with few accels still accrues share
    on the bytes dimension."""
    inv = build_inventory(n_pods=4, pod_size=8, n_memory_nodes=2,
                          memory_node_gb=1024.0, memory_node_gbps=50.0,
                          interconnect="scalepool")
    sched = Scheduler(inv, queueing="drf")
    sched.submit(PoolJob("mem", sim.MEGATRON, _par(2), n_steps=20,
                         tier2_bytes=1536 * GB, submit_t=0.0, user="M"))
    res = sched.run(until=0.0)
    assert sched._dominant_share("M") == pytest.approx(1536 / 2048)
    assert sched._dominant_share("nobody") == 0.0


def test_scheduler_rejects_unknown_queueing():
    with pytest.raises(ValueError, match="queueing"):
        Scheduler(_inv(), queueing="lottery")

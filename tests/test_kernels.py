"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
with hypothesis shape/dtype sweeps (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import HealthCheck, given, settings, st

from repro.kernels import ops, ref
from repro.models import mamba2

SETTINGS = dict(deadline=None, max_examples=12,
                suppress_health_check=[HealthCheck.too_slow])


def rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    B=st.sampled_from([1, 2]),
    Sq=st.sampled_from([16, 64, 128, 130]),
    H=st.sampled_from([1, 4]),
    group=st.sampled_from([1, 2, 4]),
    D=st.sampled_from([16, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_attention_matches_ref(B, Sq, H, group, D, dtype):
    if H % group:
        group = 1
    key = jax.random.PRNGKey(B * 1000 + Sq + H + D)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (B, Sq, H, D), dtype)
    k = rand(kk, (B, Sq, H // group, D), dtype)
    v = rand(kv, (B, Sq, H // group, D), dtype)

    got = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    want = jnp.moveaxis(
        ref.attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                          jnp.moveaxis(v, 1, 2), causal=True), 1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_sliding_window():
    key = jax.random.PRNGKey(0)
    q = rand(key, (1, 128, 2, 32), jnp.float32)
    k = rand(jax.random.fold_in(key, 1), (1, 128, 2, 32), jnp.float32)
    v = rand(jax.random.fold_in(key, 2), (1, 128, 2, 32), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, sliding_window=32,
                              block_q=32, block_k=32)
    want = jnp.moveaxis(
        ref.attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                          jnp.moveaxis(v, 1, 2), causal=True,
                          sliding_window=32), 1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_model_reference_path():
    """The kernel and the model's gqa_attention must agree (they are the
    two attention_impl choices)."""
    from repro.models.layers import gqa_attention
    key = jax.random.PRNGKey(3)
    q = rand(key, (2, 64, 4, 32), jnp.float32)
    k = rand(jax.random.fold_in(key, 1), (2, 64, 2, 32), jnp.float32)
    v = rand(jax.random.fold_in(key, 2), (2, 64, 2, 32), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    want = gqa_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    rows=st.sampled_from([1, 7, 64, 300]),
    d=st.sampled_from([64, 512, 1024]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_rmsnorm_matches_ref(rows, d, dtype):
    key = jax.random.PRNGKey(rows * 7 + d)
    x = rand(key, (rows, d), dtype)
    scale = 1.0 + 0.1 * rand(jax.random.fold_in(key, 1), (d,), jnp.float32)
    got = ops.rmsnorm(x, scale, block_rows=64)
    want = ref.rmsnorm_ref(x, scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

def ssd_inputs(key, B, S, H, P, G, N):
    ks = jax.random.split(key, 5)
    x = rand(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(0.5 * rand(ks[2], (H,), jnp.float32))
    Bm = rand(ks[3], (B, S, G, N), jnp.float32) / np.sqrt(N)
    Cm = rand(ks[4], (B, S, G, N), jnp.float32) / np.sqrt(N)
    D = jnp.ones((H,))
    return x, dt, A, Bm, Cm, D


@settings(**SETTINGS)
@given(
    B=st.sampled_from([1, 2]),
    S=st.sampled_from([8, 32, 50, 128]),
    H=st.sampled_from([1, 2, 4]),
    G=st.sampled_from([1, 2]),
    chunk=st.sampled_from([8, 16, 32]),
)
def test_ssd_kernel_matches_sequential_ref(B, S, H, G, chunk):
    if H % G:
        G = 1
    P, N = 8, 16
    x, dt, A, Bm, Cm, D = ssd_inputs(jax.random.PRNGKey(S + H), B, S, H, P, G, N)
    y, h = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk)
    y_ref, h_ref = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=2e-4, rtol=2e-4)


def test_ssd_kernel_matches_model_chunked_path():
    """Kernel vs the model's associative-scan SSD (the dry-run path)."""
    P, N = 8, 16
    x, dt, A, Bm, Cm, D = ssd_inputs(jax.random.PRNGKey(9), 2, 64, 4, P, 1, N)
    y_k, h_k = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=16)
    y_m, h_m = mamba2.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                               atol=2e-4, rtol=2e-4)


def test_ssd_kernel_initial_state():
    P, N = 8, 16
    x, dt, A, Bm, Cm, D = ssd_inputs(jax.random.PRNGKey(11), 1, 32, 2, P, 1, N)
    h0 = rand(jax.random.PRNGKey(12), (1, 2, P, N), jnp.float32)
    y, h = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=8, init_state=h0)
    y_ref, h_ref = ref.ssd_ref(x, dt, A, Bm, Cm, D, init_state=h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=2e-4, rtol=2e-4)


def test_model_ssd_chunked_matches_sequential_ref():
    """The model's chunked SSD (oracle for the dry-run) vs token-by-token
    recurrence, including the padded tail-chunk path."""
    P, N = 8, 16
    x, dt, A, Bm, Cm, D = ssd_inputs(jax.random.PRNGKey(21), 2, 50, 4, P, 1, N)
    y_m, h_m = mamba2.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    y_ref, h_ref = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h_m), np.asarray(h_ref),
                               atol=2e-4, rtol=2e-4)

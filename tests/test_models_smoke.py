"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKE_ARCHS
from repro.models.api import build_model
from conftest import make_batch

ALL_ARCHS = sorted(SMOKE_ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_loss(arch, rng):
    cfg = SMOKE_ARCHS[arch]
    model = build_model(cfg)
    params = model.init(rng)
    batch = make_batch(rng, cfg)
    loss = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert 2.0 < float(loss) < 12.0, f"{arch}: implausible init loss {loss}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_grads_finite(arch, rng):
    cfg = SMOKE_ARCHS[arch]
    model = build_model(cfg)
    params = model.init(rng)
    batch = make_batch(rng, cfg)

    @jax.jit
    def step(p, b):
        loss, grads = jax.value_and_grad(model.loss)(p, b)
        p2 = jax.tree.map(lambda w, g: w - 1e-3 * g.astype(w.dtype), p, grads)
        return loss, p2, grads

    loss0, params2, grads = step(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0, f"{arch}: grad norm {gnorm}"
    loss1, _, _ = step(params2, batch)
    assert jnp.isfinite(loss1)
    # one SGD step on the same batch should not increase loss much
    assert float(loss1) < float(loss0) + 0.5


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_axes_match_params(arch, rng):
    """The logical-axis tree must be congruent with the param tree."""
    cfg = SMOKE_ARCHS[arch]
    model = build_model(cfg)
    params = model.init(rng)
    axes = model.param_axes()
    pleaves, ptree = jax.tree.flatten(params)
    aleaves, atree = jax.tree.flatten(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(pleaves) == len(aleaves), f"{arch}: {len(pleaves)} vs {len(aleaves)}"
    for p, a in zip(pleaves, aleaves):
        assert len(a) == p.ndim, f"{arch}: axes {a} vs shape {p.shape}"

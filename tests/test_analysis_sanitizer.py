"""``repro.analysis.sanitizer`` — the modeled-time causality checker.

Two halves:

* synthetic event streams, one per rule: a hand-corrupted stream
  (clock regression, double-freed page, over-line-rate link span,
  charge without a priced revocation, ...) must be REJECTED with the
  right rule name, track, and timestamp, and the matching clean stream
  must pass;
* live instrumented runs: a private paged engine and a multi-tenant
  arbiter estate both sanitize clean, the live ``attach`` hook agrees
  with the offline passes (``sanitize_tracer`` and the Perfetto
  export round-trip), and every stateful rule actually checked
  something (no vacuous passes).
"""

import json
import math

import jax
import pytest

from repro.analysis import (RULES, Sanitizer, attach, sanitize_events,
                            sanitize_tracer, sanitize_trace_doc,
                            sanitize_trace_file)
from repro.analysis.sanitizer import TraceViolation
from repro.configs import SMOKE_ARCHS
from repro.core.tiering import KVBudget
from repro.obs import Tracer, to_chrome_trace, write_chrome_trace
from repro.obs.trace import CAT_ENGINE, CAT_KV, CAT_LINK, Event
from repro.serve import (Engine, EngineConfig, PoolArbiter, burst_trace,
                         run_multi_trace, run_trace)

VOCAB = SMOKE_ARCHS["qwen1.5-0.5b"].vocab
POOL_PAGES = 6          # tight: forces paging under the heavy trace


@pytest.fixture(scope="module")
def model():
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"].__class__(**{
        **SMOKE_ARCHS["qwen1.5-0.5b"].__dict__, "compute_dtype": "float32"})
    from repro.models.api import build_model
    return build_model(cfg)


@pytest.fixture(scope="module")
def params(model):
    return model.init(jax.random.PRNGKey(0))


def _cfg(**kw):
    base = dict(max_slots=3, max_seq=64, page_size=8)
    base.update(kw)
    return EngineConfig(**base)


def _heavy(n=5, seed=0, max_new=10):
    return burst_trace(n, prompt_len=12, max_new_tokens=max_new,
                       vocab=VOCAB, seed=seed)


# ---------------------------------------------------------------------------
# synthetic streams: event constructors
# ---------------------------------------------------------------------------

def _span(track, name, ts, dur, cat=CAT_ENGINE, **args):
    return Event("X", cat, track, name, ts, dur, args)


def _instant(track, name, ts, cat=CAT_ENGINE, **args):
    return Event("i", cat, track, name, ts, 0.0, args)


def _counter(track, name, ts, value, cat=CAT_ENGINE):
    return Event("C", cat, track, name, ts, 0.0, {"value": value})


def _only(report, rule):
    """Assert exactly one violation and it names ``rule``; return it."""
    assert not report.ok
    assert [v.rule for v in report.violations] == [rule], report.format()
    return report.violations[0]


# ---------------------------------------------------------------------------
# per-rule rejection: hand-corrupted streams
# ---------------------------------------------------------------------------

def test_finite_clock_rejects_nan_and_negative_dur():
    v = _only(sanitize_events([_span("engine:a", "decode",
                                     float("nan"), 0.1)]),
              "finite-clock")
    assert v.track == "engine:a" and math.isnan(v.ts)
    _only(sanitize_events([_span("engine:a", "decode", 1.0, -0.5)]),
          "finite-clock")
    assert sanitize_events([_span("engine:a", "decode", 1.0, 0.5)]).ok


def test_track_monotone_rejects_clock_regression():
    evs = [_span("engine:a", "prefill", 0.0, 1.0),
           _span("engine:a", "kv_fetch", 0.2, 0.1, cat=CAT_KV)]
    v = _only(sanitize_events(evs), "track-monotone")   # ends 0.3 < 1.0
    assert v.track == "engine:a"
    assert v.ts == pytest.approx(0.2)
    assert "backwards" in v.message


def test_track_monotone_exemptions():
    # future-dated submits, arbiter-track interleavings, and drop
    # decisions stamped before already-emitted spill ends are all legal
    evs = [_span("engine:a", "prefill", 0.0, 1.0),
           _instant("engine:a", "submit", 0.1),
           _instant("engine:a", "recompute_drop", 0.2, cat=CAT_KV),
           _instant("pool:arbiter", "revoke", 5.0),
           _instant("pool:arbiter", "charge", 2.0)]
    assert sanitize_events(evs).ok


def test_span_serial_rejects_overlapping_compute_spans():
    evs = [_span("engine:a", "decode", 0.0, 1.0),
           _span("engine:a", "decode", 0.5, 1.0)]
    v = _only(sanitize_events(evs), "span-serial")
    assert v.ts == pytest.approx(0.5)


def test_span_serial_ignores_kv_and_link_tracks():
    # a revocation spill legitimately overlaps the victim's compute,
    # and per-link sub-tracks carry concurrent flows by design
    evs = [_span("engine:a", "decode", 0.0, 1.0),
           _span("engine:a", "kv_spill", 0.5, 1.0, cat=CAT_KV, pages=2),
           _span("engine:a/kv", "kv_spill", 0.5, 1.0, cat=CAT_KV)]
    assert sanitize_events(evs).ok


def test_transfer_span_without_begin_rejected():
    v = _only(sanitize_events(
        [_span("fabric", "xfer", 1.0, 0.5, cat="fabric",
               fid=7, bytes=100.0)]), "transfer-causality")
    assert "no begin_transfer" in v.message and v.track == "fabric"


def test_transfer_begin_after_span_start_rejected():
    evs = [_instant("fabric", "begin_transfer", 2.0, cat="fabric",
                    fid=7, bytes=100.0),
           _span("fabric", "xfer", 1.0, 0.5, cat="fabric",
                 fid=7, bytes=100.0)]
    # begin at t=2.0 "causes" a span starting at t=1.0
    assert not sanitize_events(evs).ok


def test_transfer_byte_mismatch_rejected_and_clean_pair_passes():
    begin = _instant("fabric", "begin_transfer", 0.0, cat="fabric",
                     fid=7, bytes=100.0)
    bad = sanitize_events([begin, _span("fabric", "xfer", 0.5, 0.5,
                                        cat="fabric", fid=7, bytes=300.0)])
    _only(bad, "transfer-causality")
    good = sanitize_events([begin, _span("fabric", "xfer", 0.5, 0.5,
                                         cat="fabric", fid=7, bytes=100.0)])
    assert good.ok
    assert any("1 transfer span(s) paired" in n for n in good.notes)


def test_unmatched_begin_is_a_note_not_a_violation():
    rep = sanitize_events([_instant("fabric", "begin_transfer", 0.0,
                                    cat="fabric", fid=9, bytes=10.0)])
    assert rep.ok
    assert any("in flight" in n for n in rep.notes)


def test_link_span_faster_than_solo_rejected():
    v = _only(sanitize_events(
        [_span("link:xlink0", "xfer", 0.0, 0.5, cat=CAT_LINK,
               bytes=10.0, solo_s=1.0, capacity=1e9)]),
        "link-conservation")
    assert "FASTER" in v.message


def test_link_span_over_line_rate_rejected():
    # fires per-span AND again in the end-of-stream union check
    rep = sanitize_events(
        [_span("link:xlink0", "xfer", 0.0, 1.0, cat=CAT_LINK,
               bytes=200.0, solo_s=0.5, capacity=100.0)])
    assert not rep.ok
    assert {v.rule for v in rep.violations} == {"link-conservation"}
    assert "line rate" in rep.violations[0].message


def test_link_union_conservation_rejects_multiplied_link():
    # two fully-overlapping spans, each individually at line rate: the
    # union is 1 busy second at 100 B/s but 200 bytes "moved" — the
    # link was silently counted twice
    evs = [_span("link:xlink0", "a", 0.0, 1.0, cat=CAT_LINK,
                 bytes=100.0, solo_s=1.0, capacity=100.0),
           _span("link:xlink0", "b", 0.0, 1.0, cat=CAT_LINK,
                 bytes=100.0, solo_s=1.0, capacity=100.0)]
    v = _only(sanitize_events(evs), "link-conservation")
    assert v.track == "link:xlink0" and "busy window" in v.message
    # fair-shared version: same bytes spread over a stretched window
    ok = [_span("link:xlink0", "a", 0.0, 2.0, cat=CAT_LINK,
                bytes=100.0, solo_s=1.0, capacity=100.0),
          _span("link:xlink0", "b", 0.0, 2.0, cat=CAT_LINK,
                bytes=100.0, solo_s=1.0, capacity=100.0)]
    assert sanitize_events(ok).ok


def _solo_kv(free, hot, pool=10.0, ts=1.0):
    return [_instant("engine:a", "kv_pool", 0.0, cat=CAT_KV, pages=pool),
            _counter("engine:a", "free_pages", ts, free, cat=CAT_KV),
            _counter("engine:a", "hot_pages", ts, hot, cat=CAT_KV)]


def test_kv_conservation_solo_pool():
    assert sanitize_events(_solo_kv(4.0, 6.0)).ok
    leak = _only(sanitize_events(_solo_kv(4.0, 5.0)), "kv-conservation")
    assert "leaked" in leak.message and leak.ts == pytest.approx(1.0)
    conjured = _only(sanitize_events(_solo_kv(4.0, 7.0)),
                     "kv-conservation")
    assert "conjured" in conjured.message


def _shared_kv(hot_a, hot_b, free_b, pool=12.0):
    """A's step-end sample lands before B has allocated anything, so
    A sees ``pool - hot_a`` free; B's sample follows once it holds
    ``hot_b`` (consistent: ``free_b = pool - hot_a - hot_b``)."""
    return [
        _instant("pool:arbiter", "pool_tenants", 0.0, cat="arbiter",
                 pages=pool, tenants=["a", "b"]),
        _counter("engine:a", "free_pages", 1.0, pool - hot_a,
                 cat=CAT_KV),
        _counter("engine:a", "hot_pages", 1.0, hot_a, cat=CAT_KV),
        _counter("engine:b", "free_pages", 2.0, free_b, cat=CAT_KV),
        _counter("engine:b", "hot_pages", 2.0, hot_b, cat=CAT_KV),
    ]


def test_kv_conservation_shared_pool():
    assert sanitize_events(_shared_kv(5.0, 3.0, free_b=4.0)).ok
    v = _only(sanitize_events(_shared_kv(5.0, 3.0, free_b=5.0)),
              "kv-conservation")
    assert v.track == "engine:b" and "conjured" in v.message


def test_kv_double_free_via_oversized_revoke():
    evs = _shared_kv(5.0, 3.0, free_b=4.0) + [
        # the arbiter claims 9 pages from a tenant holding 5
        _instant("pool:arbiter", "revoke", 3.0, cat="arbiter",
                 victim="a", requester="b", pages=9, rid=0, cost_s=0.1)]
    v = _only(sanitize_events(evs), "kv-conservation")
    assert v.track == "engine:a" and "freed twice" in v.message
    assert v.ts == pytest.approx(3.0)


def test_kv_revoke_folds_into_next_sample():
    evs = _shared_kv(5.0, 3.0, free_b=4.0) + [
        _instant("pool:arbiter", "revoke", 3.0, cat="arbiter",
                 victim="a", requester="b", pages=2, rid=0, cost_s=0.1),
        # victim's next sample reflects the revocation; free grew by 2
        _counter("engine:a", "free_pages", 4.0, 6.0, cat=CAT_KV),
        _counter("engine:a", "hot_pages", 4.0, 3.0, cat=CAT_KV)]
    assert sanitize_events(evs).ok


def test_kv_rule_disabled_on_pre_instrumented_trace():
    # a revoke with no page count (old trace): the rule switches off
    # with a note instead of guessing
    evs = _shared_kv(5.0, 3.0, free_b=4.0) + [
        _instant("pool:arbiter", "revoke", 3.0, cat="arbiter",
                 victim="a", requester="b", rid=0, cost_s=0.1),
        _counter("engine:a", "free_pages", 4.0, 0.0, cat=CAT_KV),
        _counter("engine:a", "hot_pages", 4.0, 0.0, cat=CAT_KV)]
    rep = sanitize_events(evs)
    assert rep.ok
    assert any("kv-conservation disabled" in n for n in rep.notes)


# ---------------------------------------------------------------------------
# scheduler (pool:sched) rules: synthetic streams
# ---------------------------------------------------------------------------

SCHED = "pool:sched"


def _sched_base(ts=0.0):
    return [_instant(SCHED, "sched_pool", ts, cat="sched", accels=8.0,
                     tier2_gb=100.0)]


def _job(name, submit_t, admit_t, finish_t, gang=""):
    """A well-formed submit → admit → run → finish lifecycle."""
    return [
        _instant(SCHED, "submit", submit_t, cat="sched", job=name),
        _instant(SCHED, "admit", admit_t, cat="sched", job=name,
                 gang=gang),
        _span(SCHED, f"run:{name}", admit_t, finish_t - admit_t,
              cat="sched", job=name),
        _instant(SCHED, "finish", finish_t, cat="sched", job=name,
                 jct_s=finish_t - submit_t),
    ]


def test_sched_clean_lifecycle_passes_and_counts():
    rep = sanitize_events(_sched_base() + [
        _counter(SCHED, "free_accels", 0.5, 6.0, cat="sched"),
        _counter(SCHED, "busy_accels", 0.5, 2.0, cat="sched"),
        _counter(SCHED, "drf_share:u", 0.5, 0.25, cat="sched"),
    ] + _job("j0", 0.0, 1.0, 5.0))
    assert rep.ok, rep.format()
    assert rep.checks["sched-job-span"] > 0
    assert rep.checks["sched-accel-conservation"] == 1
    assert rep.checks["sched-drf-share"] == 1


def test_sched_accel_leak_and_conjure_rejected():
    leak = _only(sanitize_events(_sched_base() + [
        _counter(SCHED, "free_accels", 1.0, 4.0, cat="sched"),
        _counter(SCHED, "busy_accels", 1.0, 2.0, cat="sched")]),
        "sched-accel-conservation")
    assert "leaked" in leak.message and leak.ts == pytest.approx(1.0)
    conjured = _only(sanitize_events(_sched_base() + [
        _counter(SCHED, "free_accels", 1.0, 7.0, cat="sched"),
        _counter(SCHED, "busy_accels", 1.0, 3.0, cat="sched")]),
        "sched-accel-conservation")
    assert "conjured" in conjured.message
    # no geometry announced → the rule stands down, not guesses
    assert sanitize_events([
        _counter(SCHED, "free_accels", 1.0, 4.0, cat="sched"),
        _counter(SCHED, "busy_accels", 1.0, 2.0, cat="sched")]).ok


def test_sched_drf_share_bound():
    v = _only(sanitize_events(
        [_counter(SCHED, "drf_share:u", 1.0, 1.25, cat="sched")]),
        "sched-drf-share")
    assert "outside [0, 1]" in v.message and v.track == SCHED
    # stateless: still enforced on a truncated recording
    assert not sanitize_events(
        [_counter(SCHED, "drf_share:u", 1.0, -0.5, cat="sched")],
        truncated=True).ok
    assert sanitize_events(
        [_counter(SCHED, "drf_share:u", 1.0, 1.0, cat="sched")]).ok


def test_sched_job_span_orderings_rejected():
    # finish before admit (non-monotone job span)
    evs = _sched_base() + [
        _instant(SCHED, "submit", 0.0, cat="sched", job="j"),
        _instant(SCHED, "admit", 2.0, cat="sched", job="j", gang=""),
        _instant(SCHED, "finish", 1.0, cat="sched", job="j", jct_s=1.0)]
    rep = sanitize_events(evs)
    assert any(v.rule == "sched-job-span" and "before its last admit"
               in v.message for v in rep.violations), rep.format()
    # admitted but never submitted (ghost admission)
    v = _only(sanitize_events(_sched_base() + [
        _instant(SCHED, "admit", 1.0, cat="sched", job="ghost",
                 gang="")]), "sched-job-span")
    assert "never submitted" in v.message and v.ts == pytest.approx(1.0)
    # run segment while not admitted
    v = _only(sanitize_events(_sched_base() + [
        _span(SCHED, "run:j", 1.0, 2.0, cat="sched", job="j")]),
        "sched-job-span")
    assert "not admitted" in v.message
    # double admission with no intervening preempt/finish
    evs = _sched_base() + [
        _instant(SCHED, "submit", 0.0, cat="sched", job="j"),
        _instant(SCHED, "admit", 1.0, cat="sched", job="j", gang=""),
        _instant(SCHED, "admit", 2.0, cat="sched", job="j", gang="")]
    _only(sanitize_events(evs), "sched-job-span")
    # jct_s that disagrees with finish - submit
    evs = _sched_base() + _job("j", 0.0, 1.0, 5.0)
    evs[-1] = _instant(SCHED, "finish", 5.0, cat="sched", job="j",
                       jct_s=3.0)
    v = _only(sanitize_events(evs), "sched-job-span")
    assert "jct_s" in v.message


def test_sched_preempt_reopens_admission():
    evs = _sched_base() + [
        _instant(SCHED, "submit", 0.0, cat="sched", job="j"),
        _instant(SCHED, "admit", 1.0, cat="sched", job="j", gang=""),
        _span(SCHED, "run:j", 1.0, 1.0, cat="sched", job="j"),
        _instant(SCHED, "preempt", 2.0, cat="sched", job="j"),
        _instant(SCHED, "admit", 3.0, cat="sched", job="j", gang=""),
        _span(SCHED, "run:j", 3.0, 1.0, cat="sched", job="j"),
        _instant(SCHED, "finish", 4.0, cat="sched", job="j", jct_s=4.0)]
    assert sanitize_events(evs).ok


def _gang_pair(t_a, t_b, gang_at=None, members=2):
    evs = (_sched_base()
           + [_instant(SCHED, "submit", 0.0, cat="sched", job="a"),
              _instant(SCHED, "submit", 0.0, cat="sched", job="b"),
              _instant(SCHED, "admit", t_a, cat="sched", job="a",
                       gang="g"),
              _instant(SCHED, "admit", t_b, cat="sched", job="b",
                       gang="g")])
    if gang_at is not None:
        evs.append(_instant(SCHED, "gang_admit", gang_at, cat="sched",
                            gang="g", members=members))
    return evs


def test_sched_gang_atomic():
    ok = sanitize_events(_gang_pair(1.0, 1.0, gang_at=1.0))
    assert ok.ok and ok.checks["sched-gang-atomic"] == 1
    # member admitted in a different round than its gang_admit: the
    # stale member AND the resulting count shortfall are both named
    rep = sanitize_events(_gang_pair(1.0, 2.0, gang_at=2.0, members=2))
    assert not rep.ok
    assert all(v.rule == "sched-gang-atomic" for v in rep.violations)
    assert any("split across rounds" in v.message
               for v in rep.violations)
    assert rep.violations[0].ts == pytest.approx(2.0)
    # gang_admit names more members than actually landed
    v = _only(sanitize_events(_gang_pair(1.0, 1.0, gang_at=1.0,
                                         members=3)),
              "sched-gang-atomic")
    assert "3 member(s) but 2" in v.message
    # gang-tagged admits never covered by any gang_admit: caught at
    # end of stream
    v = _only(sanitize_events(_gang_pair(1.0, 1.0, gang_at=None)),
              "sched-gang-atomic")
    assert "split gang" in v.message


def test_sched_stateful_rules_skip_truncated_streams():
    # the same corruptions, but the ring dropped events — only the
    # stateless drf bound may still fire
    evs = _gang_pair(1.0, 2.0, gang_at=2.0) + [
        _counter(SCHED, "free_accels", 3.0, 1.0, cat="sched"),
        _counter(SCHED, "busy_accels", 3.0, 1.0, cat="sched")]
    rep = sanitize_events(evs, truncated=True)
    assert rep.ok
    assert rep.checks["sched-gang-atomic"] == 0
    assert rep.checks["sched-accel-conservation"] == 0


def test_live_scheduler_run_sanitizes_clean():
    """A real pool scheduler run — DRF queueing, a declared gang,
    preemption pressure — must satisfy every scheduler rule, and every
    rule must actually check something."""
    import dataclasses as dc

    from repro.core import simulator as sim
    from repro.pool import PoolJob, Scheduler, build_inventory

    tracer = Tracer()
    inv = build_inventory(n_pods=4, pod_size=8, hbm_per_accel_gb=192.0,
                          n_memory_nodes=2, memory_node_gb=1024.0,
                          interconnect="scalepool")
    sched = Scheduler(inv, queueing="drf", tracer=tracer)
    par = sim.ParallelismConfig(tp=2, pp=1, dp=3, global_batch_seqs=66)
    for i in range(2):
        sched.submit(PoolJob(f"g{i}", sim.MEGATRON, par, n_steps=10,
                             submit_t=float(i), gang="pair",
                             gang_size=2, user="u"))
    sched.submit(PoolJob("solo", sim.MEGATRON,
                         dc.replace(par, dp=2), n_steps=5,
                         submit_t=0.5, user="v"))
    sched.run()
    rep = sanitize_tracer(tracer)
    assert rep.ok, rep.format()
    for rule in ("sched-gang-atomic", "sched-accel-conservation",
                 "sched-job-span", "sched-drf-share"):
        assert rep.checks[rule] > 0, rule


def test_revocation_attribution_rejects_unpriced_charge():
    # kv context first so the revoke's page movement is accounted for
    base = _shared_kv(2.0, 0.0, free_b=10.0)
    revoke = _instant("pool:arbiter", "revoke", 3.0, cat="arbiter",
                      victim="a", requester="b", pages=2, rid=0,
                      cost_s=0.5)
    ok = sanitize_events(base + [
        revoke, _instant("pool:arbiter", "charge", 4.0, cat="arbiter",
                         tenant="a", cost_s=0.5)])
    assert ok.ok and ok.checks["revocation-attribution"] == 1
    v = _only(sanitize_events(base + [
        revoke, _instant("pool:arbiter", "charge", 4.0, cat="arbiter",
                         tenant="a", cost_s=0.7)]),
        "revocation-attribution")
    assert "billed" in v.message
    # a charge against a tenant nobody revoked is the degenerate case
    _only(sanitize_events(
        [_instant("pool:arbiter", "charge", 2.0, cat="arbiter",
                  tenant="z", cost_s=0.1)]),
        "revocation-attribution")


def test_truncated_stream_skips_stateful_rules():
    # the same double-free stream, but the ring dropped events: the
    # baselines may be gone, so stateful rules stand down (with a note)
    evs = _shared_kv(5.0, 3.0, free_b=4.0) + [
        _instant("pool:arbiter", "revoke", 3.0, cat="arbiter",
                 victim="a", requester="b", pages=9, rid=0, cost_s=0.1)]
    rep = sanitize_events(evs, truncated=True)
    assert rep.ok
    assert rep.checks["kv-conservation"] == 0
    assert any("truncated" in n for n in rep.notes)
    # monotonicity still applies: it needs no dropped baseline
    assert not sanitize_events(
        [_span("engine:a", "prefill", 0.0, 1.0),
         _span("engine:a", "decode", 0.2, 0.1)], truncated=True).ok


def test_report_shapes():
    rep = sanitize_events([_span("engine:a", "decode",
                                 float("inf"), 0.1)])
    assert set(RULES) == set(rep.checks)
    v = rep.violations[0]
    assert isinstance(v, TraceViolation)
    assert v.rule in rep.format() and "FAIL" in rep.format()
    doc = rep.to_doc()
    assert doc["ok"] is False and doc["events"] == 1
    assert doc["violations"][0]["rule"] == "finite-clock"
    json.dumps(doc)    # must be serializable for CI artifacts


# ---------------------------------------------------------------------------
# live instrumented runs
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_solo(model, params):
    """Private paged engine under pressure, sanitized live via hook."""
    tracer = Tracer()
    live = attach(tracer)
    eng = Engine.local(model, _cfg(), params=params,
                       budget=KVBudget(tier1_pages=POOL_PAGES,
                                       tier2_bytes=1e9, page_size=8),
                       tenant="a", tracer=tracer)
    handles = run_trace(eng, _heavy())
    live.detach()
    assert eng.stats()["preempt_swaps"] > 0, "pressure not exercised"
    return {"tracer": tracer, "live": live.finish()}


def test_live_solo_run_sanitizes_clean(traced_solo):
    rep = traced_solo["live"]
    assert rep.ok, rep.format()
    # the stateful solo rules all actually checked something
    assert rep.checks["kv-conservation"] > 0
    assert rep.checks["span-serial"] > 0
    assert rep.checks["track-monotone"] > 0


def test_live_hook_agrees_with_offline_passes(traced_solo, tmp_path):
    live = traced_solo["live"]
    offline = sanitize_tracer(traced_solo["tracer"])
    assert offline.ok and offline.events == live.events
    assert offline.checks == live.checks
    # ... and with the Perfetto export round-trip (µs quantization and
    # track reconstruction included)
    doc = to_chrome_trace(traced_solo["tracer"])
    rt = sanitize_trace_doc(doc)
    assert rt.ok, rt.format()
    assert rt.events == live.events
    path = tmp_path / "solo_trace.json"
    write_chrome_trace(traced_solo["tracer"], str(path))
    assert sanitize_trace_file(str(path)).ok


def test_live_multitenant_estate_sanitizes_clean(model, params):
    """Arbiter + two tenants with forced revocation: the shared-pool
    accounting and attribution rules must hold on a real estate."""
    tracer = Tracer()
    arb = PoolArbiter(POOL_PAGES, page_size=8, tracer=tracer)
    kw = dict(params=params,
              budget=KVBudget(tier2_bytes=1e9, page_size=8),
              arbiter=arb, tracer=tracer)
    a = Engine.local(model, _cfg(), tenant="a", **kw)
    b = Engine.local(model, _cfg(), tenant="b", **kw)
    import dataclasses
    ta = _heavy(8, seed=1, max_new=16)              # saturates the pool
    tb = [dataclasses.replace(r, arrival_time=1e-4)  # arrives mid-burst
          for r in _heavy(2, seed=2, max_new=4)]
    run_multi_trace([(a, ta), (b, tb)])
    assert arb.revoked_pages > 0, "revocation not exercised"
    rep = sanitize_tracer(tracer)
    assert rep.ok, rep.format()
    assert rep.checks["kv-conservation"] > 0
    assert rep.checks["revocation-attribution"] > 0


def test_corrupted_export_is_rejected(traced_solo):
    # hand-corrupt a real exported trace: conjure one phantom hot page
    doc = to_chrome_trace(traced_solo["tracer"])
    for e in doc["traceEvents"]:
        if e.get("ph") == "C" and e.get("name") == "hot_pages":
            e["args"]["value"] += 1.0
            break
    rep = sanitize_trace_doc(doc)
    assert not rep.ok
    assert any(v.rule == "kv-conservation" for v in rep.violations)


def test_sanitizer_detach_stops_observation():
    tracer = Tracer()
    s = attach(tracer)
    tracer.span("t", "a", 0.0, 1.0)
    s.detach()
    tracer.span("t", "b", 5.0, 1.0)
    rep = s.finish()
    assert rep.events == 1


def test_sanitizer_is_importable_without_jax_side_effects():
    # repro.analysis must stay importable on hosts without the
    # accelerator stack: it may not pull in jax transitively
    import subprocess
    import sys
    code = ("import sys; import repro.analysis; "
            "sys.exit(1 if 'jax' in sys.modules else 0)")
    proc = subprocess.run([sys.executable, "-c", code])
    assert proc.returncode == 0


# ---------------------------------------------------------------------------
# disagg-handoff: transferred-before-use on the KV handoff protocol
# ---------------------------------------------------------------------------

def _handoff_stream(use_ts=3.0, pages=(0, 1), span_pages=2,
                    page_bytes=100.0, span_bytes=200.0, ready=(1.0, 2.0),
                    span_end=None):
    """A minimal well-formed disagg:req0 handoff stream, corruptible
    via the kwargs: pages first, then the stream span, then the use."""
    evs = [_instant("disagg:req0", "handoff_page", 0.1 * (i + 1),
                    cat=CAT_KV, page=p, bytes=page_bytes,
                    ready_ts=ready[i]) for i, p in enumerate(pages)]
    end = max(ready) if span_end is None else span_end
    evs.append(_span("disagg:req0", "handoff", 0.1,
                     end - 0.1, cat=CAT_KV, pages=span_pages,
                     bytes=span_bytes))
    evs.append(_instant("disagg:req0", "handoff_use", use_ts,
                        cat=CAT_KV, pages=span_pages))
    return evs


def test_disagg_handoff_clean_stream_passes():
    rep = sanitize_events(_handoff_stream())
    assert rep.ok, rep.format()
    assert rep.checks["disagg-handoff"] == 4   # 2 pages + span + use


def test_disagg_handoff_rejects_use_before_transfer():
    # the stream span lies (claims it ended at 1.2s) so the track stays
    # monotone, but page 1's own ready_ts says it landed at 2.0s —
    # decode at 1.5s consumed a page that was still on the fabric
    v = _only(sanitize_events(_handoff_stream(use_ts=1.5, span_end=1.2)),
              "disagg-handoff")
    assert "page 1 decoded before its transfer completed" in v.message


def test_disagg_handoff_rejects_missing_page():
    evs = [e for e in _handoff_stream()
           if not (e.name == "handoff_page" and e.args["page"] == 1)]
    rep = sanitize_events(evs)
    assert not rep.ok
    # the dropped page trips both the page-set and the byte agreement
    assert all(v.rule == "disagg-handoff" for v in rep.violations)
    assert any("1 of 2 announced page(s)" in v.message
               for v in rep.violations), rep.format()


def test_disagg_handoff_rejects_duplicate_page():
    rep = sanitize_events(_handoff_stream(pages=(0, 0)))
    assert not rep.ok
    assert all(v.rule == "disagg-handoff" for v in rep.violations)
    assert any("transferred twice" in v.message
               for v in rep.violations), rep.format()


def test_disagg_handoff_rejects_byte_disagreement():
    v = _only(sanitize_events(_handoff_stream(span_bytes=250.0)),
              "disagg-handoff")
    assert "announced 250B" in v.message


def test_disagg_handoff_rejects_use_without_span():
    evs = [_instant("disagg:req0", "handoff_use", 3.0, cat=CAT_KV,
                    pages=1)]
    v = _only(sanitize_events(evs), "disagg-handoff")
    assert "no handoff span" in v.message


def test_disagg_handoff_rejects_page_after_use():
    evs = _handoff_stream() + [
        _instant("disagg:req0", "handoff_page", 4.0, cat=CAT_KV,
                 page=2, bytes=100.0, ready_ts=4.0)]
    rep = sanitize_events(evs)
    assert any("after the request's first decode" in v.message
               for v in rep.violations), rep.format()


def test_disagg_handoff_unused_stream_is_a_note_not_a_violation():
    evs = [e for e in _handoff_stream() if e.name != "handoff_use"]
    rep = sanitize_events(evs)
    assert rep.ok
    assert any("streamed but never used" in n for n in rep.notes)

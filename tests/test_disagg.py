"""repro.disagg validation: the degenerate cluster replays the
colocated engine bit-for-bit (tokens, clocks AND trace events), routed
handoffs over both stagings preserve tokens while gating decode on KV
arrival (stalled-KV correctness), the router's predicted-transit and
queue-depth fallbacks colocate, partial-arrival admission changes no
tokens, the handoff event protocol sanitizes clean under the
``disagg-handoff`` rule, and the whole cluster loop is bit-identical
under tiebreak perturbation (racecheck)."""

import jax
import pytest

from repro.analysis import racecheck, sanitize_tracer
from repro.configs import SMOKE_ARCHS
from repro.core import fabric as fb
from repro.disagg import (DisaggCluster, DisaggConfig, PrefillWorker,
                          decode_load, pick_decode_engine)
from repro.fabric import Topology, Transport
from repro.models.api import build_model
from repro.obs import Tracer
from repro.serve import Engine, EngineConfig, burst_trace, run_trace

VOCAB = SMOKE_ARCHS["qwen1.5-0.5b"].vocab


@pytest.fixture(scope="module")
def model():
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"].__class__(**{
        **SMOKE_ARCHS["qwen1.5-0.5b"].__dict__, "compute_dtype": "float32"})
    return build_model(cfg)


@pytest.fixture(scope="module")
def params(model):
    return model.init(jax.random.PRNGKey(0))


def _cfg(**kw):
    base = dict(max_slots=3, max_seq=64, page_size=8)
    base.update(kw)
    return EngineConfig(**base)


def _trace(n=6, prompt=12, new=6, seed=0):
    return burst_trace(n, prompt_len=prompt, max_new_tokens=new,
                       vocab=VOCAB, seed=seed)


def _topology(*, bw=200.0):
    """One leaf switch, two pods, one tier-2 memory node."""
    topo = Topology("disagg-test")
    topo.add_node("leaf", "switch")
    for p in (0, 1):
        topo.add_node(f"pod:{p}", "pod")
        topo.connect(f"pod:{p}", "leaf", fb.CXL3, capacity=bw, latency=1e-4)
    topo.add_node("mem:0", "memory")
    topo.connect("mem:0", "leaf", fb.CXL_CAPACITY, capacity=2 * bw,
                 latency=1e-4)
    return topo


def _routed_cluster(model, params, *, staging="direct", bw=200.0,
                    tracer=None, config=None, tenant="t0"):
    topo = _topology(bw=bw)
    tracer = tracer if tracer is not None else Tracer()
    tx = Transport(topo, tracer=tracer)
    pw = PrefillWorker(
        Engine.local(model, _cfg(), params=params, tracer=tracer), name="p0")
    de = Engine.local(model, _cfg(), params=params, tracer=tracer)
    kw = dict(transport=tx, route=topo.route("pod:0", "pod:1"),
              config=config or DisaggConfig(staging=staging))
    if (config.staging if config else staging) == "tier2":
        kw["stage_in"] = topo.route("pod:0", "mem:0")
        kw["stage_out"] = topo.route("mem:0", "pod:1")
    return DisaggCluster([pw], [de], tenant=tenant, **kw), tx


# ---------------------------------------------------------------------------
# degenerate mode: the correctness anchor
# ---------------------------------------------------------------------------

def test_degenerate_cluster_replays_engine_bit_for_bit(model, params):
    """route=None + one decode engine: the cluster's run loop must be
    indistinguishable from ``run_trace(Engine)`` — same tokens, same
    event clocks, same trace events in the same order, even with an
    (idle) prefill worker attached."""
    trace = _trace()
    tr_a, tr_b = Tracer(), Tracer()
    plain = run_trace(Engine.local(model, _cfg(), params=params,
                                   tracer=tr_a), trace)
    idle_worker = PrefillWorker(
        Engine.local(model, _cfg(), params=params), name="idle")
    cl = DisaggCluster([idle_worker],
                       [Engine.local(model, _cfg(), params=params,
                                     tracer=tr_b)])
    assert cl.degenerate
    got = cl.run(trace)
    assert [h.tokens for h in got] == [h.tokens for h in plain]
    assert [(h.submit_clock, h.first_token_clock, h.done_clock)
            for h in got] == \
        [(h.submit_clock, h.first_token_clock, h.done_clock)
         for h in plain]
    assert [(e.ph, e.track, e.name, e.ts, e.dur, e.args)
            for e in tr_b.events()] == \
        [(e.ph, e.track, e.name, e.ts, e.dur, e.args)
         for e in tr_a.events()]
    assert cl.handoffs == 0 and cl.colocated == len(trace)
    assert idle_worker.prefilled == 0


# ---------------------------------------------------------------------------
# routed handoff: token fidelity + stalled-KV gating
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("staging", ["direct", "tier2"])
def test_routed_handoff_tokens_identical(model, params, staging):
    """Disaggregated prefill -> fabric -> decode produces the exact
    colocated token stream, for direct pod->pod transfers and for
    write+read staging through the tier-2 memory node."""
    trace = _trace()
    want = [h.tokens for h in
            run_trace(Engine.local(model, _cfg(), params=params), trace)]
    cl, tx = _routed_cluster(model, params, staging=staging)
    got = cl.run(trace)
    assert [h.tokens for h in got] == want
    assert cl.handoffs == len(trace) and cl.colocated == 0
    assert all(h.kv_transit_s >= 0.0 for h in got)
    # every page rode the fabric under the kv: label class
    kvb = tx.link_label_bytes
    assert any("kv:t0" in labels for labels in kvb.values()), kvb


def test_stalled_kv_gates_decode(model, params):
    """A slow trunk stalls the handoff: decode must not consume a row
    before its last page lands (done >= first_token + transit), and
    the tokens still match the colocated run exactly."""
    trace = _trace(n=4)
    want = [h.tokens for h in
            run_trace(Engine.local(model, _cfg(), params=params), trace)]
    # ~3 pages/s of page_bytes: transfers far slower than prefill
    cl, _ = _routed_cluster(model, params, bw=3 * 16384.0)
    got = cl.run(trace)
    assert [h.tokens for h in got] == want
    assert all(h.kv_transit_s > 0.0 for h in got)
    for h in got:
        # first_token_clock is the prefill tier's emit; the decode side
        # waited out the full KV transit before producing token 2
        assert h.done_clock >= h.first_token_clock + h.kv_transit_s


def test_partial_arrival_admission_changes_no_tokens(model, params):
    """min_ready_pages=1 admits a row as soon as its first page lands
    (early slot reservation) but decode still waits for the last page:
    tokens are identical to gate-on-all admission."""
    trace = _trace(n=4)
    full = _routed_cluster(model, params, bw=3 * 16384.0,
                           config=DisaggConfig(staging="direct"))[0]
    early = _routed_cluster(model, params, bw=3 * 16384.0,
                            config=DisaggConfig(staging="direct",
                                                min_ready_pages=1))[0]
    toks_full = [h.tokens for h in full.run(trace)]
    toks_early = [h.tokens for h in early.run(trace)]
    assert toks_early == toks_full


# ---------------------------------------------------------------------------
# router fallbacks
# ---------------------------------------------------------------------------

def test_router_colocates_when_transit_exceeds_budget(model, params):
    trace = _trace()
    want = [h.tokens for h in
            run_trace(Engine.local(model, _cfg(), params=params), trace)]
    cl, _ = _routed_cluster(model, params,
                            config=DisaggConfig(max_transit_s=0.0))
    got = cl.run(trace)
    assert cl.colocated == len(trace) and cl.handoffs == 0
    assert [h.tokens for h in got] == want
    assert all(h.kv_transit_s == 0.0 for h in got)


def test_router_colocates_when_prefill_tier_saturated(model, params):
    """max_prefill_depth=0 declares the prefill tier permanently full:
    every request falls back to the decode pod's colocated path."""
    trace = _trace(n=4)
    cl, _ = _routed_cluster(model, params,
                            config=DisaggConfig(max_prefill_depth=0))
    got = cl.run(trace)
    assert cl.colocated == len(trace) and cl.handoffs == 0
    assert [h.tokens for h in got] == \
        [h.tokens for h in
         run_trace(Engine.local(model, _cfg(), params=params), trace)]


def test_predict_transit_direct_matches_route_model(model, params):
    cl, _ = _routed_cluster(model, params)
    req = _trace(n=1)[0]
    eng = cl.decode_engines[0]
    n_pages = -(-req.prompt_len // eng.cfg.page_size)
    want = cl.route.transfer_time(n_pages * eng.kv.page_bytes)
    assert cl.predict_transit(req) == pytest.approx(want)


def test_decode_load_counts_all_occupancy(model, params):
    eng = Engine.local(model, _cfg(), params=params)
    assert decode_load(eng) == 0
    from repro.serve import Request
    eng.submit(Request((1, 2, 3), 2))
    assert decode_load(eng) == 1
    assert pick_decode_engine(
        [eng, Engine.local(model, _cfg(), params=params)]) == 1


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="staging"):
        DisaggConfig(staging="bounce")
    with pytest.raises(ValueError, match="min_ready_pages"):
        DisaggConfig(min_ready_pages=0)


def test_cluster_construction_validation(model, params):
    eng = Engine.local(model, _cfg(), params=params)
    with pytest.raises(ValueError, match="decode engine"):
        DisaggCluster([], [])
    with pytest.raises(ValueError, match="transport"):
        DisaggCluster([], [eng],
                      route=_topology().route("pod:0", "pod:1"))
    with pytest.raises(ValueError, match="stage"):
        topo = _topology()
        DisaggCluster([], [eng], transport=Transport(topo),
                      route=topo.route("pod:0", "pod:1"),
                      config=DisaggConfig(staging="tier2"))


# ---------------------------------------------------------------------------
# observability + determinism
# ---------------------------------------------------------------------------

def test_handoff_events_sanitize_clean(model, params):
    """The per-request handoff protocol (pages -> stream span -> use)
    passes the full sanitizer, and the disagg-handoff rule actually
    checked something (transferred-before-use, page set, bytes)."""
    tr = Tracer()
    cl, tx = _routed_cluster(model, params, bw=3 * 16384.0, tracer=tr)
    cl.run(_trace(n=4))
    tx.quiesce()
    rep = sanitize_tracer(tr)
    assert rep.ok, rep.format()
    assert rep.checks["disagg-handoff"] > 0


def test_cluster_bit_identical_under_perturbation(model, params):
    """racecheck: perturbing every tie-break seam (candidate selection,
    engine picks) must not change tokens, clocks, transit, or the
    emitted trace — the cluster loop is order-independent."""
    trace = _trace(n=4)

    def scenario(tracer):
        topo = _topology(bw=3 * 16384.0)
        tx = Transport(topo, tracer=tracer)
        pw = PrefillWorker(Engine.local(model, _cfg(), params=params,
                                        tracer=tracer), name="p0")
        de = Engine.local(model, _cfg(), params=params, tracer=tracer)
        cl = DisaggCluster([pw], [de], transport=tx,
                           route=topo.route("pod:0", "pod:1"),
                           tenant="t0",
                           config=DisaggConfig(min_ready_pages=1))
        handles = cl.run(trace)
        tx.quiesce()
        return {
            "tokens": [h.tokens for h in handles],
            "clocks": [(h.submit_clock, h.first_token_clock, h.done_clock)
                       for h in handles],
            "transit": [h.kv_transit_s for h in handles],
            "handoffs": cl.handoffs,
        }

    racecheck(scenario, seeds=(1, 2), label="disagg", check=True)

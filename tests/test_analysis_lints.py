"""``repro.analysis.lints`` — the AST rule framework.

Each rule gets a good/bad source pair exercised through a synthetic
``src/repro/...`` tree (the wallclock rule is path-scoped, so fixture
placement matters), plus the suppression annotation, the CLI entry
point, and the headline guarantee: the real ``src/repro`` tree lints
clean under the full rule set.
"""

import textwrap
from pathlib import Path

import pytest

from repro.analysis.lints import (RULES, LintViolation, iter_py_files,
                                  lint_file, lint_paths, main,
                                  suppressed_lines)

REPO = Path(__file__).resolve().parent.parent


def _write(tmp_path: Path, rel: str, source: str) -> Path:
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return p


def _rules_hit(tmp_path, rel, source):
    return {v.rule for v in lint_file(_write(tmp_path, rel, source))}


# ---------------------------------------------------------------------------
# no-bare-print
# ---------------------------------------------------------------------------

def test_bare_print_flagged(tmp_path):
    hits = _rules_hit(tmp_path, "src/repro/models/m.py",
                      'print("hello")\n')
    assert "no-bare-print" in hits


def test_console_and_method_prints_clean(tmp_path):
    src = """\
        from repro.obs.console import emit
        emit("hello")
        logger.print("method calls are not bare print")
    """
    assert "no-bare-print" not in _rules_hit(
        tmp_path, "src/repro/models/m.py", src)


# ---------------------------------------------------------------------------
# no-wallclock
# ---------------------------------------------------------------------------

def test_wallclock_call_flagged_in_modeled_time_dir(tmp_path):
    src = """\
        import time
        t0 = time.time()
        t1 = time.perf_counter()
    """
    vs = lint_file(_write(tmp_path, "src/repro/serve/engine2.py", src))
    assert sum(v.rule == "no-wallclock" for v in vs) == 2


def test_wallclock_unscoped_outside_modeled_time_dirs(tmp_path):
    # same source, but models/ is host-side code: rule does not apply
    src = "import time\nt0 = time.time()\n"
    assert "no-wallclock" not in _rules_hit(
        tmp_path, "src/repro/models/host.py", src)


def test_wallclock_from_import_flagged(tmp_path):
    src = "from time import perf_counter\n"
    assert "no-wallclock" in _rules_hit(
        tmp_path, "src/repro/fabric/t.py", src)


def test_ambient_rng_flagged_seeded_generators_allowed(tmp_path):
    src = """\
        import random
        import numpy as np
        x = random.random()              # ambient state: flagged
        np.random.seed(0)                # global mutation: flagged
        rng = random.Random(42)          # seeded generator: fine
        rs = np.random.RandomState(7)    # seeded generator: fine
        bad = random.Random()            # unseeded generator: flagged
        k = jax.random.PRNGKey(0)        # keyed, never ambient: fine
    """
    vs = lint_file(_write(tmp_path, "src/repro/pool/r.py", src))
    wall = [v for v in vs if v.rule == "no-wallclock"]
    assert len(wall) == 3
    assert {v.line for v in wall} == {3, 4, 7}


# ---------------------------------------------------------------------------
# compat-imports
# ---------------------------------------------------------------------------

def test_drifted_jax_import_flagged(tmp_path):
    src = "from jax.experimental.shard_map import shard_map\n"
    assert "compat-imports" in _rules_hit(
        tmp_path, "src/repro/models/shard.py", src)


def test_cost_analysis_must_go_through_compat(tmp_path):
    src = """\
        from repro.core import compat
        a = compiled.cost_analysis()
        b = compat.cost_analysis(compiled)
    """
    vs = lint_file(_write(tmp_path, "src/repro/models/c.py", src))
    compat = [v for v in vs if v.rule == "compat-imports"]
    assert [v.line for v in compat] == [2]


def test_compat_module_itself_is_exempt(tmp_path):
    src = "from jax.experimental.shard_map import shard_map\n"
    assert "compat-imports" not in _rules_hit(
        tmp_path, "src/repro/core/compat.py", src)


# ---------------------------------------------------------------------------
# no-mutable-default
# ---------------------------------------------------------------------------

def test_mutable_function_defaults_flagged(tmp_path):
    src = """\
        def f(xs=[], *, opts={}):
            return xs, opts

        def g(xs=None, *, opts=()):
            return xs, opts
    """
    vs = lint_file(_write(tmp_path, "src/repro/models/d.py", src))
    assert sum(v.rule == "no-mutable-default" for v in vs) == 2


def test_mutable_dataclass_field_flagged_factory_clean(tmp_path):
    src = """\
        import dataclasses

        @dataclasses.dataclass
        class Cfg:
            xs: list = []
            ys: list = dataclasses.field(default_factory=list)

        class NotADataclass:
            xs = []              # plain class attr: out of scope
    """
    vs = lint_file(_write(tmp_path, "src/repro/models/e.py", src))
    bad = [v for v in vs if v.rule == "no-mutable-default"]
    assert [v.line for v in bad] == [5]


# ---------------------------------------------------------------------------
# no-unordered-iteration
# ---------------------------------------------------------------------------

def test_unordered_iteration_flagged_in_decision_files(tmp_path):
    src = """\
        for k, v in queue.items():
            admit(k, v)
        winners = [j for j in jobs.values()]
        losers = {x for x in set(names)}
    """
    vs = lint_file(_write(tmp_path, "src/repro/pool/scheduler.py", src))
    bad = [v for v in vs if v.rule == "no-unordered-iteration"]
    assert {v.line for v in bad} == {1, 3, 4}


def test_unordered_iteration_sanctioned_forms_clean(tmp_path):
    src = """\
        from repro.analysis import tiebreak
        for k in sorted(queue.items()):
            admit(k)
        for j in tiebreak.order(jobs.values()):
            admit(j)
        names = sorted(j.name for j in jobs.values())
        total = sum(v for v in sizes.values())  # repro: allow(no-unordered-iteration) commutative int sum
    """
    assert "no-unordered-iteration" not in _rules_hit(
        tmp_path, "src/repro/fabric/transport.py", src)


def test_unordered_iteration_scoped_to_decision_paths(tmp_path):
    # the same source elsewhere (no scheduling decisions) is fine
    src = "for k in queue.items():\n    admit(k)\n"
    assert "no-unordered-iteration" not in _rules_hit(
        tmp_path, "src/repro/models/m.py", src)


# ---------------------------------------------------------------------------
# no-float-equality
# ---------------------------------------------------------------------------

def test_float_equality_on_modeled_time_flagged(tmp_path):
    src = """\
        if eng.clock == before:
            pass
        done = t_req != deadline
        ok = arrival_time == 0.0
    """
    vs = lint_file(_write(tmp_path, "src/repro/serve/e.py", src))
    bad = [v for v in vs if v.rule == "no-float-equality"]
    assert {v.line for v in bad} == {1, 3, 4}


def test_float_equality_tolerance_and_non_time_clean(tmp_path):
    src = """\
        if abs(eng.clock - before) < 1e-9:
            pass
        if name == "decode":                 # not a time identifier
            pass
        if count != 3:
            pass
        moved = eng.clock != before  # repro: allow(no-float-equality) progress probe, not a time compare
    """
    assert "no-float-equality" not in _rules_hit(
        tmp_path, "src/repro/colo/d.py", src)


def test_float_equality_scoped_to_modeled_time_dirs(tmp_path):
    src = "ok = t0 == t1\n"
    assert "no-float-equality" not in _rules_hit(
        tmp_path, "src/repro/models/host.py", src)
    assert "no-float-equality" in _rules_hit(
        tmp_path, "src/repro/pool/p.py", src)


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_allow_annotation_suppresses_named_rule(tmp_path):
    src = ('print("x")  # repro: allow(no-bare-print) CLI banner\n'
           'print("y")\n')
    vs = lint_file(_write(tmp_path, "src/repro/models/s.py", src))
    assert [v.line for v in vs] == [2]


def test_allow_list_and_wrong_rule(tmp_path):
    src = """\
        import time
        t = time.time()  # repro: allow(no-wallclock, no-bare-print) both
        u = time.time()  # repro: allow(no-bare-print) wrong rule
    """
    vs = lint_file(_write(tmp_path, "src/repro/serve/s.py", src))
    assert [v.line for v in vs] == [3]


def test_suppressed_lines_parser():
    src = ("a = 1\n"
           "b = 2  # repro: allow(rule-a,rule-b)\n"
           "c = 3  # repro:allow( rule-c ) reason text\n")
    assert suppressed_lines(src) == {2: {"rule-a", "rule-b"},
                                     3: {"rule-c"}}


# ---------------------------------------------------------------------------
# framework plumbing + CLI
# ---------------------------------------------------------------------------

def test_syntax_error_is_reported_not_raised(tmp_path):
    vs = lint_file(_write(tmp_path, "src/repro/models/bad.py",
                          "def broken(:\n"))
    assert len(vs) == 1 and vs[0].rule == "syntax"


def test_violation_format_names_path_line_rule():
    v = LintViolation("no-bare-print", "src/repro/x.py", 7, "msg")
    assert v.format() == "src/repro/x.py:7: no-bare-print: msg"


def test_iter_py_files_accepts_files_and_trees(tmp_path):
    a = _write(tmp_path, "src/repro/a.py", "x = 1\n")
    b = _write(tmp_path, "src/repro/sub/b.py", "y = 2\n")
    _write(tmp_path, "src/repro/sub/notes.txt", "not python\n")
    assert list(iter_py_files([a])) == [a]
    assert set(iter_py_files([tmp_path])) == {a, b}


def test_cli_exit_codes(tmp_path, capsys):
    bad = _write(tmp_path, "src/repro/serve/cli.py",
                 'import time\nprint(time.time())\n')
    assert main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "no-bare-print" in out and "no-wallclock" in out
    good = _write(tmp_path, "src/repro/serve/ok.py", "x = 1\n")
    assert main([str(good)]) == 0
    # --rule filters down to one rule; unknown names are a usage error
    assert main(["--rule", "no-wallclock", str(bad)]) == 1
    assert "no-bare-print" not in capsys.readouterr().out
    assert main(["--rule", "nope", str(bad)]) == 2
    assert main(["--list-rules"]) == 0


def test_rule_registry_matches_issue_contract():
    names = {r.name for r in RULES}
    assert {"no-bare-print", "no-wallclock", "compat-imports",
            "no-mutable-default", "no-unordered-iteration",
            "no-float-equality"} <= names


# ---------------------------------------------------------------------------
# the headline guarantee
# ---------------------------------------------------------------------------

def test_real_src_repro_lints_clean():
    vs = lint_paths([REPO / "src" / "repro"])
    assert vs == [], "\n".join(v.format() for v in vs)

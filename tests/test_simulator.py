"""Leg-A validation: the fabric/cost model reproduces the paper's own
evaluation numbers (Fig 6 / Fig 7), plus property tests on the model's
invariants."""

import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import costmodel as cm
from repro.core import fabric as fb
from repro.core import simulator as sim


# ---------------------------------------------------------------------------
# paper-claim bands
# ---------------------------------------------------------------------------

def test_fig6_paper_claims():
    s = sim.fig6_summary(sim.run_fig6())
    assert s["avg_speedup"] == pytest.approx(1.22, rel=0.05)
    assert s["max_speedup"] == pytest.approx(1.84, rel=0.05)
    assert s["avg_comm_inter_speedup"] == pytest.approx(3.79, rel=0.20)


def test_fig7_paper_claims():
    s = sim.fig7_summary(sim.run_fig7())
    assert s["speedup_beyond_accel"] == pytest.approx(1.4, rel=0.08)
    assert s["speedup_beyond_cluster"] == pytest.approx(4.5, rel=0.08)
    assert s["speedup_vs_accel_clusters"] == pytest.approx(1.6, rel=0.08)


def test_fig6_speedup_is_from_communication():
    """Breakdown analysis (paper: gains 'predominantly result from reduced
    communication time'): compute must be identical across systems."""
    for r in sim.run_fig6():
        assert r.baseline.compute == pytest.approx(r.scalepool.compute)
        assert r.baseline.comm_inter_raw > r.scalepool.comm_inter_raw


# ---------------------------------------------------------------------------
# property tests: fabric/cost-model invariants
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=40)
@given(nbytes=st.integers(1, 1 << 32), n=st.integers(2, 512))
def test_allreduce_at_most_ring_and_tree(nbytes, n):
    f = fb.infiniband_fabric(1024)
    t = cm.allreduce_time(f, nbytes, n)
    assert t <= cm.ring_allreduce_time(f, nbytes, n) + 1e-12
    assert t <= cm.tree_allreduce_time(f, nbytes, n) + 1e-12
    assert t > 0


@settings(deadline=None, max_examples=40)
@given(nbytes=st.integers(1, 1 << 30))
def test_transfer_time_monotone_in_bytes(nbytes):
    f = fb.cxl_fabric(1024)
    assert f.transfer_time(nbytes) <= f.transfer_time(nbytes * 2) + 1e-15
    assert f.transfer_time(nbytes) >= f.latency()


@settings(deadline=None, max_examples=20)
@given(n_endpoints=st.sampled_from([16, 64, 256, 1024, 4096]))
def test_latency_monotone_in_scale(n_endpoints):
    small = fb.cxl_fabric(n_endpoints)
    big = fb.cxl_fabric(n_endpoints * 4)
    assert big.latency() >= small.latency() - 1e-15


@settings(deadline=None, max_examples=30)
@given(nbytes=st.integers(1 << 10, 1 << 30),
       intra=st.sampled_from([2, 4, 8, 16]),
       groups=st.sampled_from([2, 4, 16, 64]))
def test_hierarchical_beats_flat_on_slow_inter(nbytes, intra, groups):
    """The ScalePool schedule can only help when the inter fabric is the
    bottleneck — which is the paper's setting."""
    dom = cm.HierarchicalDomains(
        intra=fb.xlink_cluster_fabric(72),
        inter=fb.infiniband_fabric(groups * intra),
        intra_size=intra, n_groups=groups)
    hier = cm.hierarchical_allreduce_time(dom, nbytes)
    flat = cm.flat_allreduce_time(dom, nbytes)
    assert hier <= flat * 1.05


def test_queuing_factor_increases_with_load():
    f0 = fb.cxl_fabric(64)
    f9 = fb.dataclasses.replace(f0, load=0.9)
    assert f9.queuing_factor() > f0.queuing_factor() >= 1.0
    assert f9.bandwidth() < f0.bandwidth()


def test_flit_efficiency_accounting():
    # 1 byte still costs a whole flit on the wire
    link = fb.CXL3
    assert link.wire_bytes(1) == link.flit_bytes
    assert link.wire_bytes(link.flit_payload) == link.flit_bytes
    assert link.wire_bytes(link.flit_payload + 1) == 2 * link.flit_bytes


def test_memory_tier_ordering():
    """§5: HBM < tier-1 coherent < tier-2 pool < RDMA-remote latency."""
    calib = sim.Calibration()
    tiered = sim.make_mem_system("tiered", calib)
    base = sim.make_mem_system("baseline", calib)
    hbm, t1, t2 = tiered.tiers
    assert hbm.access_time(4096) < t1.access_time(4096) < t2.access_time(4096)
    assert t2.access_time(4096) < base.tiers[2].access_time(4096)  # vs RDMA


def test_placement_logic():
    # one replica spans exactly one rack -> no PP crossings, 1 replica/rack
    par = sim.ParallelismConfig(tp=8, pp=9, dp=4, global_batch_seqs=64)
    pl = sim.place(par, cluster_size=72)
    assert pl.pp_boundaries_crossing == 0
    assert pl.dp_intra_size == 1
    # replica spans 2 racks -> at least one crossing
    par = sim.ParallelismConfig(tp=8, pp=16, dp=4, global_batch_seqs=64)
    pl = sim.place(par, cluster_size=72)
    assert pl.pp_boundaries_crossing >= 1

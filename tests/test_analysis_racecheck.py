"""The determinism race-detector stack: the ``tiebreak`` perturbation
seam, the ``tracediff`` structural A/B differ, and the ``racecheck``
harness — including mutation tests that inject a real order-dependent
tie-break into the pool scheduler and assert racecheck catches it with
the correct first-divergent-event blame, plus no-false-positive runs
over the scheduler, transport, and multi-tenant serving estates."""

import dataclasses

import pytest

from repro.analysis import (RaceDivergence, diff_events, racecheck,
                            tiebreak)
from repro.analysis.tracediff import diff_trace_files
from repro.core import fabric as fb
from repro.core import simulator as sim
from repro.fabric import Topology, Transport
from repro.obs import JsonlSink, Tracer, events_from_jsonl
from repro.pool import PoolJob, Scheduler, build_inventory

GB = 1e9


# ---------------------------------------------------------------------------
# the tiebreak seam
# ---------------------------------------------------------------------------

def test_tiebreak_inactive_is_identity():
    items = ["c", "a", "b", "d"]
    assert not tiebreak.active()
    out = tiebreak.order(items)
    assert out == items and out is not items     # fresh list, same order
    assert tiebreak.order(iter(items)) == items


def test_tiebreak_perturb_shuffles_deterministically():
    items = list(range(12))
    with tiebreak.perturb(7):
        assert tiebreak.active()
        first = tiebreak.order(items)
    with tiebreak.perturb(7):
        again = tiebreak.order(items)
    assert first == again                        # seeded, reproducible
    assert sorted(first) == items                # a permutation
    with tiebreak.perturb(8):
        other = tiebreak.order(items)
    assert other != first                        # seeds explore orders
    assert not tiebreak.active()                 # context restored


def test_tiebreak_nesting_restores_outer():
    with tiebreak.perturb(1):
        outer = tiebreak.current()
        with tiebreak.perturb(2):
            assert tiebreak.current() is not outer
        assert tiebreak.current() is outer
    assert tiebreak.current() is None


# ---------------------------------------------------------------------------
# tracediff
# ---------------------------------------------------------------------------

def _mk(tracer_fill):
    tr = Tracer()
    tracer_fill(tr)
    return tr.events()


def test_tracediff_identical():
    def fill(tr):
        tr.span("engine:a", "decode", 0.0, 1.0, tokens=3)
        tr.instant("pool:sched", "admit", 2.0, job="x")
    d = diff_events(_mk(fill), _mk(fill))
    assert d.identical and d.first() is None
    assert "identical" in d.format()


def test_tracediff_blames_first_divergent_event_and_fields():
    def a(tr):
        tr.instant("pool:sched", "admit", 1.0, job="x")
        tr.instant("pool:sched", "finish", 2.0, job="x")
    def b(tr):
        tr.instant("pool:sched", "admit", 1.0, job="y")
        tr.instant("pool:sched", "finish", 2.0, job="x")
    d = diff_events(_mk(a), _mk(b))
    assert not d.identical
    first = d.first()
    assert first.track == "pool:sched" and first.index == 0
    assert first.fields == ("args",)
    assert "x" in first.format() and "y" in first.format()


def test_tracediff_length_and_track_mismatches():
    def a(tr):
        tr.instant("t1", "e", 0.0)
        tr.instant("t1", "f", 1.0)
        tr.instant("only_a", "g", 0.5)
    def b(tr):
        tr.instant("t1", "e", 0.0)
        tr.instant("only_b", "h", 0.5)
    d = diff_events(_mk(a), _mk(b))
    assert d.only_a == ["only_a"] and d.only_b == ["only_b"]
    delta = next(x for x in d.divergences if x.track == "t1")
    assert delta.index == 1 and delta.a is not None and delta.b is None


def test_tracediff_clock_and_label_byte_drift():
    def a(tr):
        tr.span("link:sw->mem", "xfer", 0.0, 1.0, cat="link",
                label="serve:a", bytes=100.0)
    def b(tr):
        tr.span("link:sw->mem", "xfer", 0.0, 1.5, cat="link",
                label="serve:a", bytes=160.0)
    d = diff_events(_mk(a), _mk(b))
    assert d.clock_delta["link:sw->mem"] == pytest.approx(0.5)
    assert d.label_bytes_delta["serve:a"] == pytest.approx(60.0)


def test_tracediff_files_jsonl_roundtrip(tmp_path):
    def fill(tr):
        tr.span("engine:a", "prefill", 0.0, 0.5, cat="engine", tokens=8)
        tr.counter("engine:a", "free_pages", 0.5, 3.0)
    pa, pb = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    for p in (pa, pb):
        tr = Tracer()
        with JsonlSink(p, tr):
            fill(tr)
    assert events_from_jsonl(pa) == _mk(fill)
    assert diff_trace_files(pa, pb).identical


# ---------------------------------------------------------------------------
# racecheck harness semantics
# ---------------------------------------------------------------------------

def test_racecheck_passes_canonicalized_scenario():
    def good(tracer):
        items = {"b": 2.0, "a": 1.0, "c": 3.0}
        for i, (k, v) in enumerate(sorted(tiebreak.order(items.items()))):
            tracer.instant("t", k, float(i), v=v)
        return {"n": len(items)}
    rep = racecheck(good, seeds=(1, 2, 3), label="good")
    assert rep.ok and rep.baseline_events == 3
    assert "OK (bit-identical)" in rep.format()
    rep.check()                                  # must not raise


def test_racecheck_catches_order_dependence_with_blame():
    def bad(tracer):
        order = tiebreak.order({"b": 2.0, "a": 1.0, "c": 3.0}.items())
        for i, (k, v) in enumerate(order):       # no canonical sort!
            tracer.instant("t", k, float(i), v=v)
        return {"first": order[0][0]}
    rep = racecheck(bad, seeds=(1, 2, 3), label="bad")
    assert not rep.ok and rep.divergent
    first = rep.divergent[0].trace_diff.first()
    assert first.track == "t" and first.index == 0
    assert any("first" in d for d in rep.divergent[0].outcome_diffs)
    with pytest.raises(RaceDivergence, match="DIVERGED"):
        racecheck(bad, seeds=(1,), check=True)


def test_racecheck_rejects_nested_and_non_mapping():
    with tiebreak.perturb(1):
        with pytest.raises(RuntimeError, match="inside"):
            racecheck(lambda tr: {}, seeds=(1,))
    with pytest.raises(TypeError, match="Mapping"):
        racecheck(lambda tr: [1, 2], seeds=(1,))


# ---------------------------------------------------------------------------
# real-estate no-false-positive runs (jax-free paths)
# ---------------------------------------------------------------------------

def _inventory():
    return build_inventory(n_pods=4, pod_size=8, hbm_per_accel_gb=192.0,
                           n_memory_nodes=2, memory_node_gb=1024.0,
                           interconnect="scalepool")


PAR = sim.ParallelismConfig(tp=2, pp=1, dp=3, global_batch_seqs=66)


def _sched_scenario(tracer):
    """DRF queueing, a staggered declared gang, a second user, elastic
    grow and a finish cascade — the decision paths the seam perturbs."""
    sched = Scheduler(_inventory(), queueing="drf", tracer=tracer)
    for i, t in enumerate([0.0, 1.0]):
        sched.submit(PoolJob(f"g{i}", sim.MEGATRON, PAR, n_steps=10,
                             submit_t=t, gang="pair", gang_size=2,
                             user="u"))
    sched.submit(PoolJob("solo", sim.MEGATRON,
                         dataclasses.replace(PAR, dp=2), n_steps=5,
                         submit_t=0.5, user="v"))
    sched.submit(PoolJob("el", sim.MEGATRON,
                         dataclasses.replace(PAR, dp=4), n_steps=6,
                         submit_t=0.5, user="w", elastic=True, min_dp=1))
    res = sched.run()
    return {"summary": res.summary(),
            "trace": list(res.trace),
            "finish": {n: r.finish_t for n, r in res.records.items()}}


def test_racecheck_scheduler_no_false_positive():
    rep = racecheck(_sched_scenario, seeds=(1, 2, 3, 4), label="sched")
    assert rep.ok, rep.format()
    assert rep.baseline_events > 10


def _transport_scenario(tracer):
    """Concurrent transfers fair-sharing one trunk: water-filling
    re-rates, drain order, and per-flow accounting under the seam."""
    topo = Topology("rc")
    for e in ("a", "b", "c"):
        topo.add_node(e)
    topo.add_node("sw", "switch")
    topo.add_node("mem", "memory")
    for e in ("a", "b", "c"):
        topo.connect(e, "sw", fb.CXL3, capacity=8 * GB, latency=1e-6)
    topo.connect("sw", "mem", fb.CXL_CAPACITY, capacity=1 * GB,
                 latency=1e-6)
    tx = Transport(topo, tracer=tracer)
    routes = {e: topo.route(e, "mem") for e in ("a", "b", "c")}
    done = {}
    # overlapping, staggered, different sizes: every re-rate has >1
    # live flow and the finish order interleaves sources
    for i, (src, nbytes, t0) in enumerate([
            ("a", 512e6, 0.0), ("b", 256e6, 0.1), ("c", 768e6, 0.2),
            ("a", 128e6, 0.3), ("b", 512e6, 0.35), ("c", 64e6, 0.4)]):
        done[f"{src}#{i}"] = tx.transfer_s(routes[src], nbytes, t0,
                                           label=f"serve:{src}")
    tx.quiesce()
    return {"done": done, "stats": tx.stats()}


def test_racecheck_transport_no_false_positive():
    rep = racecheck(_transport_scenario, seeds=(1, 2, 3, 4),
                    label="transport")
    assert rep.ok, rep.format()


# ---------------------------------------------------------------------------
# mutation: inject a real order-dependent tie-break, racecheck must
# catch it and blame the right event
# ---------------------------------------------------------------------------

def _fifo_scenario(tracer):
    """Scarce pool + same-timestamp submissions: FIFO admission order
    decides who runs first, so corrupting it changes the trace."""
    sched = Scheduler(_inventory(), tracer=tracer)
    for i in range(6):
        sched.submit(PoolJob(f"j{i}", sim.MEGATRON, PAR, n_steps=8,
                             submit_t=0.0))
    res = sched.run()
    return {"summary": res.summary(),
            "finish": {n: r.finish_t for n, r in res.records.items()}}


def test_mutation_unordered_admission_is_caught(monkeypatch):
    """Replace the scheduler's FIFO admission scan with incidental
    enumeration order (the classic 'iterate the dict instead of the
    spec'd queue' refactor bug).  Unmutated the scenario is
    bit-identical under the seam; mutated, racecheck must diverge and
    blame the first wrong admission on the pool:sched track."""
    rep = racecheck(_fifo_scenario, seeds=(1, 2), label="pre-mutation")
    assert rep.ok, rep.format()

    orig = Scheduler._gang_groups
    monkeypatch.setattr(
        Scheduler, "_gang_groups",
        lambda self: tiebreak.order(orig(self)))
    rep = racecheck(_fifo_scenario, seeds=(1, 2, 3), label="mutated")
    assert not rep.ok
    bad = rep.divergent[0]
    first = bad.trace_diff.first()
    assert first is not None
    assert first.track == "pool:sched"
    # the earliest divergent event is an admission-order artifact: an
    # admit (or the run/finish cascade of one) naming the wrong job
    assert first.a.name != first.b.name or first.a.args != first.b.args
    assert bad.outcome_diffs                     # outcomes moved too


def test_mutation_float_accumulation_order_is_caught(monkeypatch):
    """Drop the canonical name-sort in the DRF dominant-share
    accumulation: float addition is not associative, so the emitted
    ``drf_share`` counter value depends on dict insertion order.  The
    tier-2 reservations are sized so bytes are the dominant resource
    (the accel dimension is an exact integer ratio in every order) and
    chosen so the three per-user terms provably sum differently under
    permutation."""
    def mutated(self, user):
        caps = (self.inv.total_accels, self.inv.total_tier2,
                self.inv.total_tier2_bw)
        use = [0.0, 0.0, 0.0]
        for run in tiebreak.order(list(self._running.values())):
            if run.job.drf_user != user:         # no canonical sort!
                continue
            use[0] += run.alloc.n_requested
            use[1] += run.job.tier2_bytes
            use[2] += run.job.tier2_bw
        return max(u / c for u, c in zip(use, caps) if c > 0)
    monkeypatch.setattr(Scheduler, "_dominant_share", mutated)

    # 8*(100/3 + 200/7 + 500/11) GB ≈ 859 GB per user: dominant over
    # the 6/32 accel share, within the 2048 GB pool, and the three
    # addends yield two distinct IEEE sums across their permutations
    terms = (8 * 100 / 3 * GB, 8 * 200 / 7 * GB, 8 * 500 / 11 * GB)

    def scenario(tracer):
        sched = Scheduler(_inventory(), queueing="drf", tracer=tracer)
        for i in range(6):
            sched.submit(PoolJob(
                f"j{i}", sim.MEGATRON, dataclasses.replace(PAR, dp=1),
                n_steps=4 + i, submit_t=0.0, user=f"u{i % 2}",
                tier2_bytes=terms[i // 2]))
        res = sched.run()
        return {"summary": res.summary()}

    rep = racecheck(scenario, seeds=(1, 2, 3, 4), label="drf-mutated")
    # the mutation must NOT survive: at least one seed's drf_share
    # counter carries a different ulp of the same "equal" share
    assert not rep.ok, "non-associative accumulation went undetected"
    first = rep.divergent[0].trace_diff.first()
    assert first.track == "pool:sched"
    assert first.a.name.startswith("drf_share:")

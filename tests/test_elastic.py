"""Elastic scaling: a checkpoint written on one mesh restores onto a
different mesh (the ScalePool composability axis)."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=570)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_checkpoint_reshards_across_meshes(tmp_path):
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import checkpoint as C
        from repro.ckpt.elastic import replan, resize_plan
        from repro.sharding.partition import Rules

        # write on a (2,4) mesh, params sharded over both axes
        mesh_a = jax.make_mesh((2, 4), ("data", "model"))
        w = jnp.arange(64.0 * 32).reshape(64, 32)
        wa = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
        C.save("{tmp_path}/ck", {{"w": wa}}, step=5).wait()

        # restore on a (8,) mesh with a different rule table
        mesh_b = jax.make_mesh((8,), ("model",))
        rules = Rules({{"emb": None, "ff": "model"}})
        tree, extra = replan("{tmp_path}/ck", {{"w": w}}, mesh_b, rules,
                             {{"w": ("emb", "ff")}})
        assert extra["step"] == 5
        np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(w))
        assert tree["w"].sharding.spec == P(None, "model")

        # resize planning keeps model parallelism intact
        plan = resize_plan(512, 384, model_parallel=16)
        assert plan["model"] == 16
        assert plan["pods"] * plan["data"] * plan["model"] == 384
        print("OK")
    """)
    assert "OK" in out

"""Substrate tests: optimizer, data pipeline, checkpoint/restore + elastic,
fault-tolerant loop, tiering policy."""

import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKE_ARCHS
from repro.core import tiering
from repro.ckpt import checkpoint as C
from repro.data.pipeline import DataConfig, DataPipeline, PipelineState
from repro.models.api import build_model
from repro.optim.adamw import AdamW
from repro.runtime.ft import FaultTolerantLoop, RetryPolicy, StragglerMonitor
from conftest import make_batch


def test_adamw_reduces_loss(rng):
    cfg = SMOKE_ARCHS["olmo-1b"]
    model = build_model(cfg)
    params = model.init(rng)
    opt = AdamW(lr=3e-3)
    state = opt.init(params)
    batch = make_batch(rng, cfg)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, state, gnorm = opt.update(grads, state, params)
        return params, state, loss

    losses = []
    for _ in range(8):
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses
    assert int(state.step) == 8


def test_data_pipeline_determinism_and_resume():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8)
    p1 = DataPipeline(cfg)
    batches = [p1.next_batch() for _ in range(5)]
    # resume from step 3 reproduces batches 3, 4 exactly
    p2 = DataPipeline(cfg, PipelineState(step=3, seed=cfg.seed))
    for i in (3, 4):
        b = p2.next_batch()
        np.testing.assert_array_equal(b["tokens"], batches[i]["tokens"])
    # host sharding: different hosts get different data
    ph = DataPipeline(DataConfig(vocab=1000, seq_len=32, global_batch=8,
                                 n_hosts=2, host_id=1))
    assert not np.array_equal(ph.next_batch()["tokens"],
                              batches[0]["tokens"][:4])
    # labels are next-token shifted
    b = DataPipeline(cfg).next_batch()
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_pipeline_prefetch():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
    p = DataPipeline(cfg).start()
    b0 = p.get()
    b1 = p.get()
    p.stop()
    ref = DataPipeline(cfg)
    np.testing.assert_array_equal(b0["tokens"], ref.next_batch()["tokens"])
    np.testing.assert_array_equal(b1["tokens"], ref.next_batch()["tokens"])


def test_checkpoint_roundtrip(tmp_path, rng):
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"]
    model = build_model(cfg)
    params = model.init(rng)
    opt = AdamW()
    state = opt.init(params)
    tree = {"params": params, "opt_mu": state.mu}

    C.save(tmp_path / "ck", tree, step=7,
           extra={"pipeline": {"step": 7, "seed": 0}}).wait()
    restored, meta = C.restore(tmp_path / "ck", tree)
    assert meta["step"] == 7
    assert meta["pipeline"]["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_detects_corruption(tmp_path, rng):
    tree = {"w": jnp.arange(16.0)}
    C.save(tmp_path / "ck", tree, step=1).wait()
    # corrupt the shard file
    files = [f for f in os.listdir(tmp_path / "ck") if f.endswith(".npy")]
    arr = np.load(tmp_path / "ck" / files[0])
    arr[0] = 999.0
    np.save(tmp_path / "ck" / files[0], arr)
    with pytest.raises(IOError):
        C.restore(tmp_path / "ck", tree)


def test_async_checkpoint(tmp_path):
    tree = {"w": jnp.ones((256, 256))}
    h = C.save(tmp_path / "ck", tree, step=3, asynchronous=True)
    h.wait()
    restored, meta = C.restore(tmp_path / "ck", tree)
    assert meta["step"] == 3


def test_fault_tolerant_loop_recovers(tmp_path, rng):
    """Inject a failure at step 7; the loop must restore from the step-5
    checkpoint and converge to the SAME final state as a failure-free run
    (bitwise determinism of recovery)."""
    cfg = SMOKE_ARCHS["olmo-1b"]
    model = build_model(cfg)
    opt = AdamW(lr=1e-3)
    pipe_cfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)

    @jax.jit
    def train_step(params_state, batch):
        params, ostate = params_state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, ostate, _ = opt.update(grads, ostate, params)
        return (params, ostate), {"loss": loss}

    def run(with_failure: bool):
        params = model.init(rng)
        state = (params, opt.init(params))
        saved = {}

        def save_fn(s, step):
            saved["state"], saved["step"] = s, step

        def restore_fn():
            return saved["state"], saved["step"]

        fired = {"done": False}

        def failure_hook(step):
            if with_failure and step == 7 and not fired["done"]:
                fired["done"] = True
                raise RuntimeError("injected chip failure")

        loop = FaultTolerantLoop(
            train_step, save_fn, restore_fn, DataPipeline(pipe_cfg),
            ckpt_every=5, retry=RetryPolicy(max_retries=0),
            failure_hook=failure_hook)
        final = loop.run(state, 10)
        return final, loop

    clean, _ = run(False)
    recovered, loop = run(True)
    assert loop.restarts == 1
    for a, b in zip(jax.tree.leaves(clean[0]), jax.tree.leaves(recovered[0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0,
                                   rtol=0)


def test_straggler_monitor():
    m = StragglerMonitor(alpha=0.5, threshold=2.0)
    for step in range(5):
        assert not m.observe(step, 1.0)
    assert m.observe(5, 10.0)        # 10x slowdown flagged
    assert m.recommendation() in ("monitor", "evict-and-resize")
    for step in range(6, 9):
        m.observe(step, 10.0)
    assert m.recommendation() == "evict-and-resize"


def test_tiering_policy_traffic():
    pol = tiering.TieringPolicy(offload_optimizer=True)
    rep = tiering.tier_traffic_report(pol, n_params=1e9)
    assert rep["tier2_bytes_per_step"] == pytest.approx(16e9)
    # CPU backend: tier-2 may be unsupported; API must still be safe
    sh = tiering.to_tier2(jax.sharding.SingleDeviceSharding(jax.devices()[0]))
    assert sh is not None


def test_paged_kv_spill_fetch():
    budget = tiering.KVBudget(tier1_pages=4, tier2_bytes=2048.0, page_size=16)
    kv = tiering.PagedKV(budget, page_bytes=512.0)
    kv.alloc("seq0", 2)
    page = {"k": np.full((2, 16, 2, 4), 7.0, np.float32),
            "v": np.zeros((2, 16, 2, 4), np.float32)}
    kv.evict("seq0", 0, page)
    kv.evict("seq0", 1, page)
    assert not kv.is_fully_hot("seq0") and kv.cold_bytes_used == 1024.0
    phys, back = kv.fetch("seq0", 0)
    np.testing.assert_array_equal(back["k"], page["k"])
    assert kv.page_table("seq0")[0] == phys
    kv.fetch("seq0", 1)
    assert kv.is_fully_hot("seq0") and kv.cold_pages_used == 0
    res = kv.residency()
    assert res["spills"] == 2 and res["fetches"] == 2
    assert res["tier1_pages_used"] == 2


def test_kv_budget_pages_and_policy_view():
    b = tiering.KVBudget(tier1_pages=8, tier2_bytes=1e6, page_size=64)
    assert b.pages_for(1) == 1 and b.pages_for(64) == 1
    assert b.pages_for(65) == 2
    assert b.tier2_pages(page_bytes=1e5) == 10
    pol = tiering.TieringPolicy(kv_budget=b)
    assert pol.kv_spill                      # deprecated boolean view
    assert not tiering.TieringPolicy().kv_spill

"""repro.serve validation: engine determinism (same trace -> same tokens
under any arrival interleaving; lease-backed == local construction),
physical-page-pool accounting with page-granular, bit-exact evict/fetch
round trips, token fidelity over scattered (non-contiguous) page
layouts, modeled-clock attribution invariants, bucketed-prefill compile
bounds, and request-level failure semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.core.tiering import KVBudget, KVBudgetExceeded, PagedKV
from repro.models.api import build_model
from repro.serve import (Engine, EngineConfig, Request, RequestStatus,
                         burst_trace, latency_summary, load_trace,
                         run_trace, synthetic_trace)
from repro.serve.api import RequestHandle

VOCAB = SMOKE_ARCHS["qwen1.5-0.5b"].vocab


@pytest.fixture(scope="module")
def model():
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"].__class__(**{
        **SMOKE_ARCHS["qwen1.5-0.5b"].__dict__, "compute_dtype": "float32"})
    return build_model(cfg)


@pytest.fixture(scope="module")
def params(model):
    return model.init(jax.random.PRNGKey(0))


def _cfg(**kw):
    base = dict(max_slots=3, max_seq=64, page_size=8)
    base.update(kw)
    return EngineConfig(**base)


def _trace(n=5, prompt=12, new=6, seed=0):
    return burst_trace(n, prompt_len=prompt, max_new_tokens=new,
                       vocab=VOCAB, seed=seed)


def _check_clock_invariants(handles):
    """Every event clock sits at or after the previous event's."""
    for h in handles:
        if h.first_token_clock is not None:
            assert h.first_token_clock >= h.submit_clock
        if h.done_clock is not None and h.first_token_clock is not None:
            assert h.done_clock >= h.first_token_clock


# ---------------------------------------------------------------------------
# PagedKV: physical pool accounting + page-granular budget enforcement
# ---------------------------------------------------------------------------

def test_paged_kv_budget_enforced():
    kv = PagedKV(KVBudget(tier1_pages=4, tier2_bytes=100.0, page_size=8),
                 page_bytes=50.0)
    pa = kv.alloc("a", 2)
    pb = kv.alloc("b", 2)
    assert sorted(pa + pb) == [0, 1, 2, 3]       # distinct physical pages
    with pytest.raises(KVBudgetExceeded):
        kv.alloc("c", 1)                         # tier-1 pool full
    kv.evict("a", 0, payload={"x": 1})           # 1 page * 50B fits
    kv.evict("a", 1, payload={"x": 2})           # 2 * 50B = the whole budget
    assert kv.hot_free == 2 and kv.cold_bytes_used == 100.0
    assert kv.cold_logicals("a") == [0, 1] and not kv.is_fully_hot("a")
    with pytest.raises(KVBudgetExceeded):
        kv.evict("b", 0, payload={})             # tier-2 budget full
    phys, payload = kv.fetch("a", 0)
    assert payload == {"x": 1} and kv.page_table("a")[0] == phys
    kv.grow("a", 3)                              # 1 free page left: fits
    with pytest.raises(KVBudgetExceeded):
        kv.grow("a", 4)                          # pool exhausted again
    kv.free("a")
    kv.free("b")
    assert kv.hot_pages_used == 0 and kv.cold_pages_used == 0
    assert kv.hot_free == 4


def test_paged_kv_page_round_trip_bit_exact_and_relocated():
    rng = np.random.RandomState(0)
    page = {"k": rng.standard_normal((2, 8, 2, 4)).astype(np.float32),
            "v": np.asarray(jnp.asarray(
                rng.standard_normal((2, 8, 2, 4)), jnp.bfloat16))}
    kv = PagedKV(KVBudget(tier1_pages=4, tier2_bytes=1e9, page_size=8),
                 page_bytes=1024.0)
    kv.alloc("r", 2)
    old_phys = kv.page_table("r")[1]
    kv.evict("r", 1, page)
    kv.alloc("q", 1)                   # steals the freed physical page
    phys, back = kv.fetch("r", 1)      # must land somewhere else
    assert phys != old_phys
    np.testing.assert_array_equal(back["k"], page["k"])
    np.testing.assert_array_equal(back["v"], page["v"])
    assert kv.spills == 1 and kv.fetches == 1


def test_paged_kv_noncontiguous_reuse():
    kv = PagedKV(KVBudget(tier1_pages=4, tier2_bytes=0.0, page_size=8),
                 page_bytes=1.0)
    kv.alloc("a", 1)
    kv.alloc("b", 1)
    kv.free("a")
    phys = kv.alloc("c", 2)            # reuses a's page: non-contiguous
    assert len(set(phys)) == 2         # distinct pages; order unspecified


# ---------------------------------------------------------------------------
# engine: paging under pressure equals the unbudgeted run bit-exactly
# ---------------------------------------------------------------------------

def test_engine_budget_pressure_tokens_bit_exact(model, params):
    """A tier-1 pool tight enough to force page-granular evictions must
    reproduce the unbudgeted run token-for-token: evicted pages round-
    trip bit-exactly and the kernel's output is independent of the
    physical page layout."""
    trace = _trace()
    ref = Engine.local(model, _cfg(), params=params)
    ref_handles = run_trace(ref, trace)

    tight = Engine.local(model, _cfg(), params=params,
                         budget=KVBudget(tier1_pages=6, tier2_bytes=1e9,
                                         page_size=8))
    tight_handles = run_trace(tight, trace)
    stats = tight.stats()
    assert stats["preempt_swaps"] > 0, "budget pressure not exercised"
    assert stats["kv"]["spills"] > 0 and stats["kv"]["fetches"] > 0, \
        "no page actually rode the tier-2 fabric"
    assert [h.tokens for h in tight_handles] == \
        [h.tokens for h in ref_handles]
    _check_clock_invariants(tight_handles)


def test_engine_serves_scattered_pages(model, params):
    """After preemption scatters a request's KV across non-contiguous
    physical pages, its tokens still match the dense-cache greedy
    reference (model.prefill + model.decode, no engine)."""
    prompt = tuple(np.random.RandomState(7).randint(
        1, VOCAB, size=12).tolist())
    new = 8

    # dense reference: contiguous cache, one sequence, greedy argmax
    cache = model.init_cache(1, 64, dtype=jnp.float32)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, cache)
    want = [int(np.argmax(np.asarray(logits)[0, -1]))]
    idx = len(prompt)
    while len(want) < new:
        logits, cache = model.decode(
            params, jnp.asarray([[want[-1]]], jnp.int32), cache,
            jnp.int32(idx))
        want.append(int(np.argmax(np.asarray(logits)[0, -1])))
        idx += 1

    # engine under pressure: competing requests force the probe request
    # to be paused, paged out, and resumed into different physical pages
    eng = Engine.local(model, _cfg(), params=params,
                       budget=KVBudget(tier1_pages=6, tier2_bytes=1e9,
                                       page_size=8))
    probe = eng.submit(Request(prompt, new))
    others = [eng.submit(r) for r in _trace(n=3, prompt=12, new=8, seed=1)]
    scattered = False
    for _ in range(10_000):
        if eng.idle:
            break
        eng.step()
        if eng.kv.holds(probe.rid):
            table = [p for p in eng.kv.page_table(probe.rid)
                     if p is not None]
            if table != sorted(table) or \
                    any(b - a != 1 for a, b in zip(table, table[1:])):
                scattered = True
    assert probe.status is RequestStatus.DONE
    assert eng.kv.fetches > 0, "probe never paged back in"
    assert scattered, "page table stayed contiguous — pressure too soft"
    assert probe.tokens == want
    assert all(o.status is RequestStatus.DONE for o in others)


def test_prefill_page_writes_match_batched_scatter(model, params):
    """Page-granular prefill writes (slice_page -> _write_page, the
    disaggregated-streaming seam) must compose to exactly the old
    batched ``.at[:, idx].set`` scatter: same tokens AND a bit-equal
    physical pool after the run, including partially-filled tail
    pages."""
    import types

    def old_scatter(self, cache, phys, plen):
        ps = self.cfg.page_size
        n_copy = -(-plen // ps)
        idx = jnp.asarray(np.asarray(phys[:n_copy], np.int32))

        def put(pool_leaf, cache_leaf):
            lay = cache_leaf.shape[0]
            tail = tuple(cache_leaf.shape[3:])
            pages = cache_leaf[:, 0].reshape(
                (lay, -1, ps) + tail)[:, :n_copy]
            return pool_leaf.at[:, idx].set(pages.astype(pool_leaf.dtype))

        self._pool = jax.tree.map(put, self._pool, cache)

    trace = _trace(n=3, prompt=12, new=4)     # 12 % 8 != 0: partial page
    paged = Engine.local(model, _cfg(), params=params)
    batched = Engine.local(model, _cfg(), params=params)
    batched._write_prefill_pages = types.MethodType(old_scatter, batched)
    hs_paged = run_trace(paged, trace)
    hs_batched = run_trace(batched, trace)
    assert [h.tokens for h in hs_paged] == [h.tokens for h in hs_batched]
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
        paged._pool, batched._pool)


def test_engine_deterministic_across_arrival_interleavings(model, params):
    """Same requests, different arrival interleavings (burst vs staggered
    vs reversed submission) -> identical per-request tokens."""
    prompts = [tuple(np.random.RandomState(i).randint(
        1, VOCAB, size=10 + 2 * i).tolist()) for i in range(4)]

    def run_with(arrivals, order):
        eng = Engine.local(model, _cfg(), params=params)
        reqs = [Request(prompts[i], 5, arrival_time=arrivals[i])
                for i in range(4)]
        handles = run_trace(eng, [reqs[i] for i in order])
        by_prompt = {h.request.prompt_tokens: h.tokens for h in handles}
        return [by_prompt[p] for p in prompts]

    burst = run_with([0.0] * 4, [0, 1, 2, 3])
    staggered = run_with([0.0, 0.004, 0.008, 0.02], [0, 1, 2, 3])
    shuffled = run_with([0.0] * 4, [2, 0, 3, 1])
    assert burst == staggered == shuffled


def test_engine_lease_and_local_identical(model):
    from repro.pool import smoke_pool
    pool = smoke_pool("scalepool")
    lease = pool.lease("serve-eng", 4, tier2_gb=64, kv_gb=1.0)
    trace = _trace(n=4)
    local = run_trace(Engine.local(model, _cfg()), trace)
    leased = run_trace(Engine.from_lease(model, lease, _cfg()), trace)
    assert [h.tokens for h in local] == [h.tokens for h in leased]


# ---------------------------------------------------------------------------
# engine semantics: recycling, recompute preemption, OOM, stats
# ---------------------------------------------------------------------------

def test_engine_slot_recycling_and_fifo(model, params):
    eng = Engine.local(model, _cfg(max_slots=2), params=params)
    handles = [eng.submit(Request((1 + i,) * 8, 4)) for i in range(5)]
    eng.run_until_idle()
    assert all(h.status is RequestStatus.DONE for h in handles)
    assert all(len(h.tokens) == 4 for h in handles)
    # FIFO: a request never starts before an earlier one with 2 slots
    firsts = [h.first_token_clock for h in handles]
    assert firsts == sorted(firsts)
    assert eng.stats()["completed"] == 5
    assert eng.kv.hot_pages_used == 0       # everything freed
    _check_clock_invariants(handles)


def test_engine_recompute_preemption_still_completes(model, params):
    """Tier-1-only pressure cannot spill pages: victims drop their KV
    and re-prefill; every request still completes with its full budget."""
    trace = _trace(n=5, prompt=12, new=8)
    eng = Engine.local(model, _cfg(), params=params,
                       budget=KVBudget(tier1_pages=6, tier2_bytes=0.0,
                                       page_size=8))
    handles = run_trace(eng, trace)
    stats = eng.stats()
    assert stats["preempt_recomputes"] > 0
    assert stats["kv"]["spills"] == 0       # nowhere to spill to
    assert stats["failed_oom"] == 0
    assert all(len(h.tokens) == 8 for h in handles)


def test_engine_oom_when_request_can_never_fit(model, params):
    eng = Engine.local(model, _cfg(), params=params,
                       budget=KVBudget(tier1_pages=2, tier2_bytes=1e9,
                                       page_size=8))
    ok = eng.submit(Request((1, 2, 3), 4))            # 1 page: fits
    too_big = eng.submit(Request((5,) * 30, 20))      # 7 pages > quota
    eng.run_until_idle()
    assert ok.status is RequestStatus.DONE
    assert too_big.status is RequestStatus.FAILED_OOM
    with pytest.raises(RuntimeError, match="quota"):
        too_big.result()


def test_engine_submit_validates_capacity_and_vocab(model, params):
    eng = Engine.local(model, _cfg(), params=params)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request((1,) * 60, 10))
    # out-of-range ids would be clamped by JAX's OOB gather: reject loudly
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(Request((1, VOCAB, 2), 4))
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(Request((1, -3, 2), 4))


def test_load_trace_validates_vocab(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text('{"prompt_tokens": [1, 2], "max_new_tokens": 4}\n'
                 '{"prompt_tokens": [1, %d], "max_new_tokens": 4}\n' % VOCAB)
    assert len(load_trace(str(p))) == 2              # unvalidated: loads
    with pytest.raises(ValueError, match="trace.jsonl:2"):
        load_trace(str(p), vocab=VOCAB)


def test_engine_stats_and_latency_summary(model, params):
    eng = Engine.local(model, _cfg(), params=params)
    trace = synthetic_trace(4, mean_interarrival_s=0.001,
                            prompt_lens=(8, 16), max_new_tokens=4,
                            vocab=VOCAB, seed=1)
    handles = run_trace(eng, trace)
    s = eng.stats()
    assert s["completed"] == 4 and s["queue_depth"] == 0
    assert s["tokens_decoded"] == s["throughput_tok_s"] * s["clock_s"] \
        == pytest.approx(4 * 3)            # first token comes from prefill
    lat = latency_summary(handles)
    assert lat["n"] == 4 and lat["p95_s"] >= lat["p50_s"] > 0


def test_engine_static_reservation_serializes(model, params):
    """reserve_lifetime holds a request's full lifetime from admission:
    under a tight quota concurrency collapses but results are intact."""
    trace = _trace(n=4, prompt=12, new=8)
    static = Engine.local(model, _cfg(reserve_lifetime=True), params=params,
                          budget=KVBudget(tier1_pages=4, tier2_bytes=0.0,
                                          page_size=8))
    paged = Engine.local(model, _cfg(), params=params)
    hs_static = run_trace(static, trace)
    hs_paged = run_trace(paged, trace)
    assert static.stats()["preempt_recomputes"] == 0
    assert all(len(h.tokens) == 8 for h in hs_static)
    assert latency_summary(hs_static)["p95_s"] > \
        latency_summary(hs_paged)["p95_s"]


# ---------------------------------------------------------------------------
# modeled-clock attribution
# ---------------------------------------------------------------------------

def test_engine_clock_attribution_exact(model, params):
    """Event clocks land on the event's modeled completion time: for a
    lone request, TTFT is exactly the (bucketed) prefill cost and total
    latency adds one decode step per remaining token — no off-by-a-step
    under-reporting from stamping before the step's dt accrues."""
    eng = Engine.local(model, _cfg(), params=params)
    plen, new = 12, 5
    h = eng.submit(Request(tuple(range(1, 1 + plen)), new))
    eng.run_until_idle()
    bucket = eng._bucket_len(plen)
    assert h.ttft == pytest.approx(eng.cost.prefill_s(bucket))
    want_latency = (eng.cost.prefill_s(bucket)
                    + sum(eng.cost.decode_s(1) for _ in range(new - 1)))
    assert h.latency == pytest.approx(want_latency)
    assert h.done_clock == pytest.approx(eng.clock)


def test_engine_failed_oom_clock_consistent(model, params):
    eng = Engine.local(model, _cfg(), params=params,
                       budget=KVBudget(tier1_pages=2, tier2_bytes=0.0,
                                       page_size=8))
    big = eng.submit(Request((5,) * 30, 20))
    eng.run_until_idle()
    assert big.status is RequestStatus.FAILED_OOM
    assert big.done_clock is not None
    assert big.done_clock >= big.submit_clock
    assert big.done_clock <= eng.clock


def test_engine_future_arrival_never_decoded_early(model, params):
    """A request submitted directly (not via run_trace) with a future
    arrival_time must not be admitted before the modeled clock reaches
    it — pre-fix it was enqueued and decoded immediately, stamping
    first_token_clock BEFORE submit_clock and driving ttft/latency
    negative."""
    eng = Engine.local(model, _cfg(), params=params)
    h = eng.submit(Request((1, 2, 3, 4), 4, arrival_time=0.5))
    assert h.submit_clock == 0.5
    dt = eng.step()                     # gated: nothing to do but wait
    assert h.tokens == [] and h.status is RequestStatus.QUEUED
    assert dt == 0.0 and eng.clock == 0.5    # idle-advance to arrival
    eng.run_until_idle()
    assert h.status is RequestStatus.DONE
    assert h.first_token_clock >= 0.5
    assert h.ttft is not None and h.ttft > 0
    assert h.latency is not None and h.latency > h.ttft > 0
    _check_clock_invariants([h])


def test_engine_future_arrivals_keep_fifo_order(model, params):
    """Arrival gating is head-of-line: a later-submitted request with an
    earlier arrival still waits behind the FIFO head (determinism over
    opportunism), and both complete with non-negative clocks."""
    eng = Engine.local(model, _cfg(), params=params)
    first = eng.submit(Request((1, 2, 3), 3, arrival_time=1.0))
    second = eng.submit(Request((4, 5, 6), 3, arrival_time=0.25))
    eng.run_until_idle()
    assert first.first_token_clock <= second.first_token_clock
    for h in (first, second):
        assert h.ttft > 0 and h.latency > 0
    # the head was served at its arrival, not at the earlier one
    assert first.first_token_clock >= 1.0


def test_engine_busy_throughput_not_idle_diluted(model, params):
    """stats(): total-clock throughput is diluted by idle inter-arrival
    gaps (advance_clock), so a sparse trace reports an arbitrarily low
    rate; busy_s / throughput_busy_tok_s must reflect only worked time."""
    eng = Engine.local(model, _cfg(), params=params)
    trace = [Request((1, 2, 3), 4, arrival_time=0.0),
             Request((4, 5, 6), 4, arrival_time=100.0)]
    run_trace(eng, trace)
    s = eng.stats()
    assert s["clock_s"] > 100.0
    assert 0.0 < s["busy_s"] < 1.0
    assert s["throughput_busy_tok_s"] == pytest.approx(
        s["tokens_decoded"] / s["busy_s"])
    # the diluted number is >100x off on this trace; the busy number
    # is invariant to the gap
    assert s["throughput_busy_tok_s"] > 100 * s["throughput_tok_s"]


def test_latency_summary_nearest_rank():
    def h(lat):
        rh = RequestHandle(rid=0, request=Request((1,), 1),
                           status=RequestStatus.DONE,
                           submit_clock=0.0, done_clock=lat)
        return rh

    # n=2: the old int(p*n) indexing returned the MAX as "p50"
    two = latency_summary([h(1.0), h(2.0)])
    assert two["p50_s"] == 1.0 and two["p95_s"] == 2.0
    three = latency_summary([h(1.0), h(2.0), h(3.0)])
    assert three["p50_s"] == 2.0 and three["p95_s"] == 3.0
    hundred = latency_summary([h(float(i)) for i in range(1, 101)])
    assert hundred["p50_s"] == 50.0 and hundred["p95_s"] == 95.0


# ---------------------------------------------------------------------------
# scheduling policy details
# ---------------------------------------------------------------------------

def test_engine_paused_resume_in_pause_order(model, params):
    """The pause queue is insertion-ordered and resumes pop the FRONT:
    oldest paused re-enters first (ties impossible — pauses are
    sequential), matching the documented policy rather than rid order."""
    eng = Engine.local(model, _cfg(), params=params,
                       budget=KVBudget(tier1_pages=6, tier2_bytes=1e9,
                                       page_size=8))
    for r in _trace(n=5, prompt=12, new=10):
        eng.submit(r)
    prev = []
    saw_pause = False
    for _ in range(10_000):
        if eng.idle:
            break
        eng.step()
        cur = [s.rid for s in eng._paused]
        if cur:
            saw_pause = True
        # whatever left the pause queue this step left from the front
        # (drops can only happen with tier2 headroom exhausted — not here)
        survivors = [r for r in prev if r in cur]
        gone = [r for r in prev if r not in cur]
        assert prev[:len(gone)] == gone and prev[len(gone):] == survivors
        prev = cur
    assert saw_pause, "pressure never paused anything"


def test_engine_prefill_compile_count_bounded(model, params):
    """Bucketed prefill: many distinct prompt lengths, at most one
    compiled program per bucket (the CI compile-guard)."""
    eng = Engine.local(model, _cfg(max_slots=2), params=params)
    if not hasattr(eng._prefill_jit, "_cache_size"):
        pytest.skip("no jit cache introspection: the guard would only see "
                    "its own bucket bookkeeping and pass vacuously")
    lengths = [3, 5, 7, 9, 11, 14, 17, 21, 26, 31, 37, 45]
    rng = np.random.RandomState(0)
    handles = [eng.submit(Request(
        tuple(rng.randint(1, VOCAB, size=n).tolist()), 2))
        for n in lengths]
    eng.run_until_idle()
    assert all(h.status is RequestStatus.DONE for h in handles)
    n_buckets = len(eng.stats()["prefill_buckets"])
    assert eng.stats()["prefill_compiles"] <= n_buckets, (
        f"{eng.stats()['prefill_compiles']} prefill programs for "
        f"{len(set(lengths))} prompt lengths; bucket bound is {n_buckets}")


def test_engine_decode_compile_count_bounded(model, params):
    """Live-row bucketed decode: occupancy swings between 1 and
    max_slots rows across a trace, but the decode program count stays
    <= the pow2 row-bucket list (and tokens match the always-full-array
    reference engine bit-exactly)."""
    eng = Engine.local(model, _cfg(max_slots=6), params=params)
    if not hasattr(eng._decode_jit, "_cache_size"):
        pytest.skip("no jit cache introspection: the guard would only see "
                    "its own bucket bookkeeping and pass vacuously")
    # staggered arrivals + assorted budgets drive occupancy through
    # 1..6 live rows (every bucket), not just the burst peak
    trace = [Request(tuple(np.random.RandomState(i).randint(
                 1, VOCAB, size=8).tolist()),
                 max_new_tokens=3 + 5 * (i % 4),
                 arrival_time=2e-5 * i) for i in range(9)]
    handles = run_trace(eng, trace)
    assert all(h.status is RequestStatus.DONE for h in handles)
    s = eng.stats()
    assert s["decode_row_buckets"] == [1, 2, 4, 6]
    assert s["decode_compiles"] <= len(s["decode_row_buckets"]), (
        f"{s['decode_compiles']} decode programs; bucket bound is "
        f"{s['decode_row_buckets']}")
    assert len(eng._row_buckets_used) >= 3, "occupancy never varied"
    # bucketed decode must not change emitted tokens: per-row outputs
    # are independent of the batch they ride in
    ref = Engine.local(model, _cfg(max_slots=6), params=params)
    ref._row_buckets = [ref.cfg.max_slots]       # force full-array decode
    ref_handles = run_trace(ref, trace)
    assert [h.tokens for h in handles] == [h.tokens for h in ref_handles]

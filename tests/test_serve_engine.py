"""repro.serve validation: engine determinism (same trace -> same tokens
under any arrival interleaving; lease-backed == local construction),
PagedKV budget enforcement with bit-exact spill/fetch round trips, and
request-level failure semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.core.tiering import KVBudget, KVBudgetExceeded, PagedKV
from repro.models.api import build_model
from repro.serve import (Engine, EngineConfig, Request, RequestStatus,
                         burst_trace, latency_summary, run_trace,
                         synthetic_trace)

VOCAB = SMOKE_ARCHS["qwen1.5-0.5b"].vocab


@pytest.fixture(scope="module")
def model():
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"].__class__(**{
        **SMOKE_ARCHS["qwen1.5-0.5b"].__dict__, "compute_dtype": "float32"})
    return build_model(cfg)


def _cfg(**kw):
    base = dict(max_slots=3, max_seq=64, page_size=8)
    base.update(kw)
    return EngineConfig(**base)


def _trace(n=5, prompt=12, new=6, seed=0):
    return burst_trace(n, prompt_len=prompt, max_new_tokens=new,
                       vocab=VOCAB, seed=seed)


# ---------------------------------------------------------------------------
# PagedKV: budget enforcement + bit-exact round trips
# ---------------------------------------------------------------------------

def test_paged_kv_budget_enforced():
    kv = PagedKV(KVBudget(tier1_pages=4, tier2_bytes=100.0, page_size=8),
                 page_bytes=50.0)
    kv.alloc("a", 2)
    kv.alloc("b", 2)
    with pytest.raises(KVBudgetExceeded):
        kv.alloc("c", 1)                     # tier-1 quota full
    kv.spill("a", payload={"x": 1})          # 2 pages * 50B = 100B fits
    assert kv.hot_free == 2 and kv.cold_bytes_used == 100.0
    with pytest.raises(KVBudgetExceeded):
        kv.spill("b", payload={})            # tier-2 budget full
    assert kv.fetch("a") == {"x": 1}
    kv.grow("a", 2)
    with pytest.raises(KVBudgetExceeded):
        kv.grow("a", 3)                      # back over quota
    kv.free("a")
    kv.free("b")
    assert kv.hot_pages_used == 0 and kv.cold_pages_used == 0


def test_paged_kv_round_trip_bit_exact():
    rng = np.random.RandomState(0)
    payload = {
        "k": rng.standard_normal((2, 1, 16, 2, 4)).astype(np.float32),
        "v": jnp.asarray(rng.standard_normal((2, 1, 16, 2, 4)),
                         jnp.bfloat16),
    }
    host = jax.tree.map(np.asarray, payload)
    kv = PagedKV(KVBudget(tier1_pages=8, tier2_bytes=1e9, page_size=8),
                 page_bytes=1024.0)
    kv.alloc("r", 2)
    kv.spill("r", host)
    back = kv.fetch("r")
    np.testing.assert_array_equal(back["k"], np.asarray(payload["k"]))
    np.testing.assert_array_equal(back["v"], np.asarray(payload["v"]))
    assert kv.spills == 1 and kv.fetches == 1


# ---------------------------------------------------------------------------
# engine: spill/fetch under pressure equals the dense (unbudgeted) cache
# ---------------------------------------------------------------------------

def test_engine_budget_pressure_tokens_bit_exact(model):
    """A tier-1 quota tight enough to force tier-2 swaps must reproduce
    the unbudgeted run token-for-token: the spill/fetch round trip is
    bit-exact and the restored cache drives identical decodes."""
    trace = _trace()
    ref = Engine.local(model, _cfg())
    ref_handles = run_trace(ref, trace)

    tight = Engine.local(model, _cfg(),
                         budget=KVBudget(tier1_pages=6, tier2_bytes=1e9,
                                         page_size=8))
    tight_handles = run_trace(tight, trace)
    assert tight.stats()["preempt_swaps"] > 0, "budget pressure not exercised"
    assert [h.tokens for h in tight_handles] == \
        [h.tokens for h in ref_handles]


def test_engine_deterministic_across_arrival_interleavings(model):
    """Same requests, different arrival interleavings (burst vs staggered
    vs reversed submission) -> identical per-request tokens."""
    prompts = [tuple(np.random.RandomState(i).randint(
        1, VOCAB, size=10 + 2 * i).tolist()) for i in range(4)]

    def run_with(arrivals, order):
        eng = Engine.local(model, _cfg())
        reqs = [Request(prompts[i], 5, arrival_time=arrivals[i])
                for i in range(4)]
        handles = run_trace(eng, [reqs[i] for i in order])
        by_prompt = {h.request.prompt_tokens: h.tokens for h in handles}
        return [by_prompt[p] for p in prompts]

    burst = run_with([0.0] * 4, [0, 1, 2, 3])
    staggered = run_with([0.0, 0.004, 0.008, 0.02], [0, 1, 2, 3])
    shuffled = run_with([0.0] * 4, [2, 0, 3, 1])
    assert burst == staggered == shuffled


def test_engine_lease_and_local_identical(model):
    from repro.pool import smoke_pool
    pool = smoke_pool("scalepool")
    lease = pool.lease("serve-eng", 4, tier2_gb=64, kv_gb=1.0)
    trace = _trace(n=4)
    local = run_trace(Engine.local(model, _cfg()), trace)
    leased = run_trace(Engine.from_lease(model, lease, _cfg()), trace)
    assert [h.tokens for h in local] == [h.tokens for h in leased]


# ---------------------------------------------------------------------------
# engine semantics: recycling, recompute preemption, OOM, stats
# ---------------------------------------------------------------------------

def test_engine_slot_recycling_and_fifo(model):
    eng = Engine.local(model, _cfg(max_slots=2))
    handles = [eng.submit(Request((1 + i,) * 8, 4)) for i in range(5)]
    eng.run_until_idle()
    assert all(h.status is RequestStatus.DONE for h in handles)
    assert all(len(h.tokens) == 4 for h in handles)
    # FIFO: a request never starts before an earlier one with 2 slots
    firsts = [h.first_token_clock for h in handles]
    assert firsts == sorted(firsts)
    assert eng.stats()["completed"] == 5
    assert eng.kv.hot_pages_used == 0       # everything freed


def test_engine_recompute_preemption_matches_unbudgeted_counts(model):
    """Tier-1-only pressure preempts by drop + re-prefill; every request
    still completes with its full token budget."""
    trace = _trace(n=5, prompt=12, new=8)
    eng = Engine.local(model, _cfg(),
                       budget=KVBudget(tier1_pages=6, tier2_bytes=0.0,
                                       page_size=8))
    handles = run_trace(eng, trace)
    stats = eng.stats()
    assert stats["preempt_recomputes"] > 0
    assert stats["failed_oom"] == 0
    assert all(len(h.tokens) == 8 for h in handles)


def test_engine_oom_when_request_can_never_fit(model):
    eng = Engine.local(model, _cfg(),
                       budget=KVBudget(tier1_pages=2, tier2_bytes=1e9,
                                       page_size=8))
    ok = eng.submit(Request((1, 2, 3), 4))            # 2 pages: fits
    too_big = eng.submit(Request((5,) * 30, 20))      # 7 pages > quota
    eng.run_until_idle()
    assert ok.status is RequestStatus.DONE
    assert too_big.status is RequestStatus.FAILED_OOM
    with pytest.raises(RuntimeError, match="quota"):
        too_big.result()


def test_engine_submit_validates_capacity(model):
    eng = Engine.local(model, _cfg())
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request((1,) * 60, 10))


def test_engine_stats_and_latency_summary(model):
    eng = Engine.local(model, _cfg())
    trace = synthetic_trace(4, mean_interarrival_s=0.001,
                            prompt_lens=(8, 16), max_new_tokens=4,
                            vocab=VOCAB, seed=1)
    handles = run_trace(eng, trace)
    s = eng.stats()
    assert s["completed"] == 4 and s["queue_depth"] == 0
    assert s["tokens_decoded"] == s["throughput_tok_s"] * s["clock_s"] \
        == pytest.approx(4 * 3)            # first token comes from prefill
    lat = latency_summary(handles)
    assert lat["n"] == 4 and lat["p95_s"] >= lat["p50_s"] > 0


def test_engine_static_reservation_serializes(model):
    """reserve_lifetime holds a request's full lifetime from admission:
    under a tight quota concurrency collapses but results are intact."""
    trace = _trace(n=4, prompt=12, new=8)
    static = Engine.local(model, _cfg(reserve_lifetime=True),
                          budget=KVBudget(tier1_pages=4, tier2_bytes=0.0,
                                          page_size=8))
    paged = Engine.local(model, _cfg())
    hs_static = run_trace(static, trace)
    hs_paged = run_trace(paged, trace)
    assert static.stats()["preempt_recomputes"] == 0
    assert all(len(h.tokens) == 8 for h in hs_static)
    assert latency_summary(hs_static)["p95_s"] > \
        latency_summary(hs_paged)["p95_s"]
